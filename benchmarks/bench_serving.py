"""E5b — location-aware serving: the router saves one prefill per follow-up
turn by landing requests on the engine that already holds the session cache
(compute-on-data-path applied to inference).

Three measurements:

  (a) **router on/off** (original): follow-ups land on the cache holder vs a
      random engine that must re-prefill the history.

  (b) **memory-pressure sweep** (PR 4 tentpole): more sessions than decode
      slots. *Flat pinning* (the pre-tiered behaviour) can only make room by
      finishing sessions — their caches are lost and every follow-up to a
      lost session is a full re-prefill, with "engine full" errors absorbed
      by force-finishing. *Tiered session routing* parks idle sessions into
      the burst-buffer tier and re-hydrates them on resume, so follow-ups
      cost a tier promotion instead of a prefill. In-bench asserts (the PR 4
      acceptance criteria): tiered saves prefills at every oversubscription
      point, zero "engine full" errors on tiered follow-ups, and
      ``store.tier_report()`` accounts the true KV bytes.

  (c) **simulator serving workload**: the same session/KV-chain shape at
      cluster scale — a locality scheduler keeps each session's KV chain on
      one node (bytes stay local), FCFS migrates it every turn.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import FCFSScheduler, HPC_CLUSTER, LocalityScheduler, \
    compile_workflow
from repro.core.locstore import GiB, LocStore, tiered_hierarchy
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import serving_session_workflow
from repro.models import init_params
from repro.serve.engine import Router, ServingEngine


def run(report, quick: bool = False) -> None:
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_engines, n_sessions, n_turns = (2, 2, 2) if quick else (2, 4, 3)

    # ------------------------------------------------- (a) router on/off
    def turns(router_on: bool):
        rng = np.random.default_rng(42)
        store = LocStore(n_engines)
        engines = [ServingEngine(cfg, params, max_batch=n_sessions,
                                 max_seq=96, node=i, store=store)
                   for i in range(n_engines)]
        router = Router(engines, store)
        sessions = []
        for _ in range(n_sessions):
            eng = router.engine_for()
            sid = eng.submit(rng.integers(0, cfg.vocab, 8).tolist())
            sessions.append((eng, sid))
        # follow-up turns: with routing, decode continues on the holder;
        # without, a random engine is picked and must re-prefill the history.
        for _ in range(n_turns):
            for i, (eng, sid) in enumerate(sessions):
                if router_on:
                    target = router.engine_for(sid)
                else:
                    target = engines[rng.integers(0, n_engines)]
                if target.node == eng.node:
                    for _ in range(2):
                        target.step()
                else:  # cache miss -> re-prefill history on the new engine
                    hist = eng.sessions[sid].tokens
                    eng.finish(sid)
                    sid = target.submit(hist[-8:])
                    sessions[i] = (target, sid)
                    for _ in range(2):
                        target.step()
        return sum(e.prefills for e in engines), router

    t0 = time.perf_counter()
    prefills_off, _ = turns(False)
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    prefills_on, router = turns(True)
    t_on = time.perf_counter() - t0
    report("serving/no_router", t_off * 1e6, f"prefills={prefills_off}")
    report("serving/location_router", t_on * 1e6,
           f"prefills={prefills_on} (saved "
           f"{prefills_off - prefills_on}) hits={router.locality_hits}")

    # --------------------------------------- (b) memory-pressure sweep
    max_batch, max_seq = 2, 64
    slots = n_engines * max_batch
    rounds = 2 if quick else 3
    factors = (1.5, 2.0) if quick else (1.5, 2.0, 3.0)

    def pressure_run(n_sess: int, tiered: bool):
        """Returns (prefills, engine_full_errors, router, store, engines)."""
        rng = np.random.default_rng(7)
        if tiered:
            probe = ServingEngine(cfg, params, max_batch=max_batch,
                                  max_seq=max_seq)
            kv = probe.slot_bytes()
            store = LocStore(n_engines, hierarchy=tiered_hierarchy(
                hbm_bytes=max_batch * kv, host_bytes=max_batch * kv,
                bb_bytes=4 * GiB), write_policy="back")
        else:
            store = LocStore(n_engines)
        engines = [ServingEngine(cfg, params, max_batch=max_batch,
                                 max_seq=max_seq, node=i, store=store)
                   for i in range(n_engines)]
        rtr = Router(engines, store, allow_park=tiered)
        errors = 0
        # sid -> (engine, history); dead sessions keep their history so a
        # follow-up can re-prefill (the flat-pinning cost being measured)
        book: dict[int, tuple[ServingEngine, list[int]]] = {}
        order: list[int] = []

        def force_finish_lru() -> None:
            # flat pinning's only escape valve: finish the oldest live
            # session somewhere, discarding its cache
            for old in order:
                if old not in book:     # the session being routed right now
                    continue
                eng, _ = book[old]
                s = eng.sessions.get(old)
                if s is not None and not s.done:
                    eng.finish(old)
                    return

        def admit(prompt: list[int]) -> tuple[ServingEngine, int]:
            nonlocal errors
            while True:
                try:
                    eng = rtr.engine_for()
                    return eng, eng.submit(prompt)
                except RuntimeError:            # "all engines full"
                    errors += 1
                    force_finish_lru()

        for _ in range(n_sess):
            prompt = rng.integers(0, cfg.vocab, 8).tolist()
            eng, sid = admit(prompt)
            book[sid] = (eng, list(eng.sessions[sid].tokens))
            order.append(sid)
        for _ in range(rounds):
            for i, sid in enumerate(list(order)):
                eng, hist = book.pop(sid)
                sess = eng.sessions.get(sid)
                if tiered:
                    try:
                        d = rtr.follow_up(sid, hist[-8:])
                        eng, sid2 = d.engine, d.sid
                    except RuntimeError:
                        errors += 1
                        continue
                else:
                    if sess is not None and not sess.done:
                        eng = rtr.engine_for(sid)   # locality hit: continue
                        sid2 = sid
                    else:                           # cache lost: re-prefill
                        eng, sid2 = admit(hist[-8:])
                eng.step()
                book[sid2] = (eng, list(eng.sessions[sid2].tokens))
                order[i] = sid2
            if tiered:
                store.drain_writebacks()            # background flusher tick
        prefills = sum(e.prefills for e in engines)
        return prefills, errors, rtr, store, engines

    for factor in factors:
        n_sess = int(slots * factor)
        t0 = time.perf_counter()
        flat_prefills, flat_errors, _, _, _ = pressure_run(n_sess, False)
        t_flat = time.perf_counter() - t0
        t0 = time.perf_counter()
        prefills, tier_errors, rtr, store, engines = pressure_run(n_sess, True)
        t_tier = time.perf_counter() - t0
        kv = engines[0].slot_bytes()
        rep = store.tier_report()
        resident = sum(t["resident_bytes"] for t in rep.values())
        live = sum(1 for e in engines for s in e.sessions.values()
                   if not s.done)
        # the true KV bytes are visible to capacity accounting (the zero-byte
        # registration bug this PR fixes would make this 0)
        assert resident >= live * kv * 0.99, \
            f"tier_report misses KV bytes: {resident} < {live}*{kv}"
        assert tier_errors == 0, \
            f"tiered routing hit 'engine full' {tier_errors}x at x{factor}"
        assert prefills < flat_prefills, (
            f"tiered routing saved no prefills at x{factor}: "
            f"{prefills} !< {flat_prefills}")
        mr = store.movement_report()
        report(f"serving/pressure/x{factor}/flat", t_flat * 1e6,
               f"prefills={flat_prefills} engine_full_errors={flat_errors}")
        report(f"serving/pressure/x{factor}/tiered", t_tier * 1e6,
               f"prefills={prefills} (saved {flat_prefills - prefills}) "
               f"engine_full_errors={tier_errors} "
               f"parks={sum(e.parks for e in engines)} "
               f"resumes={sum(e.resumes for e in engines)} "
               f"evictions={rtr.locality_evictions} "
               f"writebacks={int(mr['writebacks'])} "
               f"hbm_gib={rep['hbm']['resident_bytes']/GiB:.4f} "
               f"bb_gib={rep['bb']['resident_bytes']/GiB:.4f}")

    # ------------------------------- (c) simulator serving workload
    n_s, n_t = (10, 3) if quick else (16, 4)
    wf = compile_workflow(serving_session_workflow(n_s, n_t), HPC_CLUSTER)
    r_fcfs = WorkflowSimulator(wf, FCFSScheduler(wf), n_nodes=4,
                               hw=HPC_CLUSTER).run()
    wf2 = compile_workflow(serving_session_workflow(n_s, n_t), HPC_CLUSTER)
    r_loc = WorkflowSimulator(wf2, LocalityScheduler(wf2), n_nodes=4,
                              hw=HPC_CLUSTER).run()
    report("serving/sim/fcfs", 0.0,
           f"kv_moved_gib={r_fcfs.bytes_moved/GiB:.2f} "
           f"hit={r_fcfs.locality_hit_rate:.0%}")
    report("serving/sim/locality", 0.0,
           f"kv_moved_gib={r_loc.bytes_moved/GiB:.2f} "
           f"hit={r_loc.locality_hit_rate:.0%} "
           f"vs_fcfs={r_loc.bytes_moved / max(r_fcfs.bytes_moved, 1.0):.2f}x")
    assert r_loc.bytes_moved <= r_fcfs.bytes_moved, \
        "locality scheduling moved MORE KV bytes than FCFS"
