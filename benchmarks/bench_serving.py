"""E5b — location-aware serving: the router saves one prefill per follow-up
turn by landing requests on the engine that already holds the session cache
(compute-on-data-path applied to inference)."""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.locstore import LocStore
from repro.models import init_params
from repro.serve.engine import Router, ServingEngine


def run(report, quick: bool = False) -> None:
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_engines, n_sessions, n_turns = (2, 2, 2) if quick else (2, 4, 3)

    def turns(router_on: bool):
        rng = np.random.default_rng(42)
        store = LocStore(n_engines)
        engines = [ServingEngine(cfg, params, max_batch=n_sessions,
                                 max_seq=96, node=i, store=store)
                   for i in range(n_engines)]
        router = Router(engines, store)
        sessions = []
        for _ in range(n_sessions):
            eng = router.engine_for()
            sid = eng.submit(rng.integers(0, cfg.vocab, 8).tolist())
            sessions.append((eng, sid))
        # follow-up turns: with routing, decode continues on the holder;
        # without, a random engine is picked and must re-prefill the history.
        for _ in range(n_turns):
            for i, (eng, sid) in enumerate(sessions):
                if router_on:
                    target = router.engine_for(sid)
                else:
                    target = engines[rng.integers(0, n_engines)]
                if target.node == eng.node:
                    for _ in range(2):
                        target.step()
                else:  # cache miss -> re-prefill history on the new engine
                    hist = eng.sessions[sid].tokens
                    eng.finish(sid)
                    sid = target.submit(hist[-8:])
                    sessions[i] = (target, sid)
                    for _ in range(2):
                        target.step()
        return sum(e.prefills for e in engines), router

    t0 = time.perf_counter()
    prefills_off, _ = turns(False)
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    prefills_on, router = turns(True)
    t_on = time.perf_counter() - t0
    report("serving/no_router", t_off * 1e6, f"prefills={prefills_off}")
    report("serving/location_router", t_on * 1e6,
           f"prefills={prefills_on} (saved "
           f"{prefills_off - prefills_on}) hits={router.locality_hits}")
