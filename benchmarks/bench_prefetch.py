"""E2 — proactive pipelining hides I/O time.

Two measurements:
  (a) simulator: per-task I/O wait with locality-only vs proactive scheduling
      (the paper's "data will already be there" claim), across compute:I/O
      ratios — pipelining can only hide movement behind computation, so the
      win should grow with compute intensity;
  (b) real pipeline: wall time of a smoke-scale training run with the
      prefetching loader vs a synchronous loader, with producer latency
      injected (models slow storage).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (HPC_CLUSTER, LocalityScheduler, ProactiveScheduler,
                        compile_workflow, simulate)
from repro.core.workloads import random_layered_workflow
from repro.data.pipeline import PrefetchingLoader


def run(report, quick: bool = False) -> None:
    # (a) simulated I/O wait vs compute intensity
    shape = (4, 8) if quick else (8, 16)
    for fpb in ((2000.0,) if quick else (200.0, 2000.0, 20000.0)):
        g = random_layered_workflow(*shape, seed=3, flops_per_byte=fpb)
        wf = compile_workflow(g, HPC_CLUSTER)
        loc = simulate(wf, LocalityScheduler, n_nodes=16, hw=HPC_CLUSTER)
        pro = simulate(wf, ProactiveScheduler, n_nodes=16, hw=HPC_CLUSTER)
        saved = loc.io_wait_total - pro.io_wait_total
        report(f"prefetch/sim/fpb{int(fpb)}", 0.0,
               f"io_wait {loc.io_wait_total:.1f}s -> {pro.io_wait_total:.1f}s "
               f"(saved {saved:.1f}s, {saved/max(loc.io_wait_total,1e-9):.0%}) "
               f"prefetched={pro.bytes_prefetched/2**30:.1f}GiB")

    # (b) real loader A/B with injected producer latency
    n_batches = 6 if quick else 12

    def producer(delay, n=n_batches):
        for i in range(n):
            time.sleep(delay)
            yield {"x": np.zeros((64, 64), np.float32)}

    def consume(batches, work=0.03):
        for _ in batches:
            time.sleep(work)          # stands in for train_step

    delay = 0.03
    t0 = time.perf_counter()
    consume(producer(delay))
    serial = time.perf_counter() - t0

    t0 = time.perf_counter()
    loader = PrefetchingLoader(producer(delay), depth=2)
    consume(loader)
    overlapped = time.perf_counter() - t0

    report("prefetch/real/serial", serial * 1e6 / n_batches,
           f"wall={serial:.2f}s")
    report("prefetch/real/overlapped", overlapped * 1e6 / n_batches,
           f"wall={overlapped:.2f}s speedup={serial/overlapped:.2f}x "
           f"waits={loader.waits}")
