"""E7 — the storage hierarchy earns its keep under capacity pressure.

Two measurements:

  (a) **simulator capacity sweep** (the headline): the montage workflow —
      whose projected tiles are re-read late by the correction stage — on a
      4-node cluster whose per-node memory is swept from "badly undersized"
      to "comfortable". The *flat* baseline is the paper's original two-tier
      model with a capacity: when host memory fills, the only demotion target
      is the remote PFS, so every late re-read is a PFS fetch. The *tiered*
      store demotes hbm -> host -> burst buffer instead, keeping spilled data
      node-local. Headline numbers: remote-PFS bytes and total I/O wait.

  (b) **store-level trace**: a deterministic cyclic access pattern over a
      working set 2x the host tier, measuring demotion/promotion throughput
      and the remote-byte ratio of tiered vs flat — the microbenchmark view
      of the same effect.
"""

from __future__ import annotations

import time

from repro.core import (HPC_CLUSTER, LocalityScheduler, SimConfig,
                        StorageHierarchy, TierSpec, WorkflowSimulator,
                        compile_workflow, simulate)
from repro.core.locstore import LocStore, SimObject
from repro.core.workloads import montage_workflow, pipeline_chain_workflow

GB = float(1 << 30)
REMOTE_GBPS = 0.5e9          # the paper's ~1 GB/s Lustre, shared


def _flat(cap: float) -> StorageHierarchy:
    """The two-tier baseline WITH a node capacity: host memory over PFS."""
    return StorageHierarchy([TierSpec("host", cap, 100e9)],
                            remote=TierSpec("remote", float("inf"),
                                            REMOTE_GBPS))


def _tiered(cap: float) -> StorageHierarchy:
    """Same host capacity, plus device HBM above and a burst buffer below."""
    return StorageHierarchy(
        [TierSpec("hbm", cap / 4, 819e9),
         TierSpec("host", cap, 100e9),
         TierSpec("bb", 16 * cap, 8e9)],
        remote=TierSpec("remote", float("inf"), REMOTE_GBPS))


def run(report, quick: bool = False) -> None:
    # (a) capacity sweep, tiered vs flat. Derived metrics are key=value
    # tokens so benchmarks/check_trend.py can gate them across PRs.
    width = 16 if quick else 32
    caps = (0.5, 2.0) if quick else (0.25, 0.5, 1.0, 2.0, 8.0)
    wf = compile_workflow(montage_workflow(width), HPC_CLUSTER)
    for cap_gb in caps:
        cap = cap_gb * GB
        rf = simulate(wf, LocalityScheduler, n_nodes=4, hw=HPC_CLUSTER,
                      hierarchy=_flat(cap))
        sim_t = WorkflowSimulator(
            wf, LocalityScheduler(wf),
            config=SimConfig(n_nodes=4, hw=HPC_CLUSTER,
                             hierarchy=_tiered(cap)))
        rt = sim_t.run()
        # analyzer-gated write-around traffic (PR 9): 0 for montage, whose
        # multi-consumer projected tiles earn no safe mode="around" pin
        around = sum(t.nbytes for t in sim_t.store.transfers
                     if t.kind == "writearound")
        saved = 1.0 - rt.remote_bytes / max(rf.remote_bytes, 1e-9)
        report(f"tiers/sweep/cap{cap_gb}g", 0.0,
               f"remote_gib={rt.remote_bytes/GB:.2f} "
               f"remote_flat_gib={rf.remote_bytes/GB:.2f} saved={saved:.0%} "
               f"io_wait_s={rt.io_wait_total:.1f} "
               f"io_wait_flat_s={rf.io_wait_total:.1f} "
               f"makespan_s={rt.makespan:.1f} demotions={rt.demotions} "
               f"around_saved_gib={around/GB:.2f}")

    # (c) analyzer-gated write-around earns its keep (PR 9): pipeline_chain
    # intermediates are single-consumer, so the linter proves every
    # mode="around" pin safe and honor_write_modes="auto" (the default)
    # streams them straight to the PFS — they never occupy node tiers, so
    # eviction pressure drops versus the same config with pins disabled.
    n_chains, depth = (4, 4) if quick else (8, 6)
    wfc = compile_workflow(pipeline_chain_workflow(n_chains, depth),
                           HPC_CLUSTER)
    for cap_gb in caps:
        hier = _tiered(cap_gb * GB)
        sim_off = WorkflowSimulator(
            wfc, LocalityScheduler(wfc),
            config=SimConfig(n_nodes=4, hw=HPC_CLUSTER, hierarchy=hier,
                             honor_write_modes=False))
        r_off = sim_off.run()
        sim_on = WorkflowSimulator(
            wfc, LocalityScheduler(wfc),
            config=SimConfig(n_nodes=4, hw=HPC_CLUSTER, hierarchy=hier))
        r_on = sim_on.run()
        around = sum(t.nbytes for t in sim_on.store.transfers
                     if t.kind == "writearound")
        assert around > 0, "analyzer-proven write-around pins never fired"
        assert r_on.demotions <= r_off.demotions, (
            "write-around increased eviction pressure: "
            f"{r_on.demotions} > {r_off.demotions}")
        report(f"tiers/around/cap{cap_gb}g", 0.0,
               f"around_gib={around/GB:.2f} "
               f"demotions={r_on.demotions} "
               f"demotions_off={r_off.demotions} "
               f"io_wait_s={r_on.io_wait_total:.1f} "
               f"io_wait_off_s={r_off.io_wait_total:.1f} "
               f"makespan_s={r_on.makespan:.1f}")

    # (b) store-level cyclic trace: working set 2x the host tier
    n = 32 if quick else 256
    obj = 64 * (1 << 20)                       # 64 MiB objects
    cap = n * obj / 2.0
    for label, hier in (("flat", _flat(cap)), ("tiered", _tiered(cap))):
        st = LocStore(1, hierarchy=hier)
        t0 = time.perf_counter()
        for i in range(n):
            st.put(f"o{i}", SimObject(float(obj)), loc=0)
        for _ in range(2):                     # two reuse rounds
            for i in range(n):
                st.get(f"o{i}", at=0)
        dt = time.perf_counter() - t0
        rep = st.movement_report()
        ops = n * 3
        report(f"tiers/trace/{label}", dt * 1e6 / ops,
               f"remote_gib={rep['remote_bytes']/GB:.2f} "
               f"demotions={int(rep['demotions'])} "
               f"promotions={int(rep['promotions'])} "
               f"hit={rep['locality_hit_rate']:.0%}")
