"""E10 — trace-driven serving at 10^5 sessions with tail-latency SLOs
(PR 7 tentpole evaluation).

A seeded, wall-clock-free request trace (Zipf session popularity, bursty
arrivals, heavy-tailed lengths) is pushed through the full
``Router``/``ServingEngine`` park/resume/warm/failover lifecycle on the
synthetic compute backend, with service times modeled by ``CostModel`` and
tier media speeds. Three variants, identical trace:

  * **flat**         — flat pinning (no tiers, no parking). The only relief
                       valve under memory pressure is force-finishing LRU
                       sessions, whose follow-ups then pay full-history
                       re-prefills.
  * **tiered**       — park/resume through the hbm→bb→remote hierarchy.
  * **tiered_warm**  — plus predictive warming: per-session inter-arrival
                       EMAs schedule ``Router.warm()`` ahead of the
                       predicted follow-up, promoting the parked KV slice
                       back to HBM before the request lands.

In-bench asserts (the PR 7 acceptance criteria): tiered + warming beats
flat pinning on p99 TTFT under memory pressure; tiered serving takes zero
"engine full" errors; warming produces hits and hides resume seconds.
``check_trend`` gates ``p99_ttft_ms`` / ``p99_resume_ms`` up-bad.

Full mode drives >= 10^5 sessions (~2.5e5 requests); ``--quick`` keeps CI
at 2.5e3 sessions. A failover row kills one engine node mid-trace and
reports resumed-elsewhere vs lost sessions.
"""

from __future__ import annotations

import json
import os
import time

from repro.serve.traffic import (MiB, TraceConfig, TraceDriver,
                                 build_trace_stack, generate_trace,
                                 trace_stats)


def _variant(trace, *, tiered: bool, warm: bool, n_engines: int,
             max_batch: int, failures=(), durability: str = "none"):
    router, store = build_trace_stack(
        n_engines=n_engines, max_batch=max_batch, kv_bytes=64 * MiB,
        tiered=tiered, bb_slots_per_node=96, durability=durability)
    t0 = time.perf_counter()
    rep = TraceDriver(router, trace, warm=warm, failures=failures).run()
    return rep, time.perf_counter() - t0, router, store


def _derived(s: dict, extra: str = "") -> str:
    d = (f"requests={s['requests']} sessions={s['sessions']} "
         f"p50_ttft={s['p50_ttft_ms']:.2f} p95_ttft={s['p95_ttft_ms']:.2f} "
         f"p99_ttft={s['p99_ttft_ms']:.2f} p99_queue={s['p99_queue_ms']:.2f} "
         f"p99_resume={s['p99_resume_ms']:.2f} "
         f"engine_full_errors={s['engine_full_errors']} "
         f"resumes={s['resumes']} migrations={s['migrations']}")
    return f"{d} {extra}".strip()


def run(report, quick: bool = False) -> None:
    # rates sized to ~60% prefill utilization (mean prefill ~62 ms): bursts
    # and memory pressure drive the tail, not a saturated queue
    if quick:
        n_sessions, followups, rate = 2_500, 1.2, 65.0
        n_engines, max_batch = 4, 8
    else:
        n_sessions, followups, rate = 100_000, 1.5, 160.0
        n_engines, max_batch = 8, 16

    cfg = TraceConfig(n_sessions=n_sessions, followups_per_session=followups,
                      req_rate=rate, arrival="bursty", seed=7)
    trace = generate_trace(cfg)
    st = trace_stats(trace)
    report("serving_trace/trace", 0.0,
           f"requests={st['requests']} sessions={st['sessions']} "
           f"duration_s={st['duration']:.1f} cv_gap={st['cv_gap']:.2f} "
           f"top1_share={st['top1_share']:.4f}")

    flat, t_flat, _, _ = _variant(trace, tiered=False, warm=False,
                                  n_engines=n_engines, max_batch=max_batch)
    cold, t_cold, _, _ = _variant(trace, tiered=True, warm=False,
                                  n_engines=n_engines, max_batch=max_batch)
    warm, t_warm, router, store = _variant(trace, tiered=True, warm=True,
                                           n_engines=n_engines,
                                           max_batch=max_batch)
    sf, sc, sw = flat.summary(), cold.summary(), warm.summary()

    # -- the paper claims, enforced in-bench ------------------------------
    assert sw["engine_full_errors"] == 0 and sc["engine_full_errors"] == 0, \
        "tiered serving must absorb pressure by parking, not erroring"
    assert sw["p99_ttft_ms"] < sf["p99_ttft_ms"], (
        f"tiered+warm p99 TTFT {sw['p99_ttft_ms']:.2f}ms must beat flat "
        f"pinning {sf['p99_ttft_ms']:.2f}ms under memory pressure")
    assert sw["warm_hits"] > 0 and sw["resume_hidden_s"] > 0, \
        "predictive warming produced no hits — Router.warm() has no caller?"
    # a partial warm hit pays the in-flight remainder + one extra top-tier
    # read (~0.1 ms on 64 MiB), so allow that epsilon on the p99
    assert sw["p99_resume_ms"] <= sc["p99_resume_ms"] * 1.05, (
        f"warming made p99 resume worse: {sw['p99_resume_ms']:.2f} > "
        f"{sc['p99_resume_ms']:.2f}")
    assert sf["force_finished"] > 0, \
        "flat baseline never hit pressure — trace is undersized"

    report("serving_trace/flat", t_flat * 1e6, _derived(
        sf, f"force_finished={sf['force_finished']} "
            f"lost_reprefills={sf['lost_reprefills']}"))
    report("serving_trace/tiered", t_cold * 1e6, _derived(sc))
    report("serving_trace/tiered_warm", t_warm * 1e6, _derived(
        sw, f"warms={sw['warms']} warm_hits={sw['warm_hits']} "
            f"warm_hit_rate={sw['warm_hit_rate']:.3f} "
            f"wasted_warms={sw['wasted_warms']} "
            f"resume_hidden_s={sw['resume_hidden_s']:.3f} "
            f"bytes_promoted_gib="
            f"{store.movement_report()['bytes_promoted'] / 2**30:.2f}"))

    # -- failover mid-trace: kill one node at the halfway point -----------
    t_mid = trace[len(trace) // 2].t
    fo, t_fo, fo_router, _ = _variant(
        trace, tiered=True, warm=True, n_engines=n_engines,
        max_batch=max_batch, failures=((t_mid, 0),),
        durability="flush_before_ack")
    sfo = fo.summary()
    assert len(fo_router.engines) == n_engines - 1
    assert sfo["engine_full_errors"] == 0
    assert sfo["failover_resumed"] > 0, \
        "durable parks must survive the node loss and re-home"
    report("serving_trace/failover", t_fo * 1e6, _derived(
        sfo, f"failover_resumed={sfo['failover_resumed']} "
             f"failover_lost={sfo['failover_lost']}"))

    os.makedirs("results", exist_ok=True)
    with open("results/trace_summary.json", "w") as f:
        json.dump({"trace": st, "flat": sf, "tiered": sc,
                   "tiered_warm": sw, "failover": sfo}, f, indent=1)
