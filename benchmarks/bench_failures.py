"""E9 — failure-aware durability earns its keep (ISSUE 5 tentpole).

Compute-on-data-path trades durability for locality: fresh output lives only
on the node that produced it, so a failure re-runs producers. Two sweeps
measure what closing the durability window buys and costs:

  (a) **failure rate × durability policy** (headline): the pipeline-chain
      workload (every intermediate a sole copy) under write-back, with 0/1/2
      node failures injected mid-run, for each policy. ``none`` re-runs every
      dirty sole-copy producer the failure catches; ``fsync_on_barrier``
      bounds the exposure to one barrier interval; ``flush_before_ack``
      closes it entirely. The price shows up as fsync traffic on the demand
      NIC lane — the io-wait delta against ``none`` at zero failures.

  (b) **serving failover**: a parked session whose engine node dies is
      re-hydrated on a surviving engine from the durable replica of its KV
      slice — bit-identical decode, zero re-prefill — while a live-in-slot
      session (KV = engine memory) is lost and must re-prefill.

In-bench assertions (the ISSUE 5 acceptance criteria):
  * with failures injected, ``fsync_on_barrier`` re-runs strictly fewer
    tasks than plain write-back (``none``), and loses zero dirty objects;
  * zero phantom-durable objects anywhere in the sweep (a cancelled flush
    sourced on a dead node never launders lost bytes into durability);
  * cross-engine failover saves >= 1 prefill per parked-session failure and
    the post-failover decode is bit-identical to an unfailed control.

A third sweep (ISSUE 10 satellite) compares the **predictive re-replication
trigger** against purely reactive recovery: a health monitor flags each
failing node ``predict_lead_s`` early and the store drains its sole copies
to another failure domain before the crash. The in-bench assert: the
predictive run loses strictly less (``dirty_lost + reruns``) than the
reactive run of the same schedule.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.configs import get_smoke
from repro.core import HPC_CLUSTER, ProactiveScheduler, compile_workflow
from repro.core.locstore import (GiB, LocStore, StorageHierarchy, TierSpec,
                                 tiered_hierarchy)
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import pipeline_chain_workflow
from repro.models import init_params
from repro.serve.engine import Router, ServingEngine, _cache_name

POLICIES = ("none", "fsync_on_barrier", "flush_before_ack")

# failure schedules hit the chain mid-run (makespan ~6.4s for 4x6 chains):
# every stage output the failure catches un-flushed is a producer re-run
FAILURE_SCHEDULES = ((), ((4.0, 0),), ((4.0, 0), (4.5, 2)))


def run(report, quick: bool = False) -> None:
    # ----------------------------- (a) failure rate x durability policy
    wf = compile_workflow(pipeline_chain_workflow(4, 6), HPC_CLUSTER)
    schedules = FAILURE_SCHEDULES[:2] if quick else FAILURE_SCHEDULES
    for failures in schedules:
        results = {}
        for pol in POLICIES:
            sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                    hw=HPC_CLUSTER, write_policy="back",
                                    durability=pol, failures=list(failures))
            r = sim.run()
            results[pol] = r
            assert r.tasks_done == len(wf.graph.tasks)
            assert r.phantom_durable == 0, \
                f"phantom-durable object at f={len(failures)} policy={pol}"
            assert sim.store.movement_report()["pins"] == 0, "leaked pins"
            report(f"failures/sweep/f{len(failures)}/{pol}", 0.0,
                   f"reruns={r.reruns} dirty_lost={r.dirty_lost} "
                   f"fsyncs={r.fsyncs} fsync_gib={r.fsync_bytes/GiB:.2f} "
                   f"io_wait_s={r.io_wait_total:.1f} "
                   f"makespan_s={r.makespan:.1f} "
                   f"phantom={r.phantom_durable} "
                   f"aborts={r.prefetch_aborts}")
        none, barrier = results["none"], results["fsync_on_barrier"]
        ack = results["flush_before_ack"]
        if failures:
            # the acceptance criterion: a bounded window re-runs less
            assert none.dirty_lost > 0, \
                f"failure schedule {failures} missed all dirty data"
            assert barrier.reruns < none.reruns, (
                f"fsync_on_barrier did not cut reruns at f={len(failures)}: "
                f"{barrier.reruns} !< {none.reruns}")
            assert barrier.dirty_lost == 0 and ack.dirty_lost == 0
            report(f"failures/sweep/f{len(failures)}/saved", 0.0,
                   f"reruns_saved={none.reruns - barrier.reruns} "
                   f"io_wait_cost_s="
                   f"{barrier.io_wait_total - none.io_wait_total:.1f}")
        else:
            # zero failures: the policies' only effect is the fsync cost
            assert none.fsyncs == 0 and barrier.fsyncs > 0

    # ---------------------------- (a2) predictive vs reactive recovery
    wf_p = compile_workflow(pipeline_chain_workflow(8, 6), HPC_CLUSTER)
    hier = StorageHierarchy(
        [TierSpec("hbm", 6e9, 800e9), TierSpec("bb", 12e9, 10e9)],
        remote=TierSpec("remote", float("inf"), 0.5e9))
    results_p = {}
    for mode, predict in (("predictive", True), ("reactive", False)):
        sim = WorkflowSimulator(wf_p, ProactiveScheduler(wf_p,
                                                         risk_aware=True),
                                n_nodes=4, hw=HPC_CLUSTER, hierarchy=hier,
                                failures=[(8.0, 1)], predict_failures=predict,
                                predict_lead_s=3.0)
        r = sim.run()
        results_p[mode] = r
        assert r.tasks_done == len(wf_p.graph.tasks)
        report(f"failures/predictive/{mode}", 0.0,
               f"reruns={r.reruns} dirty_lost={r.dirty_lost} "
               f"predictive_rereps={r.predictive_rereplications} "
               f"predictive_gib="
               f"{r.bytes_predictively_rereplicated / GiB:.2f} "
               f"makespan_s={r.makespan:.1f}")
    pred, react = results_p["predictive"], results_p["reactive"]
    assert pred.predictive_rereplications > 0, \
        "the flagged failure must trigger at least one predictive copy"
    assert (pred.dirty_lost + pred.reruns
            < react.dirty_lost + react.reruns), (
        f"predictive did not beat reactive: "
        f"{pred.dirty_lost}+{pred.reruns} !< "
        f"{react.dirty_lost}+{react.reruns}")
    loss_saved = (react.dirty_lost + react.reruns
                  - pred.dirty_lost - pred.reruns)
    report("failures/predictive/saved", 0.0,
           f"loss_saved={loss_saved} "
           f"makespan_saved_s={react.makespan - pred.makespan:.1f}")

    # --------------------------------------------- (b) serving failover
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_seq = 64
    kv = ServingEngine(cfg, params, max_batch=2,
                       max_seq=max_seq).slot_bytes()

    def mk_store():
        return LocStore(2, hierarchy=tiered_hierarchy(
            hbm_bytes=2 * kv, host_bytes=2 * kv, bb_bytes=float(1 << 30)),
            write_policy="back", durability="flush_before_ack")

    # control: park/resume on one engine, no failure — the token truth
    ctrl = ServingEngine(cfg, params, max_batch=2, max_seq=max_seq, node=0,
                         store=mk_store())
    sid_c = ctrl.submit([5, 6, 7])
    for _ in range(3):
        ctrl.step()
    ctrl.park(sid_c)
    ctrl.resume(sid_c)
    for _ in range(3):
        ctrl.step()
    want = ctrl.sessions[sid_c].tokens[:7]

    store = mk_store()
    engines = [ServingEngine(cfg, params, max_batch=2, max_seq=max_seq,
                             node=i, store=store) for i in range(2)]
    router = Router(engines, store)
    a, b = engines
    sid = a.submit([5, 6, 7])              # parked before the failure
    for _ in range(3):
        a.step()
    a.park(sid)
    live_sid = a.submit([9, 8, 7])         # live in a slot: dies with a
    assert store.durable(_cache_name(sid))
    prefills_before = a.prefills + b.prefills
    rep = router.fail_engine(0)
    assert rep.resumed == (sid,), "the durable parked session must fail over"
    assert rep.lost == (live_sid,), "the live slot's KV died with the engine"
    assert a.prefills + b.prefills == prefills_before, \
        "failover must not re-prefill"
    for _ in range(3):
        b.step()
    got = b.sessions[sid].tokens[:7]
    assert got == want, f"failover decode diverged: {got} != {want}"
    report("failures/serving/failover", 0.0,
           f"prefills_saved={router.failover_resumes} "
           f"sessions_lost={router.failover_lost} "
           f"bit_identical=1 "
           f"kv_gib={kv/GiB:.3f}")
    assert router.failover_resumes >= 1, \
        "a parked-session failure must save at least one prefill"
