"""E6 — roofline analysis from the dry-run's compiled artifacts.

For every (arch × shape × mesh) cell in results/dryrun.jsonl:

  compute term    = corrected dot FLOPs per device   / 197 TFLOP/s (bf16)
  memory term     = (result bytes + argument bytes)  / 819 GB/s HBM
  collective term = corrected collective bytes       / 50 GB/s ICI link

(dot FLOPs / collective bytes are while-trip-count corrected — see
launch/hlo_analysis.py; cost_analysis() counts loop bodies once and is
reported alongside for reference.)

Also derives MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens
(prefill, decode) and the usefulness ratio MODEL_FLOPS / compiled FLOPs —
remat recompute, attention, and sharding redundancy all push it below 1.

perf_fraction = ideal-compute-time / dominant-term-time — the dry-run MFU
equivalent this repo's §Perf score is measured by.
"""

from __future__ import annotations

import json
import os

from repro.configs import SHAPES, get_config
from repro.models import active_param_count

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (conservative single-link figure)

def _default_dryrun() -> str:
    for cand in ("results/dryrun_final.jsonl", "results/dryrun_opt.jsonl",
                 "results/dryrun.jsonl"):
        if os.path.exists(cand):
            return cand
    return "results/dryrun.jsonl"


DRYRUN = os.environ.get("XFLOW_DRYRUN") or _default_dryrun()


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token/sequence


def terms(rec: dict) -> dict:
    nd = rec["n_devices"]
    comp = rec.get("dot_flops_per_device", 0.0) / PEAK_FLOPS
    mem = (rec.get("result_bytes_per_device", 0.0)
           + rec.get("argument_size_in_bytes", 0)) / HBM_BW
    coll = rec.get("collective_total", 0.0) / ICI_BW
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec["shape"])
    ideal = mf / nd / PEAK_FLOPS
    frac = ideal / dom[1] if dom[1] > 0 else 0.0
    hlo_total = rec.get("dot_flops_per_device", 0.0) * nd
    return {
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dom[0], "dominant_s": dom[1],
        "model_flops": mf, "useful_ratio": mf / hlo_total if hlo_total else 0,
        "perf_fraction": frac,
    }


def suggestion(t: dict) -> str:
    if t["dominant"] == "collective":
        return "shard activations on seq (SP) / overlap collectives"
    if t["dominant"] == "memory":
        return "shrink cache sweep (window slice) / fuse & reuse"
    if t["useful_ratio"] < 0.5:
        return "cut remat recompute / replicated compute"
    return "increase arithmetic intensity (larger per-chip tiles)"


def load(path: str = DRYRUN) -> list[dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return list(recs.values())


def run(report, quick: bool = False) -> None:
    if not os.path.exists(DRYRUN):
        report("roofline/missing", 0.0, f"run launch/dryrun.py first ({DRYRUN})")
        return
    recs = [r for r in load() if r.get("ok")]
    recs = sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if quick:                   # smoke scale: a handful of cells, not the grid
        recs = recs[:4]
    worst = None
    for r in recs:
        t = terms(r)
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        report(name, t["dominant_s"] * 1e6,
               f"comp={t['compute_s']*1e3:.1f}ms mem={t['memory_s']*1e3:.1f}ms "
               f"coll={t['collective_s']*1e3:.1f}ms dom={t['dominant']} "
               f"useful={t['useful_ratio']:.2f} frac={t['perf_fraction']:.3f} "
               f"-> {suggestion(t)}")
        if worst is None or t["perf_fraction"] < worst[1]:
            worst = (name, t["perf_fraction"])
    if worst:
        report("roofline/worst_cell", 0.0, f"{worst[0]} frac={worst[1]:.4f}")
