"""Perf-trend gate: fail CI when the current benchmark run regresses >2x
against the latest committed baseline (PR 3 satellite).

Usage (the CI --quick job runs it right after ``run.py --quick``)::

    python benchmarks/check_trend.py                      # auto baseline
    python benchmarks/check_trend.py --baseline BENCH_3.json --threshold 2.0

* **Current run**: ``results/benchmarks.json`` (what run.py just wrote).
* **Baseline**: the highest-numbered ``BENCH_<n>.json`` committed at the repo
  root. Baselines are committed from ``--quick`` runs so CI compares like
  with like; commit a fresh ``BENCH_<n+1>.json`` per PR to ratchet.
* **Watched metrics**: ``key=value`` tokens in a row's ``derived`` string.
  Keys mentioning ``remote``, ``io_wait``, ``reruns`` (failure-induced task
  re-executions), ``dirty_lost``, ``phantom``, ``p99_ttft``,
  ``p99_resume`` (the serving-trace tail-latency SLOs, PR 7), ``recovery``
  or ``goodput_dip`` (the elastic-membership recovery SLOs, PR 8),
  ``cross_spine`` or ``topo_makespan`` (the topology-aware placement wins,
  PR 10) are **higher-is-worse**:
  the gate fails when current > threshold x baseline. Keys mentioning
  ``saved`` (``reruns_saved``, ``prefills_saved`` — the durability/failover
  wins) are **lower-is-worse**: the gate fails when current < baseline /
  threshold. Rows absent from either side, non-token formats, and near-zero
  baselines (< EPS, where timing noise dominates) are skipped — except that
  a higher-is-worse metric appearing from a ~zero baseline still fails, and
  a lower-is-worse win vanishing from a still-present row counts as
  shrinking to zero (not as a free pass). ``sched/scale/*`` rows are
  special: their top-level ``us_per_call`` (scheduler decision cost) is
  gated directly, higher-is-worse — the indexed-scheduler speedup (PR 6)
  must not erode.
* **Per-row allow-list**: a deliberate regression can be waived for exactly
  one (row, metric) pair — either ``--allow 'row/name:metric'`` on the
  command line or an entry in ``benchmarks/trend_allowlist.json``::

      [{"name": "writeback/sweep/cap1.0g/back_coord", "metric": "remote_gib",
        "reason": "pins keep prefetched dups on-node; remote shifts to ..."}]

  Waived regressions are printed (with their reason) but do not fail the
  gate. The ``reason`` field is mandatory in the file — an allow-list entry
  nobody can explain is a bug magnet.

Exit code 1 lists every non-waived regression; 0 otherwise.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WATCHED = ("remote", "io_wait", "reruns", "dirty_lost", "phantom",
           "p99_ttft", "p99_resume", "recovery", "goodput_dip",
           "cross_spine", "topo_makespan")
# wins that must not shrink: checked in the opposite direction. Matched
# FIRST — "reruns_saved" is a saving, not a rerun count.
WATCHED_DOWN = ("saved",)
# rows whose top-level us_per_call IS the metric (not a derived token):
# scheduler decision cost must not regress — higher is worse (PR 6)
CALL_COST_ROWS = ("sched/scale/",)
EPS = 0.05                      # ignore baselines this small (noise floor)
_TOKEN = re.compile(r"([A-Za-z_][A-Za-z0-9_]*)="
                    r"([-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?)(?![->\d])")


@dataclasses.dataclass(frozen=True)
class Regression:
    name: str
    metric: str
    baseline: float
    current: float

    @property
    def ratio(self) -> float:
        return (self.current / self.baseline if self.baseline
                else float("inf"))

    def __str__(self) -> str:
        return (f"{self.name}: {self.metric} {self.baseline:g} -> "
                f"{self.current:g} ({self.ratio:.2f}x)")


def parse_metrics(derived: str) -> dict[str, float]:
    """``key=value`` tokens with trailing units stripped; ``a 10->20s`` arrow
    forms are prose, not metrics."""
    return {k: float(v) for k, v in _TOKEN.findall(derived)}


def latest_baseline(root: str = ROOT) -> str | None:
    best, best_n = None, -1
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(path))
        if m and int(m.group(1)) > best_n:
            best, best_n = path, int(m.group(1))
    return best


def load_allowlist(path: str | None = None) -> set[tuple[str, str]]:
    """(row name, metric) pairs waived in benchmarks/trend_allowlist.json.
    Every entry must carry a non-empty ``reason``; a missing file is an
    empty allow-list."""
    path = path or os.path.join(ROOT, "benchmarks", "trend_allowlist.json")
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        entries = json.load(f)
    out: set[tuple[str, str]] = set()
    for e in entries:
        if not e.get("reason", "").strip():
            raise ValueError(f"allow-list entry {e.get('name')!r}:"
                             f"{e.get('metric')!r} has no reason")
        out.add((e["name"], e["metric"]))
    return out


def regressions(current: list[dict], baseline: list[dict],
                threshold: float = 2.0,
                allowed: set[tuple[str, str]] | None = None,
                waived: list[Regression] | None = None) -> list[Regression]:
    """``allowed`` holds (row name, metric) pairs whose regressions are
    waived — they land in ``waived`` (if given) instead of the result."""
    allowed = allowed or set()
    base_rows = {r["name"]: parse_metrics(r.get("derived", ""))
                 for r in baseline}
    out: list[Regression] = []
    def emit(r: Regression) -> None:
        if (r.name, r.metric) in allowed:
            if waived is not None:
                waived.append(r)
        else:
            out.append(r)

    base_calls = {r["name"]: float(r.get("us_per_call", 0.0))
                  for r in baseline}
    for row in current:
        base = base_rows.get(row["name"])
        if base is None:
            continue
        if any(row["name"].startswith(p) for p in CALL_COST_ROWS):
            # decision-cost rows: us_per_call itself is the watched metric,
            # direction-aware (up-bad)
            base_val = base_calls.get(row["name"], 0.0)
            cur_val = float(row.get("us_per_call", 0.0))
            if base_val >= EPS and cur_val > threshold * base_val:
                emit(Regression(row["name"], "us_per_call",
                                base_val, cur_val))
        cur = parse_metrics(row.get("derived", ""))
        for key, base_val in base.items():
            if any(w in key for w in WATCHED_DOWN):
                # a win (reruns_saved, prefills_saved) must not shrink — and
                # a win that VANISHES from the row is the maximal shrink,
                # not a free pass
                cur_val = cur.get(key, 0.0)
                if base_val >= EPS and cur_val < base_val / threshold:
                    emit(Regression(row["name"], key, base_val, cur_val))
                continue
            if key not in cur:
                continue
            if not any(w in key for w in WATCHED):
                continue
            if base_val < EPS:
                # a ~zero baseline can't be ratioed, but traffic appearing
                # from nothing (the PR-2 class of bug) must still fail
                if cur[key] > 2 * EPS:
                    emit(Regression(row["name"], key, base_val, cur[key]))
                continue
            if cur[key] > threshold * base_val:
                emit(Regression(row["name"], key, base_val, cur[key]))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=os.path.join(ROOT, "results",
                                                      "benchmarks.json"))
    ap.add_argument("--baseline", default=None,
                    help="baseline BENCH_<n>.json (default: latest committed)")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when current > threshold * baseline")
    ap.add_argument("--allow", action="append", default=[],
                    metavar="ROW:METRIC",
                    help="waive one (row, metric) regression; repeatable "
                         "(also read from benchmarks/trend_allowlist.json)")
    args = ap.parse_args()

    allowed = load_allowlist()
    for spec in args.allow:
        name, sep, metric = spec.rpartition(":")
        if not sep or not name:
            ap.error(f"--allow wants ROW:METRIC, got {spec!r}")
        allowed.add((name, metric))

    baseline_path = args.baseline or latest_baseline()
    if baseline_path is None:
        print("check_trend: no committed BENCH_<n>.json baseline — skipping")
        return 0
    if not os.path.exists(args.current):
        print(f"check_trend: no current run at {args.current} — "
              f"run benchmarks/run.py first", file=sys.stderr)
        return 1
    with open(args.current) as f:
        current = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)

    waived: list[Regression] = []
    bad = regressions(current, baseline, args.threshold,
                      allowed=allowed, waived=waived)
    compared = sum(1 for r in current
                   if r["name"] in {b["name"] for b in baseline})
    print(f"check_trend: {compared} shared rows vs "
          f"{os.path.basename(baseline_path)}, threshold {args.threshold}x")
    for r in waived:
        print(f"  waived (allow-list): {r}")
    if bad:
        print(f"FAILED: {len(bad)} perf regression(s):", file=sys.stderr)
        for r in bad:
            print(f"  {r}", file=sys.stderr)
        return 1
    print("check_trend: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
