"""E8 — async write-back and cluster-coordinated eviction earn their keep.

Three measurements on the montage workflow under tight per-node capacity
(the regime where PR 2's write-through demotion pays a synchronous PFS write
on the demand NIC lane for every spill):

  (a) **policy sweep** (headline): write-through vs async write-back vs
      write-back + coordinated eviction, per capacity point. Write-back moves
      the flush to the background lane (and drops already-flushed replicas
      for free), so critical-path I/O wait falls; coordination additionally
      drops replicas that are duplicated elsewhere instead of re-writing
      them to the PFS, so remote bytes fall.

  (b) **store-level reuse trace**: a cyclic working set ~1.6x the node
      tiers — every object is flushed to the PFS at most ONCE; re-evictions
      of PFS-backed replicas are free clean drops under both policies (the
      ledger/scalar consistency the cross-check test pins down), and
      write-back additionally takes the one flush off the caller's path.

  (c) **write-around**: streaming run-once outputs bypass the node tiers
      entirely, so they stop evicting the hot working set.

In-bench assertions (the PR 3 acceptance criteria):
  * async write-back reduces critical-path io-wait vs write-through at the
    tight capacity points,
  * coordinated eviction never drops a sole fast-tier copy anywhere in the
    sweep (``coordination_violations == 0`` and every dataset resolvable).
"""

from __future__ import annotations

import time

from repro.core import (HPC_CLUSTER, ProactiveScheduler, StorageHierarchy,
                        TierSpec, compile_workflow)
from repro.core.locstore import LocStore, SimObject
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import montage_workflow

GB = float(1 << 30)
REMOTE_GBPS = 0.5e9


def _tiered(cap: float) -> StorageHierarchy:
    return StorageHierarchy(
        [TierSpec("hbm", cap / 4, 819e9),
         TierSpec("host", cap, 100e9),
         TierSpec("bb", 16 * cap, 8e9)],
        remote=TierSpec("remote", float("inf"), REMOTE_GBPS))


POLICIES = (("through", {}),
            ("back", {"write_policy": "back"}),
            ("back_coord", {"write_policy": "back",
                            "coordinated_eviction": True}))


def run(report, quick: bool = False) -> None:
    # (a) policy sweep under capacity pressure; tight points assert the win
    width = 16 if quick else 24
    caps = (0.125, 0.25) if quick else (0.125, 0.25, 0.5, 1.0)
    tight = set(caps[:2])
    wf = compile_workflow(montage_workflow(width), HPC_CLUSTER)
    for cap_gb in caps:
        results = {}
        for label, kw in POLICIES:
            sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                    hw=HPC_CLUSTER,
                                    hierarchy=_tiered(cap_gb * GB), **kw)
            r = sim.run()
            results[label] = r
            # coordinated eviction must never cost data: no sole copy is
            # ever dropped, and every dataset stays resolvable
            assert sim.store.coordination_violations == 0, \
                f"sole-copy drop at cap={cap_gb}g policy={label}"
            assert r.tasks_done == len(wf.graph.tasks)
            for name in sim.store.loc.names():
                assert sim.store.exists(name)
            # prefetch pins released cleanly, and none of the replicas they
            # protected was evicted out from under a pending consumer (the
            # "coordinated eviction undoes prefetch at comfortable capacity"
            # ROADMAP bug, worst at the 1 GiB point)
            assert sim.store.movement_report()["pins"] == 0
            report(f"writeback/sweep/cap{cap_gb}g/{label}", 0.0,
                   f"io_wait_s={r.io_wait_total:.1f} "
                   f"remote_gib={r.remote_bytes/GB:.2f} "
                   f"makespan_s={r.makespan:.1f} writebacks={r.writebacks} "
                   f"clean_drops={r.clean_drops} coord_drops={r.coord_drops} "
                   f"pin_protected={r.pin_protected_evictions}")
        if cap_gb in tight:
            thru, back = results["through"], results["back"]
            assert back.writebacks > 0, f"no write-backs at cap={cap_gb}g"
            assert back.io_wait_total < thru.io_wait_total, (
                f"write-back did not cut io-wait at cap={cap_gb}g: "
                f"{back.io_wait_total:.1f} !< {thru.io_wait_total:.1f}")
        if cap_gb >= 1.0:
            # comfortable capacity: the do-not-evict pins must actually have
            # defended prefetched replicas from the eviction scans here —
            # this is the point where PR 3's coordination undid prefetch work
            assert results["back_coord"].pin_protected_evictions > 0, (
                f"pins never shielded a prefetched replica at cap={cap_gb}g")

    # (b) store-level reuse trace: flushed-once, re-evicted free. The node
    # tiers hold ~60% of the working set, so the cyclic reuse keeps cycling
    # objects through the PFS boundary — each object pays its flush at most
    # once; every later eviction of a PFS-backed replica is a free drop.
    n = 32 if quick else 128
    obj = 64 * (1 << 20)
    cap = n * obj / 2.0
    trace_hier = StorageHierarchy(
        [TierSpec("hbm", cap / 4, 819e9),
         TierSpec("host", cap / 2, 100e9),
         TierSpec("bb", cap / 2, 8e9)],
        remote=TierSpec("remote", float("inf"), REMOTE_GBPS))
    for label, kw in (("through", {}), ("back", {"write_policy": "back"})):
        st = LocStore(1, hierarchy=trace_hier, **kw)
        t0 = time.perf_counter()
        for i in range(n):
            st.put(f"o{i}", SimObject(float(obj)), loc=0)
        for _ in range(2):                    # cyclic reuse: re-stage, re-evict
            st.drain_writebacks()
            for i in range(n):
                st.get(f"o{i}", at=0)
                st.replicate(f"o{i}", [0])
        st.drain_writebacks()
        dt = time.perf_counter() - t0
        rep = st.movement_report()
        assert rep["writebacks"] <= n, "an object was flushed more than once"
        assert rep["clean_drops"] > 0, "reuse rounds produced no free drops"
        report(f"writeback/trace/{label}", dt * 1e6 / (n * 5),
               f"remote_gib={rep['remote_bytes']/GB:.2f} "
               f"writebacks={int(rep['writebacks'])} "
               f"clean_drops={int(rep['clean_drops'])} "
               f"demotions={int(rep['demotions'])}")

    # (c) write-around keeps streaming outputs off the node tiers
    st = LocStore(1, hierarchy=_tiered(cap))
    for i in range(n):                        # hot working set fills the tiers
        st.put(f"hot{i}", SimObject(float(obj)), loc=0, tier="host")
    st.reset_accounting()
    for i in range(n):
        st.put(f"stream{i}", SimObject(float(obj)), loc=0, mode="around")
    rep = st.movement_report()
    report("writeback/around/stream", 0.0,
           f"remote_gib={rep['remote_bytes']/GB:.2f} "
           f"demotions={int(rep['demotions'])}")
    assert rep["demotions"] == 0, "write-around must not evict the hot set"
