"""Benchmark harness — one module per paper claim / deliverable table.

Prints ``name,us_per_call,derived`` CSV rows (and tees a JSON copy to
results/benchmarks.json).

  E1 bench_scheduler — FCFS vs locality vs proactive (+ 4096-node scaling)
  E2 bench_prefetch  — proactive pipelining hides I/O time (sim + real)
  E3 bench_ablation  — cross-layer ablation (each layer earns its keep)
  E4 bench_locstore  — location service / store microbenchmarks
  E5 bench_serving   — location-aware routing saves prefills
  E6 bench_roofline  — roofline terms per (arch × shape × mesh) dry-run cell
  E7 bench_tiers     — storage hierarchy vs flat store under capacity pressure
  E8 bench_writeback — async write-back + coordinated eviction vs write-through
  E9 bench_failures  — durability policies under node failures + serving failover
  E10 bench_serving_trace — 10^5-session trace replay: tail-latency SLOs
      (p50/p95/p99 TTFT + resume), flat pinning vs tiers vs predictive warm
  E11 bench_membership — elastic membership: fail-then-join recovery time,
      goodput dip, autoscale-under-load, workflow re-replication cycle
  E12 bench_topology — topology-aware vs blind placement on oversubscribed
      and mixed-generation fabrics (cross-spine bytes + makespan)

``--quick`` runs every module at smoke scale (small shapes, few reps) — the
CI benchmark job uses it to keep the perf trajectory alive on every push
(tests/test_benchmarks_quick.py asserts every module accepts the flag).
Exits non-zero if any module reported an ``/ERROR`` row, so a crashed
benchmark cannot green-light CI. ``benchmarks/check_trend.py`` then gates
the result against the latest committed BENCH_<n>.json.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import sys

# self-sufficient invocation: `python benchmarks/run.py` from the repo root
# (or anywhere) finds both the benchmarks package and src/repro
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module name")
    ap.add_argument("--quick", action="store_true",
                    help="smoke scale: small shapes / few reps (CI)")
    ap.add_argument("--sanitize", action="store_true",
                    help="run every simulation with the runtime invariant "
                         "sanitizer enabled (repro.analysis.sanitize)")
    args, _ = ap.parse_known_args()

    if args.sanitize:
        os.environ["REPRO_SANITIZE"] = "1"

    from benchmarks import (bench_ablation, bench_failures, bench_locstore,
                            bench_membership, bench_prefetch, bench_roofline,
                            bench_scheduler, bench_serving,
                            bench_serving_trace, bench_tiers, bench_topology,
                            bench_writeback)
    modules = [bench_scheduler, bench_prefetch, bench_ablation,
               bench_locstore, bench_serving, bench_roofline, bench_tiers,
               bench_writeback, bench_failures, bench_serving_trace,
               bench_membership, bench_topology]

    rows: list[dict] = []

    def report(name: str, us_per_call: float, derived: str = "") -> None:
        rows.append({"name": name, "us_per_call": us_per_call,
                     "derived": derived})
        print(f"{name},{us_per_call:.2f},{derived}", flush=True)

    print("name,us_per_call,derived")
    for mod in modules:
        if args.only and args.only not in mod.__name__:
            continue
        try:
            if "quick" in inspect.signature(mod.run).parameters:
                mod.run(report, quick=args.quick)
            else:
                mod.run(report)
        except Exception as e:  # noqa: BLE001 - a bench failure is a result
            report(f"{mod.__name__}/ERROR", 0.0, f"{type(e).__name__}: {e}")
            import traceback
            traceback.print_exc(file=sys.stderr)

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(rows, f, indent=1)

    failed = [r["name"] for r in rows if r["name"].endswith("/ERROR")]
    if failed:
        print(f"FAILED: {len(failed)} benchmark module(s) errored: "
              f"{', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
