"""E1 — scheduler comparison (the paper's central claim).

FCFS (Swift/T baseline) vs locality-aware vs proactive, across the canonical
workflow shapes and cluster sizes up to 4096 nodes. Reports bytes moved,
locality hit rate, total I/O wait, makespan — plus the scheduler's own
decision throughput (the scalability requirement for 1000+-node clusters).
"""

from __future__ import annotations

import time

from repro.core import (FCFSScheduler, HPC_CLUSTER, LocalityScheduler,
                        ProactiveScheduler, compile_workflow, simulate)
from repro.core.workloads import (fig2_workflow, mapreduce_workflow,
                                  montage_workflow, random_layered_workflow)

SCHEDULERS = [("fcfs", FCFSScheduler), ("locality", LocalityScheduler),
              ("proactive", ProactiveScheduler)]

WORKFLOWS = [
    ("fig2", lambda: fig2_workflow(flops_per_byte=20_000)),
    ("mapreduce64", lambda: mapreduce_workflow(64, 8)),
    ("montage32", lambda: montage_workflow(32)),
    ("random8x16", lambda: random_layered_workflow(8, 16, seed=3)),
]


QUICK_WORKFLOWS = [
    ("fig2", lambda: fig2_workflow(flops_per_byte=20_000)),
    ("mapreduce16", lambda: mapreduce_workflow(16, 4)),
]


def run(report, quick: bool = False) -> None:
    for wname, builder in (QUICK_WORKFLOWS if quick else WORKFLOWS):
        wf = compile_workflow(builder(), HPC_CLUSTER)
        base = None
        for sname, factory in SCHEDULERS:
            t0 = time.perf_counter()
            r = simulate(wf, factory, n_nodes=16, hw=HPC_CLUSTER)
            dt = time.perf_counter() - t0
            if sname == "fcfs":
                base = r
            report(f"sched/{wname}/{sname}", dt * 1e6 / max(len(wf.graph.tasks), 1),
                   f"makespan={r.makespan:.1f}s moved={r.bytes_moved/2**30:.2f}GiB "
                   f"hit={r.locality_hit_rate:.1%} io_wait={r.io_wait_total:.1f}s "
                   f"vs_fcfs_moved={r.bytes_moved/max(base.bytes_moved,1):.2f}x")

    # scale sweep: decision cost per task at 256..4096 nodes. Runs at full
    # scale even under --quick: the indexed decision path makes 4096 nodes a
    # seconds-scale case, and CI's trend gate watches exactly these rows.
    for nodes in (256, 1024, 4096):
        wf = compile_workflow(mapreduce_workflow(min(nodes, 512), 32),
                              HPC_CLUSTER)
        t0 = time.perf_counter()
        r = simulate(wf, ProactiveScheduler, n_nodes=nodes, hw=HPC_CLUSTER)
        dt = time.perf_counter() - t0
        report(f"sched/scale/{nodes}nodes",
               dt * 1e6 / max(len(wf.graph.tasks), 1),
               f"tasks={len(wf.graph.tasks)} wall={dt:.2f}s "
               f"hit={r.locality_hit_rate:.1%}")
