"""E11 — elastic membership: recovery time and goodput dip across
fail-then-join and autoscale-under-load (PR 8 tentpole evaluation).

Three scenarios, all seeded and wall-clock-free:

  * **fail_then_join** — one engine node dies mid-trace and a replacement
    joins shortly after (cold params load priced by ``CostModel``). Against
    the no-failure baseline on the identical trace we derive
    ``recovery_s`` — how long after the failure the windowed p95 TTFT is
    back within 1.2x of the baseline's same window — and ``goodput_dip`` —
    the fraction of first-token completions lost over the disruption span.
    A fail-only contrast row shows what *not* re-joining costs.
  * **autoscale_spike** — the trace is sized for the full fleet but only
    half the engines are up; the other half joins mid-trace. Tail latency
    after the join must beat the same span of a no-join half-fleet control
    (the pre-join backlog still drains through the joined engines, so the
    pre-join tail itself is not the bar).
  * **workflow_cycle** — the workflow simulator runs a full
    fail -> rejoin -> fail -> growth-join membership cycle, reporting task
    reruns and the background re-replication staged toward the newcomers.

In-bench asserts (the PR 8 acceptance criteria): the cluster is back at
full size after the join; the failure actually bites (failover activity);
recovery is findable in the windowed series (two consecutive windows back
within 1.2x of the baseline's same windows); >= 85% of post-recovery
windows stay within that bar; fail+join overall p99 is no worse than
fail-only (joining beats staying degraded); overall p99 is within 1.2x of
the no-failure run at full density (looser documented smoke bar at --quick,
where the disruption spans ~40% of the trace); autoscale post-join p95
beats the no-join control over the same span. ``check_trend`` gates
``recovery_s`` / ``goodput_dip`` up-bad.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import HPC_CLUSTER, ProactiveScheduler, compile_workflow
from repro.core.locstore import StorageHierarchy, TierSpec
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import mapreduce_workflow
from repro.serve.traffic import (CostModel, MiB, TraceConfig, TraceDriver,
                                 build_trace_stack, generate_trace,
                                 trace_stats)

RECOVERY_FACTOR = 1.2           # the acceptance bar: within 1.2x baseline


def _drive(trace, *, n_engines, max_batch, failures=(), joins=()):
    router, store = build_trace_stack(
        n_engines=n_engines, max_batch=max_batch, kv_bytes=64 * MiB,
        tiered=True, bb_slots_per_node=96, durability="flush_before_ack")
    t0 = time.perf_counter()
    driver = TraceDriver(router, trace, warm=True, failures=failures,
                         joins=joins)
    rep = driver.run()
    return rep, time.perf_counter() - t0, router, driver


def _window_p95(samples, t_lo, t_hi):
    """p95 TTFT (seconds) over samples issued in [t_lo, t_hi); None when
    the window is too thin to call."""
    vals = [lat for t, lat in samples if t_lo <= t < t_hi]
    if len(vals) < 5:
        return None
    return float(np.percentile(vals, 95))


def _recovery_seconds(base_samples, fj_samples, *, t_fail, t_join,
                      win: float, horizon: float) -> float | None:
    """First point at/after the join where TWO consecutive windows have
    their p95 TTFT back within ``RECOVERY_FACTOR`` of the *same* baseline
    windows (identical trace, so windows align arrival-for-arrival; the
    persistence requirement keeps a single lull between backlog waves from
    counting as recovered). Returns seconds since the failure, or None when
    the series never recovers inside ``horizon``."""
    t = t_join
    while t < t_fail + horizon:
        ok = 0
        for k in range(2):
            b = _window_p95(base_samples, t + k * win, t + (k + 1) * win)
            f = _window_p95(fj_samples, t + k * win, t + (k + 1) * win)
            if b is not None and f is not None and f <= RECOVERY_FACTOR * b:
                ok += 1
        if ok == 2:
            return (t + 2 * win) - t_fail
        t += win
    return None


def _recovered_window_share(base_samples, fj_samples, *, t_lo, t_hi,
                            win: float) -> tuple[int, int]:
    """(windows within RECOVERY_FACTOR of the same baseline window, total
    comparable windows) over [t_lo, t_hi) — the steady-state restoration
    measure; the residual-backlog-meets-burst windows show up here."""
    good = total = 0
    t = t_lo
    while t < t_hi:
        b = _window_p95(base_samples, t, t + win)
        f = _window_p95(fj_samples, t, t + win)
        if b is not None and f is not None:
            total += 1
            if f <= RECOVERY_FACTOR * b:
                good += 1
        t += win
    return good, total


def _goodput_dip(base_samples, fj_samples, t_lo, t_hi) -> float:
    """Fraction of first-token completions the disruption cost over
    [t_lo, t_hi): 1 - served/expected, floored at 0 (completion time
    approximated by issue + TTFT)."""
    def served(samples):
        return sum(1 for t, lat in samples if t_lo <= t + lat < t_hi)
    expect = served(base_samples)
    if expect == 0:
        return 0.0
    return max(0.0, 1.0 - served(fj_samples) / expect)


def _ttft_row(s: dict, extra: str = "") -> str:
    d = (f"requests={s['requests']} p50_ttft={s['p50_ttft_ms']:.2f} "
         f"p95_ttft={s['p95_ttft_ms']:.2f} p99_ttft={s['p99_ttft_ms']:.2f} "
         f"engine_full_errors={s['engine_full_errors']} "
         f"resumes={s['resumes']} migrations={s['migrations']}")
    return f"{d} {extra}".strip()


def run(report, quick: bool = False) -> None:
    if quick:
        n_sessions, followups, rate = 2_500, 1.2, 65.0
        n_engines, max_batch, win = 4, 8, 4.0
        maps, reducers = 12, 6
    else:
        n_sessions, followups, rate = 100_000, 1.5, 160.0
        n_engines, max_batch, win = 8, 16, 10.0
        maps, reducers = 48, 24
    cost = CostModel()

    # ---------------------------------------------------- fail-then-join
    cfg = TraceConfig(n_sessions=n_sessions, followups_per_session=followups,
                      req_rate=rate, arrival="bursty", seed=7)
    trace = generate_trace(cfg)
    st = trace_stats(trace)
    report("membership/trace", 0.0,
           f"requests={st['requests']} sessions={st['sessions']} "
           f"duration_s={st['duration']:.1f}")

    t_fail = trace[len(trace) // 2].t
    t_join = t_fail + 5.0
    base, t_b, _, base_drv = _drive(trace, n_engines=n_engines,
                                    max_batch=max_batch)
    fo, t_fo, fo_router, fo_drv = _drive(trace, n_engines=n_engines,
                                         max_batch=max_batch,
                                         failures=((t_fail, 0),))
    fj, t_fj, fj_router, fj_drv = _drive(trace, n_engines=n_engines,
                                         max_batch=max_batch,
                                         failures=((t_fail, 0),),
                                         joins=((t_join, 0),))
    sb, so, sj = base.summary(), fo.summary(), fj.summary()

    # -- the acceptance criteria, enforced in-bench -----------------------
    assert len(fj_router.engines) == n_engines, \
        "fail-then-join must end back at full fleet size"
    assert len(fo_router.engines) == n_engines - 1
    assert (sj["failover_resumed"] + sj["failover_deferred"]
            + sj["failover_lost"]) > 0, "the failure never bit"
    assert sj["joins"] == 1 and sj["engine_full_errors"] == 0

    horizon = st["duration"] - t_fail
    rec = _recovery_seconds(base_drv.samples, fj_drv.samples,
                            t_fail=t_fail, t_join=t_join, win=win,
                            horizon=horizon)
    assert rec is not None, (
        f"windowed p95 TTFT never returned within {RECOVERY_FACTOR}x of "
        f"baseline after the join — recovery not achieved in {horizon:.0f}s")
    # steady-state restoration: from the settle point on, nearly every
    # window must track the no-failure run. Not "every" — the disruption's
    # deferred completions land later (conservation of work) and a couple
    # of windows where that residual backlog meets a trace burst legitimately
    # exceed the bar, so we assert the share.
    settle = t_fail + rec
    good, total = _recovered_window_share(
        base_drv.samples, fj_drv.samples, t_lo=settle, t_hi=st["duration"],
        win=win)
    # at full density the post-settle series tracks baseline almost
    # window-for-window (measured 0.99); at --quick the disruption spans
    # ~40% of the short trace, so its deferred completions collide with the
    # trace's final burst and a real minority of windows exceed the bar —
    # gate the smoke run at the measured-honest 0.55
    share_bar = 0.55 if quick else 0.85
    assert total > 0 and good / total >= share_bar, (
        f"only {good}/{total} post-recovery windows within "
        f"{RECOVERY_FACTOR}x of baseline — steady state not restored "
        f"(bar {share_bar})")
    # joining must beat staying degraded: the whole point of the join is
    # that the overall tail ends up no worse than the (n-1)-engine run
    # (small slack: the two runs shed different sessions at the failure)
    assert sj["p99_ttft_ms"] <= 1.05 * so["p99_ttft_ms"], (
        f"fail+join p99 {sj['p99_ttft_ms']:.1f}ms worse than fail-only "
        f"{so['p99_ttft_ms']:.1f}ms — the join hurt")
    # overall-p99 acceptance: at full density the disruption is a small
    # fraction of the run and the overall p99 must sit within the 1.2x bar
    # (measured 1.19x). At --quick smoke scale the failure span is ~40% of
    # the whole trace, so the backlog cascade dominates the overall tail;
    # gate at a looser documented smoke bar there (measured 1.56x).
    p99_bar = 2.0 if quick else RECOVERY_FACTOR
    assert sj["p99_ttft_ms"] <= p99_bar * sb["p99_ttft_ms"], (
        f"fail+join overall p99 {sj['p99_ttft_ms']:.1f}ms vs no-failure "
        f"{sb['p99_ttft_ms']:.1f}ms — outside the {p99_bar}x bar")
    dip = _goodput_dip(base_drv.samples, fj_drv.samples, t_fail, settle)
    # contrast: the same disruption span without the join (dip_nojoin is
    # deliberately NOT named goodput_dip — it is context, not a gated SLO)
    dip_nojoin = _goodput_dip(base_drv.samples, fo_drv.samples,
                              t_fail, settle)

    report("membership/baseline", t_b * 1e6, _ttft_row(sb))
    report("membership/fail_only", t_fo * 1e6, _ttft_row(
        so, f"dip_nojoin={dip_nojoin:.4f} "
            f"failover_resumed={so['failover_resumed']} "
            f"failover_deferred={so['failover_deferred']} "
            f"failover_lost={so['failover_lost']}"))
    report("membership/fail_join", t_fj * 1e6, _ttft_row(
        sj, f"recovery_s={rec:.1f} goodput_dip={dip:.4f} "
            f"settled_win_share={good / total:.3f} "
            f"failover_resumed={sj['failover_resumed']} "
            f"failover_deferred={sj['failover_deferred']} "
            f"failover_lost={sj['failover_lost']} "
            f"adopted_on_join={sj['adopted_on_join']} "
            f"rebalanced={sj['rebalanced']} "
            f"params_load_s={cost.join_params_load_s:.0f}"))

    # ------------------------------------------------- autoscale on spike
    # half the fleet serves a trace sized for all of it; the other half
    # joins mid-trace. The overloaded pre-join backlog still has to drain
    # through the joined engines (conservation of work), so the claim is
    # NOT "post beats pre" — it is "joining beats not joining": the same
    # span of a no-join half-fleet control, which keeps accumulating queue.
    half = n_engines // 2
    spike_joins = tuple((t_fail, n) for n in range(half, n_engines))
    asc, t_asc, asc_router, asc_drv = _drive(
        trace, n_engines=half, max_batch=max_batch, joins=spike_joins)
    ctrl, t_ctrl, _, ctrl_drv = _drive(trace, n_engines=half,
                                       max_batch=max_batch)
    sa, sc = asc.summary(), ctrl.summary()
    assert len(asc_router.engines) == n_engines, \
        "autoscale must end at the full fleet"
    assert sa["joins"] == n_engines - half
    assert any(asc_router.engines[n].prefills > 0
               for n in range(half, n_engines)), \
        "the joined engines never absorbed load"
    t_post = t_fail + cost.join_params_load_s + win
    post = [lat for t, lat in asc_drv.samples if t >= t_post]
    post_ctrl = [lat for t, lat in ctrl_drv.samples if t >= t_post]
    post_p95 = float(np.percentile(post, 95))
    ctrl_p95 = float(np.percentile(post_ctrl, 95))
    assert post_p95 < ctrl_p95, (
        f"post-join p95 TTFT {post_p95 * 1e3:.1f}ms did not beat the "
        f"no-join control's same span {ctrl_p95 * 1e3:.1f}ms")
    report("membership/autoscale_spike", t_asc * 1e6, _ttft_row(
        sa, f"engines_start={half} engines_end={len(asc_router.engines)} "
            f"post_join_p95_ms={post_p95 * 1e3:.2f} "
            f"nojoin_ctrl_p95_ms={ctrl_p95 * 1e3:.2f}"))

    # -------------------------------------- workflow membership cycle (sim)
    g = mapreduce_workflow(maps, reducers, 2e9, flops_per_byte=4.0)
    wf = compile_workflow(g, HPC_CLUSTER)
    hier = StorageHierarchy(
        [TierSpec("hbm", 6e9, 800e9), TierSpec("bb", 12e9, 10e9)],
        remote=TierSpec("remote", float("inf"), 0.5e9))
    t0 = time.perf_counter()
    res = WorkflowSimulator(
        wf, ProactiveScheduler(wf, risk_aware=True), n_nodes=8,
        hw=HPC_CLUSTER, failures=[(4.0, 1)], joins=[(8.0, 1), (16.0, 9)],
        hierarchy=hier, write_policy="back",
        durability="fsync_on_barrier").run()
    t_wf = time.perf_counter() - t0
    assert res.joins == 2 and res.rereplications > 0, \
        "the membership cycle must stage re-replication toward newcomers"
    report("membership/workflow_cycle", t_wf * 1e6,
           f"makespan_s={res.makespan:.2f} reruns={res.reruns} "
           f"joins={res.joins} rereplications={res.rereplications} "
           f"bytes_rereplicated_gib={res.bytes_rereplicated / 2**30:.3f}")

    os.makedirs("results", exist_ok=True)
    with open("results/membership_summary.json", "w") as f:
        json.dump({"trace": st, "baseline": sb, "fail_only": so,
                   "fail_join": sj, "autoscale": sa,
                   "recovery_s": rec, "goodput_dip": dip,
                   "workflow_cycle": {
                       "makespan_s": res.makespan, "reruns": res.reruns,
                       "rereplications": res.rereplications,
                       "bytes_rereplicated": res.bytes_rereplicated}},
                  f, indent=1)
