"""E12 — topology awareness earns its keep (ISSUE 10 tentpole).

The cluster model is a node -> ToR -> spine link graph: cross-rack
transfers are charged the max-utilized link on their path and contend in
per-link simulator lanes. Two sweeps measure what *seeing* that graph buys:

  (a) **spine oversubscription** (headline): the mapreduce shuffle (scattered
      external splits, tight tiers) on a 2-rack fabric at 1:1 / 4:1 / 8:1
      uplink oversubscription. *aware* = scheduler + store consume the
      topology (rack-spread placement, rack-local replica reads, link-queue
      charging in the placement cost); *blind* = they plan with the flat
      model while the network charges real paths (``topology_aware=False``).

  (b) **mixed generations**: one rack of current nodes, one rack of old-gen
      nodes (0.6x compute, half-speed NICs) behind a 4:1 spine — the
      heterogeneity the per-node profiles exist for.

In-bench assertions (the ISSUE 10 acceptance criteria):
  * on every oversubscribed fabric (4:1, 8:1, mixed) the aware run moves
    strictly fewer bytes across the spine AND finishes strictly sooner
    than the blind run;
  * on the non-blocking 1:1 fabric awareness costs nothing (aware is never
    worse than blind);
  * the oversubscribed-link lint rule flags the 8:1 stage-in plan and
    stays quiet on the 1:1 fabric.
"""

from __future__ import annotations

from repro.analysis.lint import lint
from repro.core import (ClusterTopology, HPC_CLUSTER, LocalityScheduler,
                        NodeProfile, SimConfig, compile_workflow)
from repro.core.locstore import GiB, StorageHierarchy, TierSpec
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import mapreduce_workflow

OVERSUBS = (1.0, 4.0, 8.0)

TIGHT = StorageHierarchy(
    [TierSpec("hbm", 6e9, 800e9), TierSpec("bb", 12e9, 10e9)],
    remote=TierSpec("remote", float("inf"), 0.5e9))


def _simulate(wf, topo, aware):
    sim = WorkflowSimulator(wf, LocalityScheduler(wf, speed_aware=True),
                            n_nodes=topo.n_nodes, hw=HPC_CLUSTER,
                            topology=topo, topology_aware=aware,
                            external_loc="scattered", hierarchy=TIGHT)
    return sim.run()


def _pair(report, wf, topo, row):
    out = {}
    for mode, aware in (("aware", True), ("blind", False)):
        r = _simulate(wf, topo, aware)
        out[mode] = r
        assert r.tasks_done == len(wf.graph.tasks)
        report(f"{row}/{mode}", 0.0,
               f"topo_makespan_s={r.makespan:.2f} "
               f"cross_spine_gib={r.cross_spine_bytes / GiB:.2f} "
               f"moved_gib={r.bytes_moved / GiB:.2f} "
               f"local_gib={r.bytes_local / GiB:.2f}")
    return out["aware"], out["blind"]


def run(report, quick: bool = False) -> None:
    wf = compile_workflow(mapreduce_workflow(12, 6, 2e9, flops_per_byte=4.0),
                          HPC_CLUSTER)

    # ------------------------------- (a) spine oversubscription sweep
    oversubs = (1.0, 4.0) if quick else OVERSUBS
    for o in oversubs:
        topo = ClusterTopology.two_tier(2, 4, oversubscription=o)
        aware, blind = _pair(report, wf, topo, f"topology/spine/o{o:g}")
        if o > 1.0:
            # the acceptance criterion: awareness must strictly cut both
            # the spine traffic and the makespan once the uplink blocks
            assert aware.cross_spine_bytes < blind.cross_spine_bytes, (
                f"aware moved no fewer cross-spine bytes at {o:g}:1: "
                f"{aware.cross_spine_bytes:g} !< {blind.cross_spine_bytes:g}")
            assert aware.makespan < blind.makespan, (
                f"aware did not beat blind makespan at {o:g}:1: "
                f"{aware.makespan:g} !< {blind.makespan:g}")
            report(f"topology/spine/o{o:g}/saved", 0.0,
                   f"cross_spine_saved_gib="
                   f"{(blind.cross_spine_bytes - aware.cross_spine_bytes) / GiB:.2f} "
                   f"makespan_saved_s={blind.makespan - aware.makespan:.2f}")
        else:
            # a non-blocking fabric: awareness must cost nothing
            assert aware.cross_spine_bytes <= blind.cross_spine_bytes
            assert aware.makespan <= blind.makespan

    # ------------------------------------ (b) mixed-generation fabric
    profiles = [NodeProfile() if i < 4 else
                NodeProfile(speed=0.6, cls="old-gen", nic_gbps=0.625e9)
                for i in range(8)]
    topo = ClusterTopology.two_tier(2, 4, oversubscription=4.0,
                                    profiles=profiles)
    aware, blind = _pair(report, wf, topo, "topology/mixed_gen")
    assert aware.cross_spine_bytes < blind.cross_spine_bytes
    assert aware.makespan < blind.makespan
    report("topology/mixed_gen/saved", 0.0,
           f"cross_spine_saved_gib="
           f"{(blind.cross_spine_bytes - aware.cross_spine_bytes) / GiB:.2f} "
           f"makespan_saved_s={blind.makespan - aware.makespan:.2f}")

    # ---------------------------- (c) the lint rule sees it coming too
    # default-intensity compute: the critical path is long enough that a
    # sane fabric CAN stage the externals in time (the sweeps above use
    # flops_per_byte=4.0 to be communication-bound on purpose)
    wf_lint = compile_workflow(mapreduce_workflow(12, 6, 2e9), HPC_CLUSTER)

    def findings(o, pfs):
        cfg = SimConfig.from_kwargs(
            n_nodes=8, hw=HPC_CLUSTER, external_loc="remote",
            topology=ClusterTopology.two_tier(2, 4, oversubscription=o,
                                              pfs_gbps=pfs))
        return [f for f in lint(wf_lint, config=cfg)
                if f.rule == "oversubscribed-link"]
    flagged = findings(8.0, 1e7)
    assert flagged, "8:1 stage-in plan must trip oversubscribed-link"
    assert not findings(1.0, 4e9), \
        "a non-blocking fabric must not trip oversubscribed-link"
    report("topology/lint/oversubscribed", 0.0,
           f"findings={len(flagged)} clean_on_flat=1")
