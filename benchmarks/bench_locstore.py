"""E4 — location service + store microbenchmarks (placement control, lookup
scaling, shard balance)."""

from __future__ import annotations

import time

from repro.core.locstore import LocStore, LocationService, Placement, SimObject


def run(report, quick: bool = False) -> None:
    n = 2_000 if quick else 20_000
    # put with explicit placement (S_LOC path)
    st = LocStore(1024, n_meta_shards=32)
    t0 = time.perf_counter()
    for i in range(n):
        st.put(f"f{i}", SimObject(1024.0), loc=i % 1024)
    dt = time.perf_counter() - t0
    report("locstore/put_pinned", dt * 1e6 / n, f"{n/dt:,.0f} puts/s")

    # location lookups
    t0 = time.perf_counter()
    for i in range(n):
        st.loc.lookup(f"f{i}")
    dt = time.perf_counter() - t0
    report("locstore/lookup", dt * 1e6 / n, f"{n/dt:,.0f} lookups/s")

    # locality-accounted reads (50% local)
    t0 = time.perf_counter()
    for i in range(n):
        st.get(f"f{i}", at=(i % 1024) if i % 2 == 0 else (i + 7) % 1024)
    dt = time.perf_counter() - t0
    rep = st.movement_report()
    report("locstore/get_accounted", dt * 1e6 / n,
           f"hit={rep['locality_hit_rate']:.1%}")

    # migration (runtime feedback channel)
    t0 = time.perf_counter()
    for i in range(0, n, 10):
        st.migrate(f"f{i}", (i + 1) % 1024)
    dt = time.perf_counter() - t0
    report("locstore/migrate", dt * 1e6 / (n / 10), "")

    # metadata shard balance at scale
    svc = LocationService(64)
    for i in range(10_000 if quick else 100_000):
        svc.record(f"obj{i}", Placement((i % 512,)))
    bal = svc.load_balance()
    skew = bal["max_shard"] / (bal["entries"] / bal["shards"])
    report("locstore/shard_balance", 0.0,
           f"entries={bal['entries']} shards={bal['shards']} "
           f"max/mean={skew:.2f}")
