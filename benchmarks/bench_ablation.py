"""E3 — cross-layer ablation: every layer of the paper's stack must earn its
keep. Configurations, cumulative:

  base      FCFS + default (hash) placement — Swift/T + vanilla Hercules
  +loc      locality-aware scheduler reading the location service
  +hints    compiler hints (sizes/costs) sharpen priorities & movement costs
  +proactive pre-scheduling + pipelining (the full paper stack)

"-hints" is modeled by compiling the DAG with default hints (every dataset
falls back to the 1 MiB default size, every task to unit cost) while the
SIMULATED world still uses the true sizes — i.e. the scheduler plans with
bad information, exactly what the paper argues happens without compiler help.
"""

from __future__ import annotations

import copy

from repro.core import (FCFSScheduler, HPC_CLUSTER, LocalityScheduler,
                        ProactiveScheduler, compile_workflow)
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import random_layered_workflow
from repro.core.hints import TaskHints


def _strip_hints(g):
    g2 = copy.deepcopy(g)
    for t in g2.tasks.values():
        t.hints = TaskHints()            # unit costs, ratio 1.0
        t.est_flops = t.est_seconds = None
    for d in g2.data.values():
        if d.is_external:
            d.size_bytes = None          # lose @size too
    return g2


def run(report, quick: bool = False) -> None:
    g = (random_layered_workflow(4, 8, seed=11) if quick
         else random_layered_workflow(8, 16, seed=11))
    wf_true = compile_workflow(g, HPC_CLUSTER)
    wf_blind = compile_workflow(_strip_hints(g), HPC_CLUSTER)
    # the blind plan must still run against TRUE sizes/costs:
    wf_plan = copy.copy(wf_blind)
    wf_plan.sizes = wf_true.sizes
    wf_plan.est_seconds = wf_true.est_seconds      # world truth for the sim

    def sim(wf_for_sched, sched_factory):
        # scheduler sees wf_for_sched (its beliefs); simulator charges truth
        sim = WorkflowSimulator(wf_true, sched_factory(wf_for_sched),
                                n_nodes=16, hw=HPC_CLUSTER)
        return sim.run()

    rows = [
        ("base(fcfs+hash)", sim(wf_true, FCFSScheduler)),
        ("+loc(no hints)", sim(wf_blind, LocalityScheduler)),
        ("+hints", sim(wf_true, LocalityScheduler)),
        ("+proactive(full)", sim(wf_true, ProactiveScheduler)),
    ]
    base = rows[0][1]
    for name, r in rows:
        report(f"ablation/{name}", 0.0,
               f"makespan={r.makespan:.1f}s moved={r.bytes_moved/2**30:.2f}GiB "
               f"hit={r.locality_hit_rate:.1%} io_wait={r.io_wait_total:.1f}s "
               f"moved_vs_base={r.bytes_moved/max(base.bytes_moved,1):.2f}x")
