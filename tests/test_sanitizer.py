"""Runtime invariant sanitizer (PR 9 tentpole, part b).

Three layers of proof: sanitized runs are clean AND bit-identical to
unsanitized runs (the sanitizer is a pure observer); each check catches a
deliberately injected desync, naming the first divergent entry; and the
simulator's checkpoint loop surfaces a mid-run drift as a structured
:class:`SanitizerError` instead of a silently wrong schedule.
"""

import dataclasses

import pytest

from repro.analysis import sanitize
from repro.analysis.sanitize import SanitizerError, env_enabled
from repro.core import (HPC_CLUSTER, LocalityScheduler, ProactiveScheduler,
                        SimConfig, StorageHierarchy, TierSpec,
                        WorkflowSimulator, compile_workflow)
from repro.core.workloads import mapreduce_workflow, pipeline_chain_workflow

TIGHT = StorageHierarchy(
    [TierSpec("hbm", 6e9, 800e9), TierSpec("bb", 12e9, 10e9)],
    remote=TierSpec("remote", float("inf"), 0.5e9))


def cfg(**kw) -> SimConfig:
    base = dict(n_nodes=4, hw=HPC_CLUSTER, hierarchy=TIGHT,
                write_policy="back", coordinated_eviction=True)
    base.update(kw)
    return SimConfig.from_kwargs(**base)


def run_sim(config, sched_cls=ProactiveScheduler, wf=None):
    wf = wf or compile_workflow(mapreduce_workflow(8, 4), HPC_CLUSTER)
    sim = WorkflowSimulator(wf, sched_cls(wf), config=config)
    return sim, sim.run()


class TestObserverOnly:
    def test_sanitized_run_is_clean_and_identical(self):
        _, r_off = run_sim(cfg(sanitize=False))
        _, r_on = run_sim(cfg(sanitize=True, sanitize_every=1))
        assert r_on == r_off

    def test_sanitized_failure_run_is_clean(self):
        c = cfg(sanitize=True, sanitize_every=1, failures=((4.0, 1),),
                durability="fsync_on_barrier")
        _, r = run_sim(c)
        assert r.tasks_done > 0

    def test_env_var_opt_in(self, monkeypatch):
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        assert env_enabled()
        sim, _ = run_sim(cfg())          # sanitize=None -> env
        assert sim.sanitize
        for off in ("", "0", "false", "off", "no"):
            monkeypatch.setenv("REPRO_SANITIZE", off)
            assert not env_enabled()
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        sim2, _ = run_sim(cfg(sanitize=False))   # explicit beats env
        assert not sim2.sanitize

    def test_error_is_structured(self):
        err = SanitizerError("ledger", "bytes_moved", 1.0, 2.0)
        assert err.check == "ledger" and err.key == "bytes_moved"
        assert err.expected == 1.0 and err.actual == 2.0
        assert "divergent entry 'bytes_moved'" in str(err)
        assert isinstance(err, AssertionError)


class TestInjectedDesyncs:
    """Each incremental structure, corrupted after a real run, is caught by
    its check — and the error names the entry that drifted."""

    @pytest.fixture(scope="class")
    def ran(self):
        sim, _ = run_sim(cfg())
        return sim

    def test_membership_desync(self, ran):
        store = ran.store
        store._failed_nodes.add(2)      # node never actually failed
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_membership(store, ran.cluster)
        finally:
            store._failed_nodes.discard(2)
        assert ei.value.check == "membership"

    def test_tier_usage_desync(self, ran):
        store = ran.store
        key = next(iter(store._usage), (0, "hbm"))
        store._usage[key] = store._usage.get(key, 0.0) + 123456.0
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_tier_usage(store)
        finally:
            store._usage[key] -= 123456.0
        assert ei.value.check == "tier-usage" and ei.value.key == key

    def test_ledger_desync(self, ran):
        store = ran.store
        store.bytes_moved += 1e9
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_ledger(store)
        finally:
            store.bytes_moved -= 1e9
        assert ei.value.check == "ledger" and ei.value.key == "bytes_moved"

    def test_pin_leak_desync(self, ran):
        store = ran.store
        name = next(iter(store._sizes))
        store._pins[(name, 0)] = store._pins.get((name, 0), 0) + 1
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_pin_conservation(store, {})
        finally:
            store._pins[(name, 0)] -= 1
        assert ei.value.check == "pin-conservation"
        assert ei.value.key == (name, 0)

    def test_placement_mirror_desync(self, ran):
        sched, store = ran.sched, ran.store
        sanitize.check_placement_mirror(sched, store)    # clean before
        name = next(iter(sched._placements))
        stash = sched._placements.pop(name)
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_placement_mirror(sched, store)
        finally:
            sched._placements[name] = stash
        assert ei.value.check == "placement-mirror"
        assert ei.value.key == name

    def test_term_cache_desync(self, ran):
        sched = ran.sched
        name = next((n for n in sched._term_cache if sched._term_cache[n]),
                    None)
        if name is None:
            pytest.skip("run left no cached terms")
        node = next(iter(sched._term_cache[name]))
        sched._term_cache[name][node] += 1.0
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_term_cache(sched, ran.cluster)
        finally:
            sched._term_cache[name][node] -= 1.0
        assert ei.value.check == "term-cache"
        assert ei.value.key == (name, node)

    def test_proactive_avail_desync(self, ran):
        sched = ran.sched
        tid = next(iter(sched.wf.graph.tasks))
        old = sched._avail.get(tid, 0)
        sched._avail[tid] = old + 7
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_proactive(sched, ran.cluster)
        finally:
            sched._avail[tid] = old
        assert ei.value.check == "proactive"
        assert ei.value.key == f"_avail[{tid}]"


class TestServingSanitizer:
    def test_engine_slot_desync_caught(self):
        import jax

        from repro.configs import get_smoke
        from repro.core.config import ServingConfig
        from repro.models import init_params
        from repro.serve.engine import ServingEngine

        mcfg = dataclasses.replace(get_smoke("granite-3-2b"),
                                   dtype="float32")
        params = init_params(mcfg, jax.random.PRNGKey(0))
        eng = ServingEngine(mcfg, params,
                            config=ServingConfig(max_batch=2, max_seq=64,
                                                 sanitize=True))
        sid = eng.submit([1, 2, 3])      # sanitized transitions: clean
        eng.submit([4, 5])               # keeps the next step() non-empty
        eng.step()
        eng._slotted.pop(sid)            # slot table drifts from sessions
        with pytest.raises(SanitizerError) as ei:
            eng.step()
        assert ei.value.check == "engine-slots"
        assert ei.value.key == f"session{sid}"


class TestMidRunDrift:
    def test_checkpoint_loop_catches_live_drift(self):
        """A scheduler that corrupts its own mirror mid-run: the per-event
        checkpoint must stop the simulation with the divergent dataset."""

        class DriftingScheduler(LocalityScheduler):
            def select(self, ready, cluster):
                out = super().select(ready, cluster)
                if self._placements and not getattr(self, "_hit", False):
                    self._hit = True
                    self._dropped = next(iter(self._placements))
                    del self._placements[self._dropped]
                return out

        wf = compile_workflow(pipeline_chain_workflow(2, 3), HPC_CLUSTER)
        sched = DriftingScheduler(wf)
        sim = WorkflowSimulator(wf, sched,
                                config=cfg(sanitize=True, sanitize_every=1))
        with pytest.raises(SanitizerError) as ei:
            sim.run()
        assert ei.value.check == "placement-mirror"
        assert ei.value.key == sched._dropped

    def test_unsanitized_run_tolerates_the_same_drift(self):
        """Control: without the sanitizer the drifting run completes —
        i.e. the drift above is exactly the silent-corruption class the
        sanitizer exists to catch."""

        class DriftingScheduler(LocalityScheduler):
            def select(self, ready, cluster):
                out = super().select(ready, cluster)
                if self._placements and not getattr(self, "_hit", False):
                    self._hit = True
                    del self._placements[next(iter(self._placements))]
                return out

        wf = compile_workflow(pipeline_chain_workflow(2, 3), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, DriftingScheduler(wf),
                                config=cfg(sanitize=False))
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
