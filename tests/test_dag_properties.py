"""Property-based tests (hypothesis) for the workflow compiler's analyses,
with networkx as the independent oracle."""

import networkx as nx
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compile_workflow, HPC_CLUSTER
from repro.core.dag import TaskGraph
from repro.core.workloads import random_layered_workflow


@st.composite
def layered_graphs(draw):
    layers = draw(st.integers(2, 6))
    width = draw(st.integers(2, 8))
    seed = draw(st.integers(0, 10_000))
    fan = draw(st.integers(1, 4))
    return random_layered_workflow(layers, width, seed=seed, fan_in=fan)


def to_nx(g: TaskGraph) -> nx.DiGraph:
    ng = nx.DiGraph()
    ng.add_nodes_from(g.tasks)
    for tid in g.tasks:
        for s in g.successors(tid):
            ng.add_edge(tid, s)
    return ng


@given(layered_graphs())
@settings(max_examples=25, deadline=None)
def test_topo_order_valid(g):
    order = g.topo_order()
    assert sorted(order) == sorted(g.tasks)
    pos = {t: i for i, t in enumerate(order)}
    for tid in g.tasks:
        for s in g.successors(tid):
            assert pos[tid] < pos[s]


@given(layered_graphs())
@settings(max_examples=25, deadline=None)
def test_upward_rank_matches_networkx_longest_path(g):
    """rank(t) with unit costs == longest path (in nodes) from t to a sink."""
    rank = g.upward_rank(cost=lambda t: 1.0)
    ng = to_nx(g)
    # longest path from t == 1 + max over successors
    expected = {}
    for t in reversed(list(nx.topological_sort(ng))):
        succ = [expected[s] for s in ng.successors(t)]
        expected[t] = 1.0 + (max(succ) if succ else 0.0)
    assert rank == expected


@given(layered_graphs())
@settings(max_examples=25, deadline=None)
def test_critical_path_is_consistent(g):
    path, total = g.critical_path()
    rank = g.upward_rank()
    # path starts at the max-rank task and walks monotonically down
    assert abs(rank[path[0]] - total) < 1e-9
    for a, b in zip(path, path[1:]):
        assert b in set(g.successors(a))
    # path weight equals total
    costs = [g.tasks[t].est_seconds or 1.0 for t in path]
    assert abs(sum(costs) - total) < 1e-6 * max(1.0, total)


@given(layered_graphs())
@settings(max_examples=25, deadline=None)
def test_size_propagation_conservation(g):
    """Every dataset gets a size; io_ratio math is respected per task."""
    wf = compile_workflow(g, HPC_CLUSTER)
    for name, size in wf.sizes.items():
        assert size >= 0
    for tid, t in g.tasks.items():
        in_bytes = sum(wf.sizes[n] for n in t.inputs)
        for out in t.outputs:
            d = g.data[out]
            if d.is_external:
                continue
            expected = t.hints.ratio_for(out) * (
                in_bytes / max(len(t.outputs), 1)
                if len(t.outputs) > 1 else in_bytes)
            assert abs(wf.sizes[out] - expected) <= 1e-6 * max(1.0, expected)


@given(layered_graphs())
@settings(max_examples=25, deadline=None)
def test_earliest_start_monotone_along_edges(g):
    wf = compile_workflow(g, HPC_CLUSTER)
    es = wf.earliest_start
    for tid in g.tasks:
        for s in g.successors(tid):
            assert es[s] >= es[tid] + wf.est_seconds[tid] - 1e-9


@given(layered_graphs(), st.integers(2, 32))
@settings(max_examples=15, deadline=None)
def test_simulation_invariants(g, n_nodes):
    """Makespan bounds & byte accounting hold on random DAGs/cluster sizes."""
    from repro.core import ProactiveScheduler, simulate
    wf = compile_workflow(g, HPC_CLUSTER)
    r = simulate(wf, ProactiveScheduler, n_nodes=n_nodes, hw=HPC_CLUSTER)
    assert r.tasks_done == len(g.tasks)
    # lower bound: critical path compute; no I/O can make it faster
    assert r.makespan >= wf.critical_seconds * 0.999
    assert r.bytes_local >= 0 and r.bytes_moved >= 0
    assert r.io_wait_max <= r.io_wait_total + 1e-9
