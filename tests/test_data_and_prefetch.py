"""Data pipeline (determinism + prefetch) and the PrefetchEngine data plane."""

import time

import numpy as np

from repro.configs import get_smoke
from repro.core.locstore import LocStore, SimObject
from repro.core.prefetch import PrefetchEngine
from repro.data.pipeline import (PrefetchingLoader, SyntheticCorpus,
                                 epoch_workflow)
from repro.core import compile_workflow, ProactiveScheduler, simulate, HPC_CLUSTER


class TestCorpus:
    def test_deterministic_across_instances(self):
        c1 = SyntheticCorpus(1000, seed=5)
        c2 = SyntheticCorpus(1000, seed=5)
        np.testing.assert_array_equal(c1.shard(3), c2.shard(3))

    def test_restart_resumes_exact_batches(self):
        c = SyntheticCorpus(1000, seed=1)
        full = [b for _, b in zip(range(8), c.batches(2, 16))]
        resumed = [b for _, b in zip(range(3), c.batches(2, 16, start_step=5))]
        for a, b in zip(full[5:], resumed):
            np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_next_tokens(self):
        c = SyntheticCorpus(1000)
        b = next(c.batches(2, 16))
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestPrefetchingLoader:
    def test_yields_all_and_counts_waits(self):
        def slow_gen():
            for i in range(5):
                yield {"x": np.full((2,), i)}

        loader = PrefetchingLoader(slow_gen(), depth=2)
        got = [np.asarray(b["x"])[0] for b in loader]
        assert got == [0, 1, 2, 3, 4]

    def test_prefetch_hides_producer_latency(self):
        def gen(delay):
            for i in range(6):
                time.sleep(delay)
                yield {"x": np.zeros(1)}

        t0 = time.perf_counter()
        loader = PrefetchingLoader(gen(0.05), depth=3)
        for _ in loader:
            time.sleep(0.05)      # consumer work overlaps producer
        overlapped = time.perf_counter() - t0
        assert overlapped < 2 * 6 * 0.05 + 0.2   # far below serial 0.6s


class TestPrefetchEngine:
    def test_stage_creates_replica(self):
        store = LocStore(4)
        store.put("d", SimObject(100), loc=0)
        eng = PrefetchEngine(store)
        eng.submit("d", 3)
        eng.drain()
        assert store.stat("d").resident_on(3)
        _, t = store.get("d", at=3)
        assert t.local

    def test_idempotent_submit(self):
        store = LocStore(4)
        store.put("d", SimObject(10), loc=0)
        eng = PrefetchEngine(store)
        f1 = eng.submit("d", 2)
        f2 = eng.submit("d", 2)
        assert f1 is f2
        eng.drain()
        assert eng.submitted == 1

    def test_wait_returns_false_without_submit(self):
        store = LocStore(2)
        store.put("d", SimObject(1), loc=0)
        assert PrefetchEngine(store).wait("d", 1) is False


def test_epoch_workflow_schedules_with_locality():
    """The training-epoch DAG built from a real config runs in the simulator
    and the proactive scheduler pipelines batches (paper's claim, applied to
    the framework's own input pipeline)."""
    cfg = get_smoke("granite-3-2b")
    g = epoch_workflow(cfg, n_steps=6, n_dp=4, batch=8, seq=64,
                       step_flops=5e11)
    wf = compile_workflow(g, HPC_CLUSTER)
    r = simulate(wf, ProactiveScheduler, n_nodes=4, hw=HPC_CLUSTER)
    assert r.tasks_done == len(g.tasks)
    assert r.bytes_prefetched > 0          # batches were pipelined
