"""The REAL executor: runs TaskGraphs with Python/JAX bodies on worker
threads, with the same schedulers as the simulator."""

import numpy as np

from repro.core import (LocalityScheduler, ProactiveScheduler, TaskGraph,
                        WorkflowExecutor, compile_workflow, size_hint, task)


def pipeline_graph():
    g = TaskGraph()
    g.add_data("x", size_bytes=size_hint(4 * 400))
    g.add_task("square", inputs=("x",), outputs=("x2",),
               fn=lambda x: {"x2": x * x}, hints=task(io_ratio=1.0))
    g.add_task("sum", inputs=("x2",), outputs=("total",),
               fn=lambda x2: {"total": float(np.sum(x2))},
               hints=task(io_ratio=0.01))
    return g


def test_executor_computes_correct_result():
    g = pipeline_graph()
    wf = compile_workflow(g)
    ex = WorkflowExecutor(wf, LocalityScheduler(wf), n_nodes=2,
                          inject_inputs={"x": np.arange(400, dtype=np.float32)})
    res = ex.run()
    expected = float(np.sum(np.arange(400, dtype=np.float32) ** 2))
    assert res.outputs["total"] == expected
    assert res.wall_seconds > 0


def test_executor_parallel_fanout():
    g = TaskGraph()
    g.add_data("seed", size_bytes=size_hint(8))
    for i in range(6):
        g.add_task(f"work{i}", inputs=("seed",), outputs=(f"out{i}",),
                   fn=lambda seed, i=i: {f"out{i}": seed + i})
    g.add_task("gather", inputs=tuple(f"out{i}" for i in range(6)),
               outputs=("final",),
               fn=lambda **kw: {"final": sum(kw.values())})
    wf = compile_workflow(g)
    ex = WorkflowExecutor(wf, ProactiveScheduler(wf), n_nodes=3,
                          inject_inputs={"seed": 10})
    res = ex.run()
    assert res.outputs["final"] == sum(10 + i for i in range(6))
    assert len(res.task_records) == 7


def test_executor_feeds_back_placement_to_store():
    """Outputs land where the producer ran (paper's feedback loop #3)."""
    g = pipeline_graph()
    wf = compile_workflow(g)
    ex = WorkflowExecutor(wf, LocalityScheduler(wf), n_nodes=2,
                          inject_inputs={"x": np.ones(400, np.float32)})
    res = ex.run()
    node = ex.store.stat("x2").real_loc
    rec = res.task_records["square"]
    assert node == rec["node"]


def test_executor_jax_bodies():
    import jax.numpy as jnp
    g = TaskGraph()
    g.add_data("a", size_bytes=size_hint(1024))
    g.add_task("mm", inputs=("a",), outputs=("b",),
               fn=lambda a: {"b": jnp.asarray(a) @ jnp.asarray(a).T})
    wf = compile_workflow(g)
    ex = WorkflowExecutor(wf, LocalityScheduler(wf), n_nodes=2,
                          inject_inputs={"a": np.eye(16, dtype=np.float32)})
    res = ex.run()
    assert np.allclose(np.asarray(res.outputs["b"]), np.eye(16))
