"""int8 error-feedback gradient compression: exactness bounds + convergence."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.dist.compression import (compressed_psum, compression_ratio,
                                    dequantize_int8, quantize_int8, wrap_grads)


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-7     # half-ULP of the int8 grid


def test_compression_ratio_near_4x():
    t = {"w": jnp.zeros((1024, 1024))}
    assert 3.9 < compression_ratio(t) <= 4.0


_MESH = Mesh(np.array(jax.devices()[:1]), ("d",))
_PSUM = jax.jit(jax.shard_map(
    lambda a, e: compressed_psum(a, "d", e),
    mesh=_MESH, in_specs=jax.sharding.PartitionSpec(),
    out_specs=jax.sharding.PartitionSpec()))


def _psum_1dev(x, err):
    """Run compressed_psum under a 1-device shard_map (API-level check)."""
    return _PSUM(x, err)


def test_compressed_psum_single_device_identity_up_to_quantization():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    mean, err = _psum_1dev(x, jnp.zeros_like(x))
    # value+err must reconstruct x exactly (error feedback invariant)
    np.testing.assert_allclose(np.asarray(mean + err), np.asarray(x),
                               rtol=1e-6, atol=1e-6)


def test_error_feedback_unbiased_over_steps():
    """Accumulated compressed means -> true mean (EF eliminates bias)."""
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    err = jnp.zeros_like(g)
    acc = np.zeros(128, np.float32)
    T = 80
    for _ in range(T):
        mean, err = _psum_1dev(g, err)
        acc += np.asarray(mean)
    np.testing.assert_allclose(acc / T, np.asarray(g), rtol=5e-3, atol=5e-3)


def test_wrap_grads_pytree():
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    grads = {"a": jnp.ones((8,)), "b": {"c": jnp.full((4,), -2.0)}}

    def f(g):
        return wrap_grads(g, "d", None)

    sm = jax.shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                       out_specs=jax.sharding.PartitionSpec())
    out, err = sm(grads)
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), -2.0, rtol=1e-2)
    assert jax.tree.structure(err) == jax.tree.structure(grads)


def test_ef_sgd_converges_on_quadratic():
    """EF-compressed gradients still optimize f(w) = ||w - w*||^2."""
    w_star = jnp.asarray(np.random.default_rng(3).normal(size=(32,)),
                         jnp.float32)
    w = jnp.zeros((32,), jnp.float32)
    err = jnp.zeros_like(w)
    for _ in range(200):
        g = 2 * (w - w_star)
        g_c, err = _psum_1dev(g, err)
        w = w - 0.05 * g_c
    assert float(jnp.linalg.norm(w - w_star)) < 1e-2
