"""Write-back / write-around / coordinated eviction (PR 3 tentpole).

Covers dirty-bit tracking, WriteBackQueue drain ordering, write-around
read-once semantics, and the coordinated-eviction sole-copy protection —
plus the simulator/executor plumbing that keeps the flush off the critical
path.
"""

import pytest

from repro.core import (HPC_CLUSTER, LocalityScheduler, ProactiveScheduler,
                        StorageHierarchy, TierSpec, WorkflowExecutor,
                        compile_workflow)
from repro.core.locstore import LocStore, Placement, REMOTE_TIER, SimObject
from repro.core.prefetch import PrefetchEngine
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import fig2_workflow, montage_workflow

GB = float(1 << 30)


def small_hierarchy(cap=100.0):
    return StorageHierarchy(
        [TierSpec("hbm", cap, 800e9),
         TierSpec("host", 2 * cap, 100e9),
         TierSpec("bb", 4 * cap, 8e9)],
        remote=TierSpec("remote", float("inf"), 2e9))


def tiny_hierarchy(cap=100.0):
    """One node tier: eviction spills straight to the PFS."""
    return StorageHierarchy([TierSpec("hbm", cap, 800e9)],
                            remote=TierSpec("remote", float("inf"), 2e9))


class TestDirtyTracking:
    def test_fresh_put_is_dirty(self):
        st = LocStore(2, hierarchy=small_hierarchy())
        st.put("a", SimObject(10.0), loc=0)
        assert st.is_dirty("a")
        assert st.is_dirty("a", 0)
        assert not st.is_dirty("a", 1)          # no replica there

    def test_pfs_pinned_put_is_clean(self):
        st = LocStore(2, hierarchy=small_hierarchy())
        st.put("a", SimObject(10.0), loc=Placement((REMOTE_TIER,),
                                                   tier="remote"))
        assert not st.is_dirty("a")

    def test_synchronous_spill_cleans(self):
        """Write-through spill to the PFS makes the durable copy current."""
        st = LocStore(1, hierarchy=tiny_hierarchy(100))
        st.put("huge", SimObject(500.0), loc=0)   # fits nowhere: sync spill
        assert not st.is_dirty("huge")

    def test_drain_clears_dirty(self):
        st = LocStore(1, hierarchy=tiny_hierarchy(100), write_policy="back")
        st.put("a", SimObject(90.0), loc=0)
        st.put("b", SimObject(90.0), loc=0)       # a evicted -> queued flush
        assert st.is_dirty("a") and len(st.writeback) == 1
        assert st.stat("a").resident_on(REMOTE_TIER)   # logical move now
        drained = st.drain_writebacks()
        assert [e.name for e in drained] == ["a"]
        assert not st.is_dirty("a")

    def test_overwrite_redirties_and_cancels_stale_flush(self):
        st = LocStore(1, hierarchy=tiny_hierarchy(100), write_policy="back")
        st.put("a", SimObject(90.0), loc=0)
        st.put("b", SimObject(90.0), loc=0)       # queue flush of a-v1
        st.put("a", SimObject(50.0), loc=0)       # overwrite: v1 must not land
        assert st.writeback.cancelled == 1
        assert st.is_dirty("a")
        drained = st.drain_writebacks()
        assert "a" not in [e.name for e in drained]


class TestWriteBackQueue:
    def test_drain_is_fifo(self):
        st = LocStore(1, hierarchy=tiny_hierarchy(100), write_policy="back")
        for i in range(5):
            st.put(f"o{i}", SimObject(90.0), loc=0)   # evicts o0..o3 in order
        drained = st.drain_writebacks()
        assert [e.name for e in drained] == ["o0", "o1", "o2", "o3"]
        assert [e.seq for e in drained] == sorted(e.seq for e in drained)

    def test_partial_drain_respects_limit(self):
        st = LocStore(1, hierarchy=tiny_hierarchy(100), write_policy="back")
        for i in range(5):
            st.put(f"o{i}", SimObject(90.0), loc=0)
        first = st.drain_writebacks(max_entries=2)
        assert [e.name for e in first] == ["o0", "o1"]
        assert len(st.writeback) == 2
        rest = st.drain_writebacks()
        assert [e.name for e in rest] == ["o2", "o3"]

    def test_clean_eviction_is_free(self):
        """Once flushed, re-staged replicas evict with zero PFS traffic."""
        st = LocStore(2, hierarchy=tiny_hierarchy(100), write_policy="back")
        st.put("a", SimObject(90.0), loc=0)
        st.put("b", SimObject(90.0), loc=0)       # a -> writeback queue
        st.drain_writebacks()                     # a durable on PFS
        st.replicate("a", [0])                    # stage a back in (evicts b)
        before = st.remote_bytes
        st.put("c", SimObject(90.0), loc=0)       # evicts clean a: free
        assert st.clean_drops >= 1
        assert st.remote_bytes == before          # no second PFS write for a
        assert st.exists("a")

    def test_writeback_recorded_as_transfer_and_counted(self):
        st = LocStore(1, hierarchy=tiny_hierarchy(100), write_policy="back")
        st.put("a", SimObject(90.0), loc=0)
        st.put("b", SimObject(90.0), loc=0)
        (wb,) = [t for t in st.transfers if t.kind == "writeback"]
        assert wb.name == "a" and wb.dst == REMOTE_TIER
        assert wb.est_seconds > 0
        assert st.writeback_bytes == 90.0
        assert st.remote_bytes == 90.0            # the bytes will cross
        rep = st.movement_report()
        assert rep["writebacks"] == 1.0
        assert rep["writeback_pending"] == 1.0


class TestWriteAround:
    def test_put_streams_to_pfs_only(self):
        st = LocStore(2, hierarchy=small_hierarchy())
        p = st.put("stream", SimObject(50.0), loc=0, mode="around")
        assert p.nodes == (REMOTE_TIER,) and p.tiers == ("remote",)
        assert not st.is_dirty("stream")          # the PFS copy IS the copy
        assert st.remote_bytes == 50.0            # producer -> PFS write
        (t,) = [t for t in st.transfers if t.kind == "writearound"]
        assert t.src == 0 and t.dst == REMOTE_TIER

    def test_pfs_origin_put_counts_no_movement(self):
        st = LocStore(2, hierarchy=small_hierarchy())
        st.put("ext", SimObject(50.0), mode="around",
               loc=Placement((REMOTE_TIER,), tier="remote"))
        assert st.remote_bytes == 0.0

    def test_reads_are_never_cached(self):
        st = LocStore(2, hierarchy=small_hierarchy())
        st.put("stream", SimObject(50.0), loc=0, mode="around")
        for _ in range(2):                        # every read pays the PFS
            _, tr = st.get("stream", at=1)
            assert tr.src == REMOTE_TIER and not tr.local
        assert st.stat("stream").nodes == (REMOTE_TIER,)
        assert st.remote_bytes == 50.0 * 3        # 1 write + 2 reads

    def test_replicate_is_noop(self):
        st = LocStore(2, hierarchy=small_hierarchy())
        st.put("stream", SimObject(50.0), loc=0, mode="around")
        p = st.replicate("stream", [1])
        assert p.nodes == (REMOTE_TIER,)

    def test_prefetch_engine_skips_read_once(self):
        st = LocStore(2, hierarchy=small_hierarchy())
        st.put("stream", SimObject(50.0), loc=0, mode="around")
        eng = PrefetchEngine(st)
        eng.submit("stream", 1)
        eng.drain()
        assert eng.skipped_read_once == 1
        assert st.stat("stream").nodes == (REMOTE_TIER,)

    def test_store_wide_around_rejected(self):
        with pytest.raises(ValueError):
            LocStore(1, write_policy="around")
        with pytest.raises(ValueError):
            LocStore(1).put("x", SimObject(1.0), mode="nonsense")

    def test_around_rejects_conflicting_pins(self):
        st = LocStore(2, hierarchy=small_hierarchy())
        with pytest.raises(ValueError):            # tier pin is contradictory
            st.put("s", SimObject(1.0), loc=0, tier="host", mode="around")
        with pytest.raises(ValueError):            # so is multi-node loc
            st.put("s", SimObject(1.0), loc=(0, 1), mode="around")


class TestCoordinatedEviction:
    def test_replicated_victim_dropped_before_sole_copy(self):
        st = LocStore(2, hierarchy=tiny_hierarchy(100),
                      coordinated_eviction=True)
        st.put("dup", SimObject(60.0), loc=(0, 1))
        st.put("sole", SimObject(30.0), loc=0)
        st.put("new", SimObject(60.0), loc=0)     # pressure on node 0
        # dup's node-0 replica dropped (free: node 1 still has it);
        # sole survives on node 0 (demoted at worst), never dropped
        assert st.stat("dup").nodes == (1,)
        assert st.exists("sole")
        assert (0 in st.stat("sole").nodes
                or st.stat("sole").resident_on(REMOTE_TIER))
        assert st.coord_drops == 1
        assert st.bytes_coord_dropped == 60.0
        assert st.coordination_violations == 0

    def test_drop_moves_no_bytes(self):
        st = LocStore(2, hierarchy=tiny_hierarchy(100),
                      coordinated_eviction=True)
        st.put("dup", SimObject(90.0), loc=(0, 1))
        before = st.movement_report()
        st.put("new", SimObject(90.0), loc=0)     # dup@0 dropped, not demoted
        after = st.movement_report()
        assert st.coord_drops == 1
        assert after["bytes_demoted"] == before["bytes_demoted"]
        assert after["remote_bytes"] == before["remote_bytes"]

    def test_sole_copies_are_demoted_not_dropped(self):
        """No dataset is ever lost: with only sole copies under pressure the
        coordinated policy degrades to plain demotion."""
        st = LocStore(1, hierarchy=small_hierarchy(100),
                      coordinated_eviction=True)
        for i in range(10):
            st.put(f"o{i}", SimObject(90.0), loc=0)
        assert all(st.exists(f"o{i}") for i in range(10))
        assert st.coord_drops == 0
        assert st.demotions > 0

    def test_prefers_victim_with_fast_duplicate(self):
        """Class 0 (duplicate in an equal-or-faster tier elsewhere) evicts
        before class 1 (only cold duplicates — the last fast-tier copy)."""
        st = LocStore(2, hierarchy=small_hierarchy(100),
                      coordinated_eviction=True, promote_on_access=False)
        # cold_dup: node-0 hbm copy + node-1 burst-buffer copy (cold)
        st.put("cold_dup", SimObject(40.0), loc=0)
        st.replicate("cold_dup", [1], tier="bb")
        # fast_dup: node-0 hbm copy + node-1 hbm copy (fast)
        st.put("fast_dup", SimObject(40.0), loc=(0, 1))
        st.get("fast_dup", at=0)    # make fast_dup the LRU-protected one...
        st.get("cold_dup", at=0)    # ...and cold_dup most-recently used
        st.put("new", SimObject(40.0), loc=0)     # evict one from node-0 hbm
        # plain LRU would evict fast_dup (older); coordination drops it too —
        # but only because it has a FAST duplicate; cold_dup (last fast copy,
        # fresher anyway) must still be in node-0 hbm
        assert st.stat("cold_dup").tier_on(0) == "hbm"
        assert st.stat("fast_dup").nodes == (1,)

    def test_last_fast_copy_dropped_only_when_no_alternative(self):
        """With ONLY class-1 candidates, the last fast-tier replica is
        dropped (free — the cold duplicate keeps the data safe), never
        demoted through the PFS."""
        st = LocStore(2, hierarchy=tiny_hierarchy(100),
                      coordinated_eviction=True)
        st.put("d", SimObject(90.0), loc=0)
        st.replicate("d", [REMOTE_TIER])          # cold duplicate on the PFS
        before = st.remote_bytes
        st.put("new", SimObject(90.0), loc=0)
        assert st.coord_drops == 1
        assert st.remote_bytes == before          # dropped, not re-written
        assert st.exists("d")


class TestDoNotEvictPins:
    """PR 4 satellite: prefetched replicas are pinned do-not-evict for their
    consumer's lifetime, so coordinated eviction at comfortable capacity
    cannot undo prefetch work (the bench_writeback 1 GiB regression)."""

    def test_pinned_replica_survives_eviction_pressure(self):
        st = LocStore(2, hierarchy=tiny_hierarchy(100),
                      coordinated_eviction=True)
        st.put("dup", SimObject(60.0), loc=(0, 1))   # prefetched duplicate
        st.pin("dup", 0)
        st.put("new", SimObject(60.0), loc=0)        # pressure on node 0
        # without the pin this is exactly test_replicated_victim_dropped_...:
        # dup@0 would be the coordinated-eviction victim. Pinned, it stays.
        assert st.stat("dup").resident_on(0)
        assert st.coord_drops == 0
        assert st.pin_protected_evictions > 0
        st.unpin("dup", 0)
        st.put("more", SimObject(60.0), loc=0)       # unpinned: fair game
        assert not st.stat("dup").resident_on(0)
        assert st.coord_drops >= 1

    def test_pin_refcounting(self):
        st = LocStore(1, hierarchy=tiny_hierarchy(100))
        st.put("a", SimObject(10.0), loc=0)
        st.pin("a", 0)
        st.pin("a", 0)
        st.unpin("a", 0)
        assert st.is_pinned("a", 0)                  # one pin still held
        st.unpin("a", 0)
        assert not st.is_pinned("a", 0)
        st.unpin("a", 0)                             # over-unpin is harmless
        assert not st.is_pinned("a", 0)

    def test_delete_clears_pins(self):
        st = LocStore(1, hierarchy=tiny_hierarchy(100))
        st.put("a", SimObject(10.0), loc=0)
        st.pin("a", 0)
        st.delete("a")
        assert not st.is_pinned("a", 0)

    def test_fully_pinned_tier_runs_overfull_never_drops(self):
        st = LocStore(1, hierarchy=tiny_hierarchy(100),
                      coordinated_eviction=True)
        st.put("a", SimObject(60.0), loc=0)
        st.pin("a", 0)
        st.put("b", SimObject(60.0), loc=0)          # no victim available
        st.pin("b", 0)
        assert st.stat("a").tier_on(0) == "hbm"
        assert st.stat("b").tier_on(0) == "hbm"      # overfull, not dropped

    def test_prefetch_engine_pins_until_release(self):
        st = LocStore(2, hierarchy=small_hierarchy(100))
        st.put("x", SimObject(10.0), loc=0)
        eng = PrefetchEngine(st)
        eng.submit("x", 1, tier="hbm", pin_for="consumer_task")
        eng.drain()
        assert st.is_pinned("x", 1)
        assert eng.report()["pins_held"] == 1
        assert eng.release("consumer_task") == 1
        assert not st.is_pinned("x", 1)
        assert eng.release("consumer_task") == 0     # idempotent
        eng.shutdown()

    def test_prefetch_tier_upgrade_resubmits(self):
        """A later request for a FASTER tier must not be swallowed by the
        (name, dst) idempotence — a bb-staged session cache still needs its
        HBM warm-up."""
        st = LocStore(2, hierarchy=small_hierarchy(100))
        st.put("x", SimObject(10.0), loc=0)
        eng = PrefetchEngine(st)
        eng.submit("x", 1, tier="bb")
        eng.drain()
        assert st.stat("x").tier_on(1) == "bb"
        eng.submit("x", 1, tier="hbm")
        eng.drain()
        assert st.stat("x").tier_on(1) == "hbm"
        assert eng.submitted == 2
        eng.shutdown()

    def test_sim_releases_all_pins_by_end_of_run(self):
        wf = compile_workflow(montage_workflow(16), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER,
                                hierarchy=StorageHierarchy(
                                    [TierSpec("hbm", 0.25 * GB / 4, 819e9),
                                     TierSpec("host", 0.25 * GB, 100e9),
                                     TierSpec("bb", 4 * GB, 8e9)],
                                    remote=TierSpec("remote", float("inf"),
                                                    0.5e9)),
                                write_policy="back",
                                coordinated_eviction=True)
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
        rep = sim.store.movement_report()
        assert rep["pins"] == 0        # every prefetch pin was released


class TestSimulatorPlumbing:
    def _hier(self, cap):
        return StorageHierarchy(
            [TierSpec("hbm", cap / 4, 819e9),
             TierSpec("host", cap, 100e9),
             TierSpec("bb", 16 * cap, 8e9)],
            remote=TierSpec("remote", float("inf"), 0.5e9))

    def test_writeback_reduces_io_wait_under_pressure(self):
        wf = compile_workflow(montage_workflow(16), HPC_CLUSTER)
        hier = self._hier(0.125 * GB)
        r_thru = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=4,
                                   hw=HPC_CLUSTER, hierarchy=hier).run()
        r_back = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=4,
                                   hw=HPC_CLUSTER, hierarchy=self._hier(0.125 * GB),
                                   write_policy="back").run()
        assert r_back.writebacks > 0
        assert r_back.io_wait_total < r_thru.io_wait_total
        assert r_back.tasks_done == r_thru.tasks_done == len(wf.graph.tasks)

    def test_coordinated_eviction_sim_never_loses_data(self):
        wf = compile_workflow(montage_workflow(16), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER, hierarchy=self._hier(0.25 * GB),
                                write_policy="back", coordinated_eviction=True)
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
        assert r.coord_drops > 0                  # coordination actually fired
        assert sim.store.coordination_violations == 0

    def test_queue_drained_by_end_of_run(self):
        wf = compile_workflow(montage_workflow(12), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER, hierarchy=self._hier(0.125 * GB),
                                write_policy="back")
        sim.run()
        assert len(sim.store.writeback) == 0
        assert not any(sim.store.is_dirty(n) for n in sim.store.loc.names()
                       if sim.store.stat(n).resident_on(REMOTE_TIER))


class TestExecutorPlumbing:
    def test_executor_drains_writebacks_off_critical_path(self):
        wf = compile_workflow(fig2_workflow(256.0), HPC_CLUSTER)

        def body(tid):
            def fn(**inputs):
                t = wf.graph.tasks[tid]
                return {o: SimObject(wf.sizes[o]) for o in t.outputs}
            return fn
        for tid in wf.graph.tasks:
            wf.graph.tasks[tid].fn = body(tid)
        ex = WorkflowExecutor(wf, LocalityScheduler(wf), n_nodes=2,
                              hierarchy=StorageHierarchy(
                                  [TierSpec("hbm", 96.0, 800e9)],
                                  remote=TierSpec("remote", float("inf"), 2e9)),
                              write_policy="back",
                              inject_inputs={"raw": SimObject(256.0)})
        res = ex.run()
        assert set(res.outputs) == {"result"}
        assert len(ex.store.writeback) == 0       # drainer flushed everything
        assert res.writebacks > 0

    def test_executor_rejects_store_plus_policy(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        with pytest.raises(ValueError):
            WorkflowExecutor(wf, LocalityScheduler(wf), n_nodes=2,
                             store=LocStore(2), write_policy="back")


class TestTierPinning:
    """The compiler->scheduler loop: est_stage_seconds picks prefetch tiers."""

    def test_hot_input_pinned_to_top_tier(self):
        # compute-heavy tasks: staging is hideable -> hbm
        wf = compile_workflow(fig2_workflow(4 * GB, flops_per_byte=200000.0),
                              HPC_CLUSTER)
        s = ProactiveScheduler(wf)
        assert s._pin_tier("raw", "split", _TieredView()) == "hbm"

    def test_bulk_input_pinned_to_burst_buffer(self):
        # I/O-dominated tasks: staging dwarfs compute -> bb
        wf = compile_workflow(fig2_workflow(4 * GB, flops_per_byte=1.0),
                              HPC_CLUSTER)
        s = ProactiveScheduler(wf)
        assert wf.est_stage_seconds["split"] > wf.est_seconds["split"]
        assert s._pin_tier("raw", "split", _TieredView()) == "bb"

    def test_explicit_tier_still_pins_everything(self):
        wf = compile_workflow(fig2_workflow(4 * GB, flops_per_byte=1.0),
                              HPC_CLUSTER)
        s = ProactiveScheduler(wf, prefetch_tier="hbm")
        assert s._pin_tier("raw", "split", _TieredView()) == "hbm"

    def test_preplace_emits_pinned_requests(self):
        wf = compile_workflow(fig2_workflow(4 * GB, flops_per_byte=1.0),
                              HPC_CLUSTER)
        s = ProactiveScheduler(wf)
        # raw lives on busy node 2: whichever free node wins needs a prefetch
        view = _TieredView(free=[0, 1],
                           loc={"raw": Placement((2,), tier="hbm",
                                                 tiers=("hbm",))})
        reqs = s.preplace(["split"], view, {})
        assert reqs and all(r.tier == "bb" for r in reqs
                            if r.data_name == "raw")

    def test_compiler_exposes_per_dataset_stage_seconds(self):
        wf = compile_workflow(fig2_workflow(4 * GB), HPC_CLUSTER)
        assert wf.stage_seconds["raw"] == pytest.approx(
            wf.est_stage_seconds["split"])
        assert "part_a" not in wf.stage_seconds    # internal datasets excluded


class _TieredView:
    def __init__(self, free=(0,), loc=None):
        self._free, self._loc = list(free), dict(loc or {})

    def free_workers(self):
        return list(self._free)

    def locate(self, name):
        return self._loc.get(name)

    def link_gbps(self, src, dst):
        return float("inf") if src == dst else 10e9

    def tier_gbps(self, tier):
        return {"hbm": 800e9, "host": 100e9, "bb": 8e9,
                "remote": 2e9}.get(tier, float("inf"))

    def top_tier(self):
        return "hbm"

    def bulk_tier(self):
        return "bb"

    def worker_speed(self, node):
        return 1.0
