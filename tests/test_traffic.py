"""Trace generator + trace-driven serving driver (PR 7 tentpole).

Generator: seeded determinism, Zipf-skew and arrival-rate statistical
sanity, percentile math on known fixtures. Driver: the full
park/resume/warm/failover lifecycle on the synthetic backend — zero
engine-full errors under pressure, predictive warming hiding resume
latency, flat pinning losing on tail TTFT, and bit-identical reruns.
"""

import numpy as np
import pytest

from repro.serve.engine import ServingEngine
from repro.serve.traffic import (InterArrivalPredictor, MiB,
                                 SyntheticBackend, TraceConfig, TraceDriver,
                                 build_trace_stack, generate_trace,
                                 latency_percentiles, trace_stats)


# ----------------------------------------------------------------- generator
def test_trace_seeded_determinism():
    cfg = TraceConfig(n_sessions=300, seed=11, arrival="bursty")
    assert generate_trace(cfg) == generate_trace(cfg)
    other = generate_trace(TraceConfig(n_sessions=300, seed=12,
                                       arrival="bursty"))
    assert other != generate_trace(cfg)


def test_trace_structure_invariants():
    cfg = TraceConfig(n_sessions=400, followups_per_session=2.0, seed=3)
    trace = generate_trace(cfg)
    assert len(trace) == 400 + 800
    # every session opens with turn 0, turns are consecutive, exactly one
    # final per session, and times are sorted
    seen: dict[int, int] = {}
    finals: dict[int, int] = {}
    last_t = 0.0
    for r in trace:
        assert r.t >= last_t
        last_t = r.t
        expect = seen.get(r.session, -1) + 1
        assert r.turn == expect
        seen[r.session] = r.turn
        if r.final:
            finals[r.session] = finals.get(r.session, 0) + 1
        assert 1 <= r.prompt_len <= cfg.max_prompt
        assert 1 <= r.output_len <= cfg.max_output
    assert len(seen) == 400                      # all sessions distinct+used
    assert all(v == 1 for v in finals.values()) and len(finals) == 400


def test_zipf_skew_and_arrival_rate():
    cfg = TraceConfig(n_sessions=2000, followups_per_session=3.0,
                      req_rate=500.0, zipf_alpha=1.2, seed=5)
    st = trace_stats(generate_trace(cfg))
    # Poisson arrivals: mean gap ~ 1/rate, CV ~ 1
    assert st["mean_gap"] == pytest.approx(1 / 500.0, rel=0.1)
    assert 0.9 < st["cv_gap"] < 1.1
    # Zipf: the hottest session gets far more than the uniform 1/N share,
    # and the top decile dominates
    uniform_share = 1.0 / 2000
    assert st["top1_share"] > 20 * uniform_share
    assert st["top10pct_share"] > 0.35


def test_bursty_arrivals_overdispersed():
    base = TraceConfig(n_sessions=3000, req_rate=300.0, seed=9)
    poisson = trace_stats(generate_trace(base))
    bursty = trace_stats(generate_trace(
        dataclass_replace(base, arrival="bursty", burst_factor=12.0,
                          burst_fraction=0.15)))
    # burstiness shows up as gap overdispersion; long-run rate is preserved
    assert bursty["cv_gap"] > poisson["cv_gap"] + 0.05
    assert bursty["mean_gap"] == pytest.approx(1 / 300.0, rel=0.15)


def dataclass_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


def test_heavy_tailed_lengths():
    cfg = TraceConfig(n_sessions=3000, followups_per_session=0.0,
                      prompt_sigma=1.0, seed=2)
    lens = np.array([r.prompt_len for r in generate_trace(cfg)])
    assert np.percentile(lens, 99) > 3 * np.median(lens)


def test_percentiles_on_fixture():
    vals = list(range(100))                      # 0..99
    p = latency_percentiles(vals)
    assert p["p50"] == pytest.approx(49.5)
    assert p["p95"] == pytest.approx(94.05)
    assert p["p99"] == pytest.approx(98.01)
    assert latency_percentiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}


def test_interarrival_predictor():
    pr = InterArrivalPredictor(alpha=0.5)
    assert pr.predict(1) is None                 # nothing observed yet
    for t in (0.0, 10.0, 20.0, 30.0):
        pr.observe(1, t)
    assert pr.predict(1) == pytest.approx(10.0)
    # a session seen once falls back to the global prior
    pr.observe(2, 5.0)
    assert pr.predict(2) == pytest.approx(10.0, rel=0.01)


# ----------------------------------------------------------- synthetic engine
def test_synthetic_backend_park_resume_bit_identical():
    """The synthetic backend honours the same contract as the JAX one:
    park/resume through a REAL ServingEngine + LocStore reproduces the
    uninterrupted token stream, and the store accounts the modeled bytes."""
    kv = 4 * MiB
    router, store = build_trace_stack(n_engines=1, max_batch=2, kv_bytes=kv,
                                      bb_slots_per_node=4)
    (eng,) = router.engines.values()
    control = ServingEngine(None, None, node=0,
                            backend=SyntheticBackend(kv_bytes=kv))
    prompt = [5, 6, 7]
    sid = eng.submit(prompt)
    cid = control.submit(prompt)
    for _ in range(3):
        eng.step()
        control.step()
    eng.park(sid)
    assert store.tier_used(0, "bb") >= kv        # parked slice in the bb
    eng.resume(sid)
    for _ in range(3):
        eng.step()
        control.step()
    assert eng.sessions[sid].tokens == control.sessions[cid].tokens
    assert eng.slot_bytes() == kv


def test_route_decision_kinds_synthetic():
    router, store = build_trace_stack(n_engines=2, max_batch=2)
    (e0, e1) = (router.engines[0], router.engines[1])
    d = router.route(None)
    assert d.kind == "new" and d.engine in (e0, e1)
    sid = e0.submit([1, 2, 3])
    d = router.follow_up(sid, [1, 2, 3])
    assert d.kind == "hit_live" and d.sid == sid and not d.resumed
    e0.park(sid)
    d = router.follow_up(sid, [1, 2, 3])
    assert d.kind == "hit_parked" and d.resumed and not d.prefilled


# --------------------------------------------------------------------- driver
def _run(n_sessions=250, *, warm=False, tiered=True, failures=(), seed=21,
         bb=8, engines=2, batch=4, followups=2.0, rate=60.0,
         durability="none"):
    trace = generate_trace(TraceConfig(
        n_sessions=n_sessions, followups_per_session=followups,
        req_rate=rate, arrival="bursty", seed=seed))
    router, store = build_trace_stack(n_engines=engines, max_batch=batch,
                                      kv_bytes=8 * MiB, tiered=tiered,
                                      bb_slots_per_node=bb,
                                      durability=durability)
    drv = TraceDriver(router, trace, warm=warm, failures=failures)
    return drv.run(), router, store


def test_driver_lifecycle_under_pressure():
    rep, router, store = _run()
    s = rep.summary()
    assert s["requests"] == rep.requests == 750
    assert s["sessions"] == 250
    # memory pressure forced parking and resuming, never an engine-full error
    assert s["engine_full_errors"] == 0
    assert s["resumes"] > 0
    assert sum(e.parks for e in router.engines.values()) > 0
    assert s["p99_ttft_ms"] >= s["p50_ttft_ms"] > 0
    # every arrival is accounted exactly once
    assert (s["new_sessions"] + s["lost_reprefills"] + s["followups"]
            == rep.requests)


def test_driver_deterministic_rerun():
    rep1, _, _ = _run(warm=True)
    rep2, _, _ = _run(warm=True)
    assert rep1.summary() == rep2.summary()


def test_predictive_warming_hides_resume_latency():
    cold, _, _ = _run(warm=False, seed=33)
    warmed, _, _ = _run(warm=True, seed=33)
    sw = warmed.summary()
    assert sw["warms"] > 0 and sw["warm_hits"] > 0
    assert sw["resume_hidden_s"] > 0
    # partial warm hits pay one extra top-tier read; allow that epsilon
    assert (sw["p99_resume_ms"]
            <= cold.summary()["p99_resume_ms"] * 1.05)


def test_flat_pinning_pays_on_tail_ttft():
    tiered, _, _ = _run(seed=44, warm=True)
    flat, _, _ = _run(seed=44, tiered=False)
    st, sf = tiered.summary(), flat.summary()
    # flat pinning force-finishes LRU sessions and re-prefills whole
    # histories; the tiered park/resume path beats it on tail TTFT
    assert sf["force_finished"] > 0 and sf["lost_reprefills"] > 0
    assert st["p99_ttft_ms"] < sf["p99_ttft_ms"]
    assert st["engine_full_errors"] == 0


def test_driver_failover_mid_trace():
    trace = generate_trace(TraceConfig(n_sessions=200,
                                       followups_per_session=2.0,
                                       req_rate=50.0, seed=8))
    t_mid = trace[len(trace) // 2].t
    rep, router, _ = _run(n_sessions=200, failures=((t_mid, 0),), seed=8,
                          rate=50.0, durability="flush_before_ack")
    s = rep.summary()
    assert 0 not in router.engines                 # the node is gone
    assert s["failover_resumed"] > 0               # durable parks re-homed
    assert s["failover_resumed"] + s["failover_lost"] > 0
    assert s["engine_full_errors"] == 0
    assert rep.requests == 600                     # every request was served


def test_tier_used_matches_tier_report():
    """The O(1) pressure probe agrees with the full-scan report."""
    _, router, store = _run(n_sessions=120, seed=13)
    for node in router.engines:
        rep = store.tier_report(node=node)
        for tier in ("hbm", "bb"):
            assert store.tier_used(node, tier) == rep[tier]["resident_bytes"]


def test_bytes_promoted_accounting():
    _, router, store = _run(n_sessions=120, warm=True, seed=13)
    mv = store.movement_report()
    assert mv["bytes_promoted"] > 0
    assert mv["promotions"] > 0
    store.reset_accounting()
    assert store.movement_report()["bytes_promoted"] == 0.0
