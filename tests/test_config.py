"""Config-object API (PR 7 satellites): ``SimConfig`` / ``ServingConfig``.

The load-bearing guarantee is *equivalence*: the legacy flat-keyword
spelling and the new ``config=`` spelling must drive the exact same code
paths, pinned by comparing full ``SimResult``s field by field. Plus the
contract edges: unknown knobs raise ``TypeError`` (as the old signatures
did), and passing ``config=`` together with legacy keywords is rejected.
"""

import dataclasses

import pytest

from repro.core import (HPC_CLUSTER, LocalityScheduler, ProactiveScheduler,
                        ServingConfig, SimConfig, compile_workflow)
from repro.core.locstore import GiB, tiered_hierarchy
from repro.core.simulator import WorkflowSimulator, simulate
from repro.core.workloads import montage_workflow
from repro.serve.engine import Router, ServingEngine
from repro.serve.traffic import MiB, SyntheticBackend


def _wf():
    return compile_workflow(montage_workflow(width=12), HPC_CLUSTER)


def _same_result(a, b) -> None:
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    assert da == db, {k: (da[k], db[k]) for k in da if da[k] != db[k]}


# ------------------------------------------------------------------ SimConfig
def test_simconfig_equivalent_to_legacy_kwargs_basic():
    legacy = WorkflowSimulator(_wf(), LocalityScheduler(_wf()), n_nodes=4,
                               hw=HPC_CLUSTER, external_loc="scattered").run()
    cfg = SimConfig(n_nodes=4, hw=HPC_CLUSTER, external_loc="scattered")
    new = WorkflowSimulator(_wf(), LocalityScheduler(_wf()), config=cfg).run()
    _same_result(legacy, new)


def test_simconfig_equivalent_under_tiers_writeback_durability_failures():
    """The heavyweight knobs — tiered hierarchy, write-back, durability
    windows, mid-run failures — all route identically through the config."""
    kw = dict(
        n_nodes=4, hw=HPC_CLUSTER,
        hierarchy=tiered_hierarchy(hbm_bytes=0.5 * GiB, host_bytes=1 * GiB,
                                   bb_bytes=2 * GiB),
        write_policy="back", coordinated_eviction=True,
        honor_write_modes=True, durability="fsync_on_barrier", barrier_every=2,
        failures=[(5.0, 1)], proactive=True, indexed=False,
    )
    legacy = WorkflowSimulator(_wf(), ProactiveScheduler(_wf()), **kw).run()
    new = WorkflowSimulator(_wf(), ProactiveScheduler(_wf()),
                            config=SimConfig.from_kwargs(**kw)).run()
    _same_result(legacy, new)
    assert legacy.reruns + new.reruns > 0 or legacy.drop_reports


def test_simconfig_from_kwargs_normalizes_failures():
    cfg = SimConfig.from_kwargs(failures=[(1.0, 0)])
    assert cfg.failures == ((1.0, 0),)
    assert hash(cfg) == hash(SimConfig(failures=((1.0, 0),)))


def test_simconfig_unknown_knob_raises():
    with pytest.raises(TypeError, match="unknown knob"):
        SimConfig.from_kwargs(n_noodles=4)
    with pytest.raises(TypeError, match="unknown knob"):
        WorkflowSimulator(_wf(), LocalityScheduler(_wf()), n_noodles=4)


def test_simconfig_xor_legacy_kwargs():
    with pytest.raises(TypeError, match="config"):
        WorkflowSimulator(_wf(), LocalityScheduler(_wf()),
                          config=SimConfig(), n_nodes=4)


def test_simulate_accepts_config():
    cfg = SimConfig(n_nodes=4, hw=HPC_CLUSTER)
    legacy = simulate(_wf(), LocalityScheduler, n_nodes=4, hw=HPC_CLUSTER)
    new = simulate(_wf(), LocalityScheduler, config=cfg)
    _same_result(legacy, new)
    sim = WorkflowSimulator(_wf(), LocalityScheduler(_wf()), config=cfg)
    assert sim.config is cfg                 # the consumed config is kept


# -------------------------------------------------------------- ServingConfig
def test_servingconfig_equivalent_to_legacy_kwargs():
    be = SyntheticBackend(kv_bytes=MiB)
    legacy = ServingEngine(None, None, backend=be, max_batch=3, max_seq=64,
                           eos_id=9, idle_tier="host")
    cfg = ServingConfig(max_batch=3, max_seq=64, eos_id=9, idle_tier="host")
    new = ServingEngine(None, None, backend=be, config=cfg)
    assert (legacy.max_batch, legacy.max_seq, legacy.eos_id,
            legacy.idle_tier) == (3, 64, 9, "host")
    assert (new.max_batch, new.max_seq, new.eos_id, new.idle_tier) \
        == (legacy.max_batch, legacy.max_seq, legacy.eos_id, legacy.idle_tier)


def test_servingconfig_xor_and_unknown():
    be = SyntheticBackend(kv_bytes=MiB)
    with pytest.raises(TypeError, match="config"):
        ServingEngine(None, None, backend=be, config=ServingConfig(),
                      max_batch=3)
    with pytest.raises(TypeError, match="unknown knob"):
        ServingConfig.from_kwargs(max_batches=3)


def test_router_config_xor_allow_park():
    from repro.core.locstore import LocStore
    store = LocStore(1)
    eng = ServingEngine(None, None, backend=SyntheticBackend(kv_bytes=MiB),
                        node=0, store=store)
    with pytest.raises(TypeError, match="config"):
        Router([eng], store, config=ServingConfig(), allow_park=True)
    rtr = Router([eng], store, config=ServingConfig(allow_park=False))
    assert rtr.allow_park is False


def test_configs_frozen():
    with pytest.raises(dataclasses.FrozenInstanceError):
        SimConfig().n_nodes = 8
    with pytest.raises(dataclasses.FrozenInstanceError):
        ServingConfig().max_batch = 8
