"""The --quick CI contract: every benchmark module must accept (and honor)
the quick flag, and the harness must run every module that exists — a module
that silently ignores quick reintroduces full-size sweeps into the smoke job
(PR 3 satellite fix: bench_roofline lacked the parameter entirely).
"""

import importlib
import inspect
import os
import pkgutil
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

import benchmarks  # noqa: E402


def bench_modules():
    for info in pkgutil.iter_modules(benchmarks.__path__):
        if info.name.startswith("bench_"):
            yield importlib.import_module(f"benchmarks.{info.name}")


def test_every_bench_module_accepts_quick():
    mods = list(bench_modules())
    assert mods, "no benchmark modules found"
    missing = [m.__name__ for m in mods
               if "quick" not in inspect.signature(m.run).parameters]
    assert not missing, (f"benchmark modules ignoring --quick: {missing} — "
                         f"the CI smoke job would run them at full scale")


def test_harness_runs_every_module():
    """run.py's explicit module list must cover every bench_* file on disk."""
    import benchmarks.run as harness

    src = inspect.getsource(harness.main)
    on_disk = {m.__name__.split(".")[-1] for m in bench_modules()}
    not_wired = {name for name in on_disk if name not in src}
    assert not not_wired, f"bench modules not wired into run.py: {not_wired}"


def test_trend_checker_importable_and_selfchecks():
    from benchmarks import check_trend

    # token parser: units are stripped, percentages and arrows ignored
    m = check_trend.parse_metrics(
        "remote_gib=3.25 io_wait_s=12.5 hit=45% makespan 10->20s x=1e-3")
    assert m["remote_gib"] == 3.25 and m["io_wait_s"] == 12.5
    assert "makespan" not in m          # arrow form is not a token
    # regression logic
    base = [{"name": "a", "us_per_call": 0.0, "derived": "remote_gib=1.0"}]
    cur_ok = [{"name": "a", "us_per_call": 0.0, "derived": "remote_gib=1.5"}]
    cur_bad = [{"name": "a", "us_per_call": 0.0, "derived": "remote_gib=2.5"}]
    assert check_trend.regressions(cur_ok, base) == []
    bad = check_trend.regressions(cur_bad, base)
    assert len(bad) == 1 and bad[0].name == "a"
    # traffic appearing from a ~zero baseline must still fail the gate
    base0 = [{"name": "a", "us_per_call": 0.0, "derived": "remote_gib=0.00"}]
    cur0 = [{"name": "a", "us_per_call": 0.0, "derived": "remote_gib=3.00"}]
    (r0,) = check_trend.regressions(cur0, base0)
    assert r0.current == 3.0 and str(r0)      # printable despite inf ratio
    # per-row allow-list: the waived (row, metric) passes, others still fail
    waived = []
    assert check_trend.regressions(cur_bad, base,
                                   allowed={("a", "remote_gib")},
                                   waived=waived) == []
    assert len(waived) == 1 and waived[0].name == "a"
    assert check_trend.regressions(cur_bad, base,
                                   allowed={("other", "remote_gib")})


def test_trend_checker_direction_aware_metrics():
    """ISSUE 5: ``reruns`` is higher-is-worse, ``*_saved`` lower-is-worse."""
    from benchmarks import check_trend

    base = [{"name": "f", "us_per_call": 0.0,
             "derived": "reruns=1 prefills_saved=2 dirty_lost=0"}]
    worse = [{"name": "f", "us_per_call": 0.0,
              "derived": "reruns=5 prefills_saved=2 dirty_lost=0"}]
    (r,) = check_trend.regressions(worse, base)
    assert r.metric == "reruns" and r.current == 5
    shrunk = [{"name": "f", "us_per_call": 0.0,
               "derived": "reruns=1 prefills_saved=0 dirty_lost=0"}]
    (r2,) = check_trend.regressions(shrunk, base)
    assert r2.metric == "prefills_saved" and r2.current == 0
    # dirty objects appearing from a zero baseline must fail too
    leak = [{"name": "f", "us_per_call": 0.0,
             "derived": "reruns=1 prefills_saved=2 dirty_lost=3"}]
    (r3,) = check_trend.regressions(leak, base)
    assert r3.metric == "dirty_lost"
    # a win that vanishes from the row is the maximal shrink, not a skip
    gone = [{"name": "f", "us_per_call": 0.0,
             "derived": "reruns=1 dirty_lost=0"}]
    (r4,) = check_trend.regressions(gone, base)
    assert r4.metric == "prefills_saved" and r4.current == 0.0
    same = [{"name": "f", "us_per_call": 0.0,
             "derived": "reruns=1 prefills_saved=2 dirty_lost=0"}]
    assert check_trend.regressions(same, base) == []


def test_trend_allowlist_requires_reason(tmp_path):
    import json

    from benchmarks import check_trend

    good = tmp_path / "allow.json"
    good.write_text(json.dumps([{"name": "a", "metric": "remote_gib",
                                 "reason": "deliberate: see PR"}]))
    assert check_trend.load_allowlist(str(good)) == {("a", "remote_gib")}
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([{"name": "a", "metric": "remote_gib"}]))
    import pytest
    with pytest.raises(ValueError):
        check_trend.load_allowlist(str(bad))
    assert check_trend.load_allowlist(str(tmp_path / "missing.json")) == set()
