"""Unit tests for the three schedulers over a hand-built ClusterView."""

from repro.core import (FCFSScheduler, LocalityScheduler, Placement,
                        ProactiveScheduler, compile_workflow, HPC_CLUSTER)
from repro.core.workloads import fig2_workflow


class FakeCluster:
    def __init__(self, free, locations, speeds=None):
        self._free = free
        self._loc = locations        # data name -> Placement
        self._speeds = speeds or {}

    def free_workers(self):
        return list(self._free)

    def locate(self, name):
        return self._loc.get(name)

    def link_gbps(self, src, dst):
        return float("inf") if src == dst else 1e9

    def worker_speed(self, node):
        return self._speeds.get(node, 1.0)


def make_wf():
    return compile_workflow(fig2_workflow(), HPC_CLUSTER)


def test_fcfs_assigns_in_arrival_order_round_robin():
    wf = make_wf()
    s = FCFSScheduler(wf)
    cluster = FakeCluster([0, 1, 2, 3], {"raw": Placement((2,))})
    a1 = s.select(["split"], cluster)
    assert len(a1) == 1
    # round robin: successive selects rotate workers even if 0 is free
    a2 = s.select(["filter_a"], FakeCluster([0, 1, 2, 3], {}))
    assert a2[0].node != a1[0].node


def test_locality_picks_resident_node():
    wf = make_wf()
    s = LocalityScheduler(wf)
    cluster = FakeCluster([0, 1, 2, 3], {"raw": Placement((2,))})
    (a,) = s.select(["split"], cluster)
    assert a.node == 2
    assert a.move_seconds == 0.0


def test_locality_prioritizes_critical_path():
    wf = make_wf()
    s = LocalityScheduler(wf)
    # only one worker: the higher-rank task must win
    cluster = FakeCluster([0], {"raw": Placement((0,)),
                                "fa": Placement((0,))})
    picks = s.select(["analyze_a", "merge"], cluster)
    assert picks[0].tid == "analyze_a"    # longer path to sink than merge


def test_proactive_preassigns_and_requests_prefetch():
    wf = make_wf()
    s = ProactiveScheduler(wf)
    # no input of filter_a is materialized yet -> must NOT be pre-assigned
    cluster = FakeCluster([0, 2, 3], {"raw": Placement((1,))})
    s.preplace(["filter_a"], cluster, running_at={"split": 1})
    assert "filter_a" not in s.preassignment
    # merge has one of two inputs (ra) materialized on node 1 -> paper: "the
    # task might be pre-scheduled even [if] only parts of its inputs are
    # ready", and the ready part is pipelined to the chosen node.
    cluster2 = FakeCluster([0, 2, 3], {"raw": Placement((1,)),
                                       "ra": Placement((1,))})
    reqs = s.preplace(["merge"], cluster2, running_at={})
    assert "merge" in s.preassignment
    if s.preassignment["merge"] != 1:
        assert any(r.data_name == "ra" for r in reqs)


def test_proactive_select_honours_preassignment():
    wf = make_wf()
    s = ProactiveScheduler(wf)
    cluster = FakeCluster([0, 1, 2], {"raw": Placement((1,))})
    s.preassignment["split"] = 2
    (a,) = s.select(["split"], cluster)
    assert a.node == 2


def test_prefetch_requests_deduplicated():
    wf = make_wf()
    s = ProactiveScheduler(wf)
    cluster = FakeCluster([0], {"raw": Placement((1,)),
                                "part_a": Placement((1,))})
    r1 = s.preplace(["filter_a"], cluster, {})
    r2 = s.preplace(["filter_a"], cluster, {})
    assert not r2 or set((r.data_name, r.dst) for r in r2).isdisjoint(
        set((r.data_name, r.dst) for r in r1))


def test_speed_aware_avoids_straggler():
    wf = make_wf()
    s = LocalityScheduler(wf, speed_aware=True)
    # node 0 holds the data but is 100x slower
    cluster = FakeCluster([0, 1], {"raw": Placement((0,))},
                          speeds={0: 0.01})
    (a,) = s.select(["split"], cluster)
    assert a.node == 1


# ---------------------------------------------------------------- PR 6 fixes

def test_preplace_without_free_workers_skips_instead_of_node0():
    """No free worker + no alive-node signal: preplace must NOT invent a
    pre-assignment (the old `or [0]` fallback pre-assigned node 0 even when
    node 0 was the failed one)."""
    wf = make_wf()
    s = ProactiveScheduler(wf)
    cluster = FakeCluster([], {"raw": Placement((1,))})
    reqs = s.preplace(["split"], cluster, {})
    assert "split" not in s.preassignment
    assert reqs == []


def test_preplace_without_free_workers_falls_back_to_alive_nodes():
    class AliveCluster(FakeCluster):
        def alive_nodes(self):
            return [2, 3]

    wf = make_wf()
    s = ProactiveScheduler(wf)
    cluster = AliveCluster([], {"raw": Placement((1,))})
    s.preplace(["split"], cluster, {})
    assert s.preassignment.get("split") in (2, 3)


def test_store_events_invalidate_prefetch_markers_and_preassignments():
    """A replica lost to drop_node / delete must become re-prefetchable, and
    pre-assignments onto the dead node must not linger."""
    from repro.core import LocStore

    wf = make_wf()
    s = ProactiveScheduler(wf)
    store = LocStore(4)
    s.attach_store(store)
    store.put("raw", b"x", loc=1)
    s._prefetched["raw"] = {1, 2}
    s.preassignment["split"] = 2
    store.drop_node(2)
    assert 2 not in s._prefetched.get("raw", set())
    assert "split" not in s.preassignment
    store.delete("raw")
    assert "raw" not in s._prefetched


def test_eviction_off_prefetch_target_reopens_prefetch():
    """Evicting the replica off its prefetch target (placement shrinks via a
    record event) clears that node's emitted-marker."""
    from repro.core import LocStore

    wf = make_wf()
    s = ProactiveScheduler(wf)
    store = LocStore(4)
    s.attach_store(store)
    store.put("raw", b"x", loc=1)
    store.replicate("raw", [2])
    s._prefetched["raw"] = {2}
    store.forget_replica("raw", 2)
    assert 2 not in s._prefetched.get("raw", set())


def test_fcfs_rotor_stable_within_multi_assignment_tick():
    """The old rotor indexed a list that shrank as the loop assigned, so the
    stride drifted toward low ids within one tick. The fixed rotor strides
    over the tick-stable ordering: n assignments hit n distinct consecutive
    positions, and the next tick resumes where this one stopped."""
    wf = make_wf()
    s = FCFSScheduler(wf)
    a = s.select(["split", "filter_a"], FakeCluster([0, 1, 2, 3], {}))
    assert [x.node for x in a] == [0, 1]
    b = s.select(["filter_b", "analyze_a"], FakeCluster([0, 1, 2, 3], {}))
    assert [x.node for x in b] == [2, 3]
