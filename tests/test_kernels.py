"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import decode_attention_op, window_slice

RNG = np.random.default_rng(42)


def mk(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def max_err(a, b):
    return float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())


FLASH_CASES = [
    # B, Sq, Sk, Hq, Hkv, hd, causal, window, off
    (2, 128, 128, 4, 2, 64, True, 0, 0),
    (1, 100, 100, 4, 4, 72, True, 0, 0),       # unaligned seq + head dim
    (2, 64, 192, 8, 2, 64, True, 0, 128),      # suffix prefill offset
    (2, 256, 256, 4, 2, 64, True, 64, 0),      # sliding window (gemma local)
    (1, 96, 160, 2, 2, 48, False, 0, 0),       # bidirectional (encoder)
    (1, 64, 64, 8, 1, 128, True, 0, 0),        # MQA
    (2, 80, 80, 6, 3, 240, True, 0, 0),        # gemma3-12b head dim
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=[f"flash{i}" for i in range(len(FLASH_CASES))])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, Hq, Hkv, hd, causal, win, off = case
    q, k, v = (mk((B, Sq, Hq, hd), dtype), mk((B, Sk, Hkv, hd), dtype),
               mk((B, Sk, Hkv, hd), dtype))
    out = flash_attention(q, k, v, causal=causal, window=win, q_offset=off,
                          interpret=True, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=win,
                                   q_offset=off)
    tol = 0.05 if dtype == jnp.bfloat16 else 2e-5
    assert max_err(out, want) < tol


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (128, 128)])
def test_flash_attention_block_shape_invariance(block_q, block_k):
    q, k, v = (mk((1, 130, 4, 64), jnp.float32),
               mk((1, 130, 2, 64), jnp.float32),
               mk((1, 130, 2, 64), jnp.float32))
    out = flash_attention(q, k, v, causal=True, interpret=True,
                          block_q=block_q, block_k=block_k)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert max_err(out, want) < 2e-5


DECODE_CASES = [
    # B, S, Hq, Hkv, hd, window
    (2, 256, 4, 2, 64, 0),
    (2, 300, 8, 8, 80, 0),        # unaligned cache + head dim
    (3, 512, 4, 2, 64, 128),      # sliding window decode
    (1, 64, 2, 1, 32, 16),
    (2, 1024, 16, 2, 128, 0),     # long cache, high group count
]


@pytest.mark.parametrize("case", DECODE_CASES,
                         ids=[f"dec{i}" for i in range(len(DECODE_CASES))])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_decode_attention_matches_ref(case, dtype):
    B, S, Hq, Hkv, hd, win = case
    q = mk((B, Hq, hd), dtype)
    kc, vc = mk((B, S, Hkv, hd), dtype), mk((B, S, Hkv, hd), dtype)
    lengths = jnp.asarray(RNG.integers(1, S + 1, (B,)), jnp.int32)
    out = decode_attention(q, kc, vc, lengths, window=win, interpret=True,
                           block_k=64)
    want = ref.decode_attention_ref(q, kc, vc, lengths, window=win)
    tol = 0.05 if dtype == jnp.bfloat16 else 2e-5
    assert max_err(out, want) < tol


def test_decode_length_one_edge():
    q = mk((1, 2, 64), jnp.float32)
    kc, vc = mk((1, 128, 2, 64), jnp.float32), mk((1, 128, 2, 64), jnp.float32)
    lengths = jnp.asarray([1], jnp.int32)
    out = decode_attention(q, kc, vc, lengths, interpret=True, block_k=32)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    assert max_err(out, want) < 2e-5


@pytest.mark.parametrize("S,W,lens", [
    (1024, 100, [900, 310]), (1024, 100, [50, 1024]),
    (512, 512, [512, 33]), (256, 300, [100, 256]),
])
def test_window_slice_equivalence(S, W, lens):
    """Sliced-cache decode == full-cache windowed decode (the long-context
    decode optimization for sliding-window layers)."""
    B, H, hd = 2, 2, 64
    kc, vc = mk((B, S, H, hd), jnp.float32), mk((B, S, H, hd), jnp.float32)
    q = mk((B, 4, hd), jnp.float32)
    lengths = jnp.asarray(lens, jnp.int32)
    ks, lk = window_slice(kc, lengths, W, block=128)
    vs, _ = window_slice(vc, lengths, W, block=128)
    out = decode_attention_op(q, ks, vs, lk, window=W)
    want = decode_attention_op(q, kc, vc, lengths, window=W)
    assert max_err(out, want) < 1e-5
    assert ks.shape[1] <= min(S, W + 2 * 128)
