"""Cross-check: simulator-reported NIC/tier traffic must equal the LocStore's
Transfer/TierHop ledger for the same workload trace (PR 3 satellite — catches
the class of spill-accounting bugs found in the PR 2 review: bytes counted in
a scalar but missing from the transfer log, or vice versa).
"""

import pytest

from repro.core import (HPC_CLUSTER, LocalityScheduler, ProactiveScheduler,
                        StorageHierarchy, TierSpec, compile_workflow)
from repro.core.locstore import LocStore, REMOTE_TIER, SimObject
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import fig2_workflow, montage_workflow

GB = float(1 << 30)

SPILL_KINDS = ("demote", "spill", "writeback", "writearound")


def recompute_from_transfers(store: LocStore) -> dict:
    """Re-derive every scalar movement counter from the transfer ledger."""
    fetches = [t for t in store.transfers if t.kind == "fetch"]
    migrates = [t for t in store.transfers if t.kind == "migrate"]
    spills = [t for t in store.transfers
              if t.kind in SPILL_KINDS and t.dst == REMOTE_TIER]
    demotes = [t for t in store.transfers if t.kind == "demote"]
    writebacks = [t for t in store.transfers if t.kind == "writeback"]
    tier_reads: dict[str, float] = {}
    for t in fetches:
        tier_reads[t.src_tier] = tier_reads.get(t.src_tier, 0.0) + t.nbytes
    return {
        "bytes_local": sum(t.nbytes for t in fetches if t.local),
        "bytes_moved": (sum(t.nbytes for t in fetches if not t.local)
                        + sum(t.nbytes for t in migrates)
                        + sum(t.nbytes for t in spills)),
        "remote_bytes": (sum(t.nbytes for t in fetches if not t.local
                             and (t.src == REMOTE_TIER or t.dst == REMOTE_TIER))
                         + sum(t.nbytes for t in migrates
                               if t.src == REMOTE_TIER or t.dst == REMOTE_TIER)
                         + sum(t.nbytes for t in spills)),
        "bytes_demoted": (sum(t.nbytes for t in demotes)
                          + sum(t.nbytes for t in writebacks)),
        "demotions": len(demotes) + len(writebacks),
        "writebacks": len(writebacks),
        "writeback_bytes": sum(t.nbytes for t in writebacks),
        "tier_reads": tier_reads,
    }


def assert_ledger_balances(store: LocStore) -> None:
    got = store.movement_report()
    want = recompute_from_transfers(store)
    for key in ("bytes_local", "bytes_moved", "remote_bytes", "bytes_demoted",
                "writeback_bytes"):
        assert got[key] == pytest.approx(want[key]), key
    assert got["demotions"] == want["demotions"]
    assert got["writebacks"] == want["writebacks"]
    # per-tier read traffic balances too
    rep = store.tier_report()
    for tier, nb in want["tier_reads"].items():
        assert rep[tier]["bytes_read"] == pytest.approx(nb), tier
    # every hop in every transfer describes the transferred object, nothing
    # else (the PR 2 hop-attribution rule)
    for t in store.transfers:
        assert all(h.nbytes == t.nbytes for h in t.hops), t


def _tiered(cap):
    return StorageHierarchy(
        [TierSpec("hbm", cap / 4, 819e9),
         TierSpec("host", cap, 100e9),
         TierSpec("bb", 16 * cap, 8e9)],
        remote=TierSpec("remote", float("inf"), 0.5e9))


def _flat_capped(cap):
    return StorageHierarchy([TierSpec("host", cap, 100e9)],
                            remote=TierSpec("remote", float("inf"), 0.5e9))


class TestSimulatorTraceBalances:
    @pytest.mark.parametrize("policy,coord", [
        ("through", False), ("back", False), ("back", True)],
        ids=["through", "back", "back+coord"])
    def test_montage_under_pressure(self, policy, coord):
        wf = compile_workflow(montage_workflow(16), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER, hierarchy=_tiered(0.25 * GB),
                                write_policy=policy,
                                coordinated_eviction=coord)
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
        assert_ledger_balances(sim.store)
        # the SimResult the benchmarks report is the same ledger
        rep = sim.store.movement_report()
        assert r.bytes_moved == rep["bytes_moved"]
        assert r.remote_bytes == rep["remote_bytes"]
        assert r.bytes_demoted == rep["bytes_demoted"]
        assert r.writeback_bytes == rep["writeback_bytes"]

    def test_flat_capped_sweep_point(self):
        wf = compile_workflow(montage_workflow(16), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER, hierarchy=_flat_capped(0.5 * GB))
        sim.run()
        assert_ledger_balances(sim.store)

    def test_default_flat_fig2(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER)
        sim.run()
        assert_ledger_balances(sim.store)

    def test_failure_path_balances(self):
        wf = compile_workflow(montage_workflow(12), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=8,
                                hw=HPC_CLUSTER, hierarchy=_tiered(1 * GB),
                                write_policy="back", failures=[(1.0, 0)])
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
        assert_ledger_balances(sim.store)


class TestStoreLevelTraceBalances:
    def test_spill_heavy_trace(self):
        """Oversized puts, migrations and replicas — every byte in a scalar
        counter has a Transfer record behind it."""
        st = LocStore(2, hierarchy=_tiered(400 * 4.0))
        st.put("big", SimObject(16000.0), loc=0)        # fits nowhere: spill
        st.put("a", SimObject(300.0), loc=0)
        st.put("b", SimObject(300.0), loc=0)
        st.get("a", at=1)
        st.replicate("a", [1])
        st.migrate("b", 1)
        st.get("big", at=0)                             # PFS demand fetch
        assert_ledger_balances(st)

    def test_writeback_trace(self):
        st = LocStore(1, hierarchy=_tiered(400 * 4.0), write_policy="back")
        for i in range(12):
            st.put(f"o{i}", SimObject(350.0), loc=0)
        st.drain_writebacks()
        for i in range(12):
            st.get(f"o{i}", at=0)
        assert_ledger_balances(st)

    def test_writearound_trace(self):
        st = LocStore(2, hierarchy=_tiered(400 * 4.0))
        st.put("s", SimObject(100.0), loc=0, mode="around")
        st.get("s", at=1)
        st.get("s", at=0)
        assert_ledger_balances(st)
