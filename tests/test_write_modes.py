"""Compiler-emitted per-dataset write-mode pins (PR 4 satellite).

The compiler knows consumer counts: a produced dataset with exactly one
consumer whose locality-bound node is the producing node is pinned
``mode="around"`` (run-once streaming output — no other node ever reads it),
and the simulator can honor the pins (``honor_write_modes=True``).

PR 9 flips the default to ``"auto"``: pins the analyzer re-proves safe
(``repro.analysis.lint.safe_write_modes``) are honored by default — but only
in configurations where write-around can pay off (a finite node tier, a
locality-aware scheduler, stable membership).
"""

import pytest

from repro.core import (FCFSScheduler, HPC_CLUSTER, LocalityScheduler,
                        SimConfig, StorageHierarchy, TierSpec,
                        compile_workflow)
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import (fig2_workflow, montage_workflow,
                                  serving_session_workflow)

FINITE = StorageHierarchy(
    [TierSpec("hbm", 6e9, 800e9), TierSpec("bb", 12e9, 10e9)],
    remote=TierSpec("remote", float("inf"), 0.5e9))


class TestEmittedPins:
    def test_fig2_single_consumer_chains_pinned(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        # part_a -> filter_a is the consumer's ONLY input: co-located, pinned
        for name in ("part_a", "part_b", "fa", "fb"):
            assert wf.write_modes.get(name) == "around", name
            assert wf.graph.data[name].xattr.get("write_mode") == "around"

    def test_fig2_fanin_inputs_not_pinned(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        # ra/rb each feed merge at a 50/50 byte split: neither producer is a
        # strict majority, so the consumer's node is not predictable
        assert "ra" not in wf.write_modes
        assert "rb" not in wf.write_modes

    def test_externals_and_sinks_not_pinned(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        assert "raw" not in wf.write_modes       # external input
        assert "result" not in wf.write_modes    # zero consumers

    def test_multi_consumer_not_pinned(self):
        wf = compile_workflow(montage_workflow(8), HPC_CLUSTER)
        # proj<i> feeds diff tasks AND correct<i>: multiple consumers
        assert "proj0" not in wf.write_modes

    def test_serving_kv_chain_pinned(self):
        wf = compile_workflow(serving_session_workflow(2, 3), HPC_CLUSTER)
        # kv<s>_<t> dominates the next turn's input bytes (prompt is tiny)
        assert wf.write_modes.get("kv0_0") == "around"
        assert wf.write_modes.get("kv0_1") == "around"
        assert "kv0_2" not in wf.write_modes     # final turn: no consumer


class TestSimulatorHonorsPins:
    def test_default_ignores_pins_without_capacity_pressure(self):
        # honor_write_modes="auto": with no finite node tier, write-around
        # has nothing to save, so the pins stay inert (the PR-4 default)
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER)
        sim.run()
        assert sim.store.write_mode("part_a") == "through"

    def test_honor_write_modes_streams_pinned_outputs(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER, honor_write_modes=True)
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
        assert sim.store.write_mode("part_a") == "around"
        # around outputs live on the PFS only — they never occupy node tiers
        assert sim.store.stat("part_a").tier_on(
            sim.store.stat("part_a").real_loc) == "remote"
        # unpinned datasets keep the store default
        assert sim.store.write_mode("ra") == "through"


class TestAutoGate:
    """honor_write_modes="auto" (the PR 9 default): analyzer-proven pins are
    honored exactly when the config can profit from them."""

    def run_fig2(self, **kw):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        sched_cls = kw.pop("sched_cls", LocalityScheduler)
        cfg = SimConfig.from_kwargs(n_nodes=4, hw=HPC_CLUSTER, **kw)
        sim = WorkflowSimulator(wf, sched_cls(wf), config=cfg)
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
        return sim

    def test_auto_honors_under_finite_tiers_and_locality(self):
        sim = self.run_fig2(hierarchy=FINITE)
        assert sim.store.write_mode("part_a") == "around"
        assert any(t.kind == "writearound" for t in sim.store.transfers)
        # unsafe/unpinned datasets stay on the default path
        assert sim.store.write_mode("ra") == "through"

    def test_auto_off_with_failures(self):
        # rerun recovery refetches inputs: a PFS-only sole copy turns every
        # recovery read into a remote fetch, so membership churn disables auto
        sim = self.run_fig2(hierarchy=FINITE, failures=[(5.0, 1)])
        assert sim.store.write_mode("part_a") == "through"
        assert sim._write_modes == {}

    def test_auto_off_for_non_locality_scheduler(self):
        # FCFS does not bind consumers to data: co-scheduling is unprovable
        sim = self.run_fig2(hierarchy=FINITE, sched_cls=FCFSScheduler)
        assert sim.store.write_mode("part_a") == "through"

    def test_explicit_false_beats_auto(self):
        sim = self.run_fig2(hierarchy=FINITE, honor_write_modes=False)
        assert sim.store.write_mode("part_a") == "through"
        assert not any(t.kind == "writearound" for t in sim.store.transfers)

    def test_explicit_true_is_legacy_unguarded(self):
        # True keeps the PR-4 semantics: every compiler pin, no runtime guard
        sim = self.run_fig2(honor_write_modes=True)
        assert sim.store.write_mode("part_a") == "around"

    def test_invalid_value_rejected(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        with pytest.raises(ValueError, match="honor_write_modes"):
            WorkflowSimulator(wf, LocalityScheduler(wf),
                              config=SimConfig(n_nodes=4, hw=HPC_CLUSTER,
                                               honor_write_modes="yes"))
