"""Compiler-emitted per-dataset write-mode pins (PR 4 satellite).

The compiler knows consumer counts: a produced dataset with exactly one
consumer whose locality-bound node is the producing node is pinned
``mode="around"`` (run-once streaming output — no other node ever reads it),
and the simulator can honor the pins (``honor_write_modes=True``).
"""

from repro.core import HPC_CLUSTER, LocalityScheduler, compile_workflow
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import (fig2_workflow, montage_workflow,
                                  serving_session_workflow)


class TestEmittedPins:
    def test_fig2_single_consumer_chains_pinned(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        # part_a -> filter_a is the consumer's ONLY input: co-located, pinned
        for name in ("part_a", "part_b", "fa", "fb"):
            assert wf.write_modes.get(name) == "around", name
            assert wf.graph.data[name].xattr.get("write_mode") == "around"

    def test_fig2_fanin_inputs_not_pinned(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        # ra/rb each feed merge at a 50/50 byte split: neither producer is a
        # strict majority, so the consumer's node is not predictable
        assert "ra" not in wf.write_modes
        assert "rb" not in wf.write_modes

    def test_externals_and_sinks_not_pinned(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        assert "raw" not in wf.write_modes       # external input
        assert "result" not in wf.write_modes    # zero consumers

    def test_multi_consumer_not_pinned(self):
        wf = compile_workflow(montage_workflow(8), HPC_CLUSTER)
        # proj<i> feeds diff tasks AND correct<i>: multiple consumers
        assert "proj0" not in wf.write_modes

    def test_serving_kv_chain_pinned(self):
        wf = compile_workflow(serving_session_workflow(2, 3), HPC_CLUSTER)
        # kv<s>_<t> dominates the next turn's input bytes (prompt is tiny)
        assert wf.write_modes.get("kv0_0") == "around"
        assert wf.write_modes.get("kv0_1") == "around"
        assert "kv0_2" not in wf.write_modes     # final turn: no consumer


class TestSimulatorHonorsPins:
    def test_default_ignores_pins(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER)
        sim.run()
        assert sim.store.write_mode("part_a") == "through"

    def test_honor_write_modes_streams_pinned_outputs(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        sim = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER, honor_write_modes=True)
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
        assert sim.store.write_mode("part_a") == "around"
        # around outputs live on the PFS only — they never occupy node tiers
        assert sim.store.stat("part_a").tier_on(
            sim.store.stat("part_a").real_loc) == "remote"
        # unpinned datasets keep the store default
        assert sim.store.write_mode("ra") == "through"
