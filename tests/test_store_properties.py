"""Property-based tests for StorageHierarchy/LocStore (PR 3 satellite).

Under arbitrary put/get/replicate/promote/migrate/drain/delete sequences:

  * no dataset is ever lost (everything put and not deleted stays resolvable),
  * per-(node, tier) capacity is never exceeded,
  * `tier_report` byte totals balance against the residency map
    (conservation invariant), and usage counters agree with residency.

Runs in two modes: a deterministic seeded fuzzer that always executes, and a
hypothesis-driven variant when the library is installed (the container may
not ship it — same importorskip guard as test_dag_properties).
"""

import random

import pytest

from repro.core.locstore import (LocStore, Placement, REMOTE_TIER, SimObject,
                                 StorageHierarchy, TierSpec)

try:
    import hypothesis
    from hypothesis import strategies as hst
    HAS_HYPOTHESIS = True
except ImportError:                               # pragma: no cover
    HAS_HYPOTHESIS = False

N_NODES = 3
NAMES = [f"d{i}" for i in range(8)]
TIERS = ("hbm", "host", "bb", None)
MODES = (None, "through", "back", "around")


def small_hierarchy():
    return StorageHierarchy(
        [TierSpec("hbm", 100.0, 800e9),
         TierSpec("host", 200.0, 100e9),
         TierSpec("bb", 300.0, 8e9)],
        remote=TierSpec("remote", float("inf"), 2e9))


def check_invariants(st: LocStore, live: set[str]) -> None:
    """The conservation/capacity/balance invariants every op must preserve."""
    # 1. conservation: nothing put (and not deleted) is ever lost
    for name in live:
        assert st.exists(name), f"{name} was lost"
        assert st._residency.get(name), f"{name} resolvable but replica-free"
    # 2. residency <-> usage agreement, capacity never exceeded
    usage: dict[tuple[int, str], float] = {}
    for name, res in st._residency.items():
        assert name in st._values and name in st._sizes
        for node, tier in res.items():
            if node == REMOTE_TIER:
                assert tier == "remote"
                continue
            assert st.hierarchy.is_node_tier(tier), (name, node, tier)
            key = (node, tier)
            usage[key] = usage.get(key, 0.0) + st._sizes[name]
    for key, used in usage.items():
        assert st._usage.get(key, 0.0) == pytest.approx(used), key
        assert used <= st.hierarchy.capacity(key[1]) + 1e-9, (
            f"capacity exceeded at {key}: {used}")
    for key, used in st._usage.items():
        assert used == pytest.approx(usage.get(key, 0.0)), key
    # 3. tier_report byte totals balance with the residency map
    rep = st.tier_report()
    per_tier: dict[str, float] = {}
    replicas: dict[str, int] = {}
    for res in st._residency.values():
        for node, tier in res.items():
            replicas[tier] = replicas.get(tier, 0) + 1
    for (node, tier), used in usage.items():
        per_tier[tier] = per_tier.get(tier, 0.0) + used
    for tier in st.hierarchy.names():
        assert rep[tier]["resident_bytes"] == pytest.approx(
            per_tier.get(tier, 0.0)), tier
        assert rep[tier]["replicas"] == replicas.get(tier, 0), tier
    # 4. the location service mirrors residency
    for name in st.loc.names():
        p = st.loc.lookup(name)
        assert p is not None and name in st._residency


def apply_op(st: LocStore, op: tuple, live: set[str]) -> None:
    """One fuzzed store operation (total: never raises for valid sequences)."""
    kind = op[0]
    if kind == "put":
        _, name, size, node, tier, mode = op
        st.put(name, SimObject(float(size)), loc=node, tier=tier, mode=mode)
        live.add(name)
    elif kind == "put_replicated":
        _, name, size, nodes = op
        st.put(name, SimObject(float(size)), loc=tuple(nodes))
        live.add(name)
    elif kind == "put_pfs":
        _, name, size = op
        st.put(name, SimObject(float(size)),
               loc=Placement((REMOTE_TIER,), tier="remote"))
        live.add(name)
    elif kind == "get":
        _, name, at = op
        if name in live:
            st.get(name, at=at)
    elif kind == "replicate":
        _, name, node, tier = op
        if name in live:
            st.replicate(name, [node], tier=tier)
    elif kind == "promote":
        _, name, node, tier = op
        if name in live and node in st._residency.get(name, {}):
            st.promote(name, node, tier)
    elif kind == "migrate":
        _, name, node = op
        if name in live:
            st.migrate(name, node)
    elif kind == "drain":
        st.drain_writebacks()
    elif kind == "delete":
        _, name = op
        if name in live:
            st.delete(name)
            live.discard(name)
    elif kind == "forget":
        _, name, node = op
        if name in live:
            res = st._residency.get(name, {})
            if len(res) > 1 and node in res:   # never forget the last copy
                st.forget_replica(name, node)


def random_op(rng: random.Random) -> tuple:
    name = rng.choice(NAMES)
    kind = rng.choices(
        ["put", "put_replicated", "put_pfs", "get", "replicate", "promote",
         "migrate", "drain", "delete", "forget"],
        weights=[30, 6, 4, 25, 10, 6, 5, 6, 4, 4])[0]
    if kind == "put":
        mode = rng.choice(MODES)
        # an around-put cannot carry a tier pin (the store rejects the combo)
        tier = None if mode == "around" else rng.choice(TIERS)
        return (kind, name, rng.choice([10, 40, 90, 150, 250, 500]),
                rng.randrange(N_NODES), tier, mode)
    if kind == "put_replicated":
        return (kind, name, rng.choice([10, 40, 90]),
                rng.sample(range(N_NODES), k=2))
    if kind == "put_pfs":
        return (kind, name, rng.choice([10, 90, 500]))
    if kind == "get":
        return (kind, name, rng.randrange(N_NODES))
    if kind in ("replicate", "promote"):
        return (kind, name, rng.randrange(N_NODES),
                rng.choice(("hbm", "host", "bb", None)))
    if kind in ("migrate", "forget"):
        return (kind, name, rng.randrange(N_NODES))
    if kind == "delete":
        return (kind, name)
    return (kind,)


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("store_kw", [
    {},                                             # write-through LRU
    {"write_policy": "back"},
    {"write_policy": "back", "coordinated_eviction": True},
    {"eviction_policy": "cost", "coordinated_eviction": True},
], ids=["through", "back", "back+coord", "cost+coord"])
def test_random_sequences_preserve_invariants(seed, store_kw):
    rng = random.Random(1000 + seed)
    st = LocStore(N_NODES, hierarchy=small_hierarchy(), **store_kw)
    live: set[str] = set()
    for step in range(120):
        apply_op(st, random_op(rng), live)
        if step % 10 == 9:
            check_invariants(st, live)
    st.drain_writebacks()
    check_invariants(st, live)
    # final: every surviving object still readable from every node
    for name in live:
        for node in range(N_NODES):
            value, _ = st.get(name, at=node)
            assert value is not None
    check_invariants(st, live)


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_sequences_preserve_invariants():
    op_strategy = hst.builds(
        random_op, hst.integers(min_value=0, max_value=2**31).map(random.Random))

    @hypothesis.given(
        ops=hst.lists(op_strategy, min_size=1, max_size=60),
        policy=hst.sampled_from(["through", "back"]),
        coord=hst.booleans())
    @hypothesis.settings(max_examples=40, deadline=None)
    def inner(ops, policy, coord):
        st = LocStore(N_NODES, hierarchy=small_hierarchy(),
                      write_policy=policy, coordinated_eviction=coord)
        live: set[str] = set()
        for op in ops:
            apply_op(st, op, live)
        check_invariants(st, live)
        st.drain_writebacks()
        check_invariants(st, live)

    inner()
