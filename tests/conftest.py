"""Test session config: CPU, single real device (the dry-run's 512 forced
host devices are set ONLY inside launch/dryrun.py, never here)."""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import repro  # noqa: E402,F401 — installs the jax API compat shims

