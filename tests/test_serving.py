"""Serving engine: continuous batching, slot isolation, location-aware
routing, and the tiered session lifecycle (KV caches as first-class
LocStore replicas: submit -> idle-park -> resume-promote -> finish)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.locstore import (LocStore, StorageHierarchy, TierSpec,
                                 tiered_hierarchy)
from repro.core.prefetch import PrefetchEngine
from repro.models import decode_step, init_params, prefill
from repro.serve.engine import (Router, ServingEngine, _cache_name,
                                _read_slot, _write_slot)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_generate_deterministic(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    out1 = eng.generate([5, 6, 7], max_new=6)
    eng2 = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    out2 = eng2.generate([5, 6, 7], max_new=6)
    assert out1 == out2
    assert len(out1) == 6


def test_batched_sessions_isolated(setup):
    """Two concurrent sessions decode as if they were alone (slot masking)."""
    cfg, params = setup
    solo = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    a_solo = solo.generate([1, 2, 3, 4], max_new=5)

    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    sa = eng.submit([1, 2, 3, 4])
    sb = eng.submit([9, 8, 7])
    for _ in range(4):
        eng.step()
    a_batched = eng.sessions[sa].tokens[:5]
    assert a_batched == a_solo[:5]


def test_write_slot_roundtrip(setup):
    cfg, params = setup
    from repro.models import init_decode_state
    pooled = init_decode_state(cfg, 4, 32)
    batch = {"tokens": jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)}
    batch["labels"] = batch["tokens"]
    _, single = prefill(cfg, params, batch, 32)
    merged = _write_slot(pooled, single, 2)
    # decode from slot 2 of merged equals decode from the single state
    tok = jnp.asarray([[7]], jnp.int32)
    l_single, _ = decode_step(cfg, params, single, tok)
    toks4 = jnp.zeros((4, 1), jnp.int32).at[2, 0].set(7)
    l_merged, _ = decode_step(cfg, params, merged, toks4)
    np.testing.assert_allclose(np.asarray(l_merged[2], np.float32),
                               np.asarray(l_single[0], np.float32),
                               rtol=2e-4, atol=2e-4)


def test_slots_recycled(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    s1 = eng.submit([1, 2])
    slot1 = eng.sessions[s1].slot
    eng.finish(s1)                   # releases the slot (slot -> None)
    s2 = eng.submit([3, 4])          # must not raise: slot recycled
    assert eng.sessions[s2].slot == slot1
    assert eng.sessions[s1].slot is None


def _tiered_store(n_nodes, kv_bytes, slots_per_node=2):
    """hbm holds exactly the live slots; parked sessions land in bb."""
    return LocStore(n_nodes, hierarchy=tiered_hierarchy(
        hbm_bytes=slots_per_node * kv_bytes,
        host_bytes=slots_per_node * kv_bytes,
        bb_bytes=float(1 << 30)), write_policy="back")


def test_submit_registers_true_kv_bytes(setup):
    """The zero-byte-placeholder bugfix: capacity accounting must see the
    session cache's real size, not 0 bytes hidden in an xattr."""
    cfg, params = setup
    probe = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    kv = probe.slot_bytes()
    assert kv > 0
    store = _tiered_store(1, kv)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                        store=store)
    sid = eng.submit([1, 2, 3])
    name = _cache_name(sid)
    assert store.getxattr(name, "size") == kv
    rep = store.tier_report()
    assert rep["hbm"]["resident_bytes"] == kv        # true bytes, top tier
    assert store.stat(name).tier_on(0) == "hbm"
    sid2 = eng.submit([4, 5])
    assert store.tier_report()["hbm"]["resident_bytes"] == 2 * kv
    eng.finish(sid)
    eng.finish(sid2)
    assert store.tier_report()["hbm"]["resident_bytes"] == 0.0


def test_session_lifecycle_submit_park_resume_finish(setup):
    cfg, params = setup
    probe = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    kv = probe.slot_bytes()
    store = _tiered_store(1, kv)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                        store=store)
    control = ServingEngine(cfg, params, max_batch=2, max_seq=64)

    sid = eng.submit([5, 6, 7])
    c_sid = control.submit([5, 6, 7])
    for _ in range(2):
        eng.step()
        control.step()
    # idle-demote: the KV slice moves to the burst-buffer tier, slot frees
    eng.park(sid)
    name = _cache_name(sid)
    assert eng.sessions[sid].slot is None
    assert eng.can_admit()
    assert store.stat(name).tier_on(0) == "bb"
    assert store.tier_report()["bb"]["resident_bytes"] == kv
    # resume-promote: back to hbm, slot re-hydrated from the stored slice —
    # NO re-prefill, and decode continues bit-identically to never parking
    prefills_before = eng.prefills
    assert eng.resume(sid)
    assert eng.prefills == prefills_before
    assert eng.rehydrates == 1
    assert store.stat(name).tier_on(0) == "hbm"
    for _ in range(2):
        eng.step()
        control.step()
    assert eng.sessions[sid].tokens == control.sessions[c_sid].tokens
    # finish deletes the replica
    eng.finish(sid)
    assert not store.exists(name)
    assert store.tier_report()["hbm"]["resident_bytes"] == 0.0


def test_park_idle_sweep(setup):
    cfg, params = setup
    probe = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    store = _tiered_store(1, probe.slot_bytes())
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                        store=store)
    s1 = eng.submit([1, 2])
    s2 = eng.submit([3, 4])          # s2 touched after s1
    parked = eng.park_idle(max_idle=0)   # stale == anything but the newest
    assert parked == [s1]
    assert eng.sessions[s1].slot is None
    assert eng.sessions[s2].slot is not None


def test_read_slot_inverts_write_slot(setup):
    cfg, params = setup
    from repro.models import init_decode_state
    pooled = init_decode_state(cfg, 4, 32)
    template = init_decode_state(cfg, 1, 32)
    batch = {"tokens": jnp.asarray([[3, 1, 4]], jnp.int32)}
    batch["labels"] = batch["tokens"]
    _, single = prefill(cfg, params, batch, 32)
    merged = _write_slot(pooled, single, 2)
    back = _read_slot(merged, template, 2)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(single)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_router_routes_to_cache_holder(setup):
    cfg, params = setup
    store = LocStore(2)
    engines = [ServingEngine(cfg, params, max_batch=2, max_seq=64, node=i,
                             store=store) for i in range(2)]
    router = Router(engines, store)
    eng = router.engine_for()
    sid = eng.submit([1, 2, 3])
    # a follow-up for this session must land on the same engine
    again = router.engine_for(sid)
    assert again.node == eng.node
    assert router.locality_hits == 1
    # unknown session falls through to load balancing
    other = router.engine_for(99_999)
    assert router.locality_misses == 1
    assert other.can_admit()


def test_router_full_engine_locality_hit_falls_through(setup):
    """The PR 4 router bugfix: a locality hit whose engine cannot admit the
    session must fall through to load balancing (counted as a distinct
    locality_evictions stat) instead of letting the caller hit 'engine
    full'."""
    cfg, params = setup
    probe = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    store = _tiered_store(2, probe.slot_bytes(), slots_per_node=1)
    engines = [ServingEngine(cfg, params, max_batch=1, max_seq=64, node=i,
                             store=store) for i in range(2)]
    e0, e1 = engines
    # e1 has a measured prefill cost and a free slot (a migrate target)
    warm = e1.submit([7, 7])
    e1.finish(warm)
    router = Router(engines, store, allow_park=False)   # flat-pinning rules
    sid = e0.submit([1, 2, 3])
    e0.park(sid)                     # parked: resuming needs a slot
    blocker = e0.submit([9, 9])      # ...but e0's only slot is taken
    assert not e0.can_admit()
    target = router.engine_for(sid)  # must NOT return the full holder
    assert target is e1
    assert router.locality_evictions == 1
    assert router.locality_hits == 0
    # follow_up completes the migration without an 'engine full' error
    hist = list(e0.sessions[sid].tokens)
    d = router.follow_up(sid, hist)
    assert d.engine is e1 and d.sid != sid
    assert d.kind == "migrate" and d.prefilled and not d.resumed
    assert router.migrations == 1
    assert e0.sessions[sid].done     # the holder dropped the stale session
    assert e0.sessions[blocker].slot is not None    # blocker untouched


def test_router_resumes_parked_session_by_parking_victim(setup):
    """With parking allowed and no cheap migrate target, a follow-up to a
    full engine parks the LRU victim and re-hydrates in place — zero
    re-prefills."""
    cfg, params = setup
    probe = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    store = _tiered_store(2, probe.slot_bytes(), slots_per_node=1)
    engines = [ServingEngine(cfg, params, max_batch=1, max_seq=64, node=i,
                             store=store) for i in range(2)]
    e0, e1 = engines                 # e1 idle: no measured prefill -> inf
    router = Router(engines, store)
    sid = e0.submit([1, 2, 3])
    e0.park(sid)
    blocker = e0.submit([9, 9])
    prefills = e0.prefills
    d = router.follow_up(sid, [1, 2, 3])
    assert d.engine is e0 and d.sid == sid
    assert d.kind == "hit_parked" and d.resumed and not d.prefilled
    assert e0.sessions[sid].slot is not None         # re-hydrated
    assert e0.sessions[blocker].slot is None         # victim parked
    assert e0.prefills == prefills                   # no re-prefill
    assert router.locality_hits == 1
    assert e0.resumes == 1


def test_router_pressure_prefers_fast_migrate(setup):
    """Tier-awareness: when the parked cache sits behind a glacial medium,
    the priced resume loses to a re-prefill on a free engine."""
    cfg, params = setup
    probe = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    kv = probe.slot_bytes()
    # burst buffer at 10 B/s: promoting the parked KV costs ~kv/10 seconds
    store = LocStore(2, hierarchy=StorageHierarchy(
        [TierSpec("hbm", kv, 819e9), TierSpec("bb", float(1 << 30), 10.0)],
        remote=TierSpec("remote", float("inf"), 2e9)))
    engines = [ServingEngine(cfg, params, max_batch=1, max_seq=64, node=i,
                             store=store) for i in range(2)]
    e0, e1 = engines
    warm = e1.submit([7, 7])         # measured (fast) prefill on e1
    e1.finish(warm)
    router = Router(engines, store)
    sid = e0.submit([1, 2, 3])
    e0.park(sid)
    assert e0.can_admit()            # a slot IS free: only cost disqualifies
    target = router.engine_for(sid)
    assert target is e1
    assert router.locality_evictions == 1


def test_router_warm_promotes_parked_cache(setup):
    cfg, params = setup
    probe = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    store = _tiered_store(1, probe.slot_bytes())
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                        store=store)
    prefetch = PrefetchEngine(store)
    router = Router([eng], store, prefetch=prefetch)
    sid = eng.submit([1, 2, 3])
    eng.park(sid)
    assert store.stat(_cache_name(sid)).tier_on(0) == "bb"
    assert router.warm(sid)
    prefetch.drain()
    assert store.stat(_cache_name(sid)).tier_on(0) == "hbm"
    assert router.warmups == 1
    assert not router.warm(99_999)   # unknown session: no-op
    prefetch.shutdown()
