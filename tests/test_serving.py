"""Serving engine: continuous batching, slot isolation, location-aware
routing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.locstore import LocStore
from repro.models import decode_step, init_params, prefill
from repro.serve.engine import Router, ServingEngine, _write_slot


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_generate_deterministic(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    out1 = eng.generate([5, 6, 7], max_new=6)
    eng2 = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    out2 = eng2.generate([5, 6, 7], max_new=6)
    assert out1 == out2
    assert len(out1) == 6


def test_batched_sessions_isolated(setup):
    """Two concurrent sessions decode as if they were alone (slot masking)."""
    cfg, params = setup
    solo = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    a_solo = solo.generate([1, 2, 3, 4], max_new=5)

    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    sa = eng.submit([1, 2, 3, 4])
    sb = eng.submit([9, 8, 7])
    for _ in range(4):
        eng.step()
    a_batched = eng.sessions[sa].tokens[:5]
    assert a_batched == a_solo[:5]


def test_write_slot_roundtrip(setup):
    cfg, params = setup
    from repro.models import init_decode_state
    pooled = init_decode_state(cfg, 4, 32)
    batch = {"tokens": jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)}
    batch["labels"] = batch["tokens"]
    _, single = prefill(cfg, params, batch, 32)
    merged = _write_slot(pooled, single, 2)
    # decode from slot 2 of merged equals decode from the single state
    tok = jnp.asarray([[7]], jnp.int32)
    l_single, _ = decode_step(cfg, params, single, tok)
    toks4 = jnp.zeros((4, 1), jnp.int32).at[2, 0].set(7)
    l_merged, _ = decode_step(cfg, params, merged, toks4)
    np.testing.assert_allclose(np.asarray(l_merged[2], np.float32),
                               np.asarray(l_single[0], np.float32),
                               rtol=2e-4, atol=2e-4)


def test_slots_recycled(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    s1 = eng.submit([1, 2])
    eng.finish(s1)
    s2 = eng.submit([3, 4])          # must not raise: slot recycled
    assert eng.sessions[s2].slot == eng.sessions[s1].slot


def test_router_routes_to_cache_holder(setup):
    cfg, params = setup
    store = LocStore(2)
    engines = [ServingEngine(cfg, params, max_batch=2, max_seq=64, node=i,
                             store=store) for i in range(2)]
    router = Router(engines, store)
    eng = router.engine_for()
    sid = eng.submit([1, 2, 3])
    # a follow-up for this session must land on the same engine
    again = router.engine_for(sid)
    assert again.node == eng.node
    assert router.locality_hits == 1
    # unknown session falls through to load balancing
    other = router.engine_for(99_999)
    assert router.locality_misses == 1
    assert other.can_admit()
