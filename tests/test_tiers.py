"""Tier machinery: eviction/demotion under capacity pressure, promote-on-
access, per-tier Transfer accounting, and tier-aware scheduling decisions."""

import pytest

from repro.core import (HPC_CLUSTER, LocalityScheduler, ProactiveScheduler,
                        StorageHierarchy, TierSpec, compile_workflow, simulate,
                        tiered_hierarchy)
from repro.core.locstore import LocStore, Placement, REMOTE_TIER, SimObject
from repro.core.prefetch import PrefetchEngine
from repro.core.workloads import fig2_workflow, montage_workflow

GB = float(1 << 30)


def small_hierarchy(cap=100.0):
    return StorageHierarchy(
        [TierSpec("hbm", cap, 800e9),
         TierSpec("host", 2 * cap, 100e9),
         TierSpec("bb", 4 * cap, 8e9)],
        remote=TierSpec("remote", float("inf"), 2e9))


class TestEvictionDemotion:
    def test_capacity_pressure_demotes_not_drops(self):
        st = LocStore(2, hierarchy=small_hierarchy(100))
        for i in range(10):                 # 900 bytes into 700 of node tiers
            st.put(f"o{i}", SimObject(90.0), loc=0)
        # nothing is ever dropped: every object still resolvable
        assert all(st.exists(f"o{i}") for i in range(10))
        assert st.demotions > 0 and st.bytes_demoted > 0
        # the freshest object sits in the top tier, the coldest spilled to PFS
        assert st.stat("o9").tier_on(0) == "hbm"
        assert st.stat("o0").resident_on(REMOTE_TIER)
        # per-node tier usage never exceeds capacity
        rep = st.tier_report()
        assert rep["hbm"]["resident_bytes"] <= 100
        assert rep["host"]["resident_bytes"] <= 200
        assert rep["bb"]["resident_bytes"] <= 400

    def test_demotions_recorded_as_transfers(self):
        st = LocStore(1, hierarchy=small_hierarchy(100))
        st.put("a", SimObject(90.0), loc=0)
        st.put("b", SimObject(90.0), loc=0)   # evicts a: hbm -> host
        demotes = [t for t in st.transfers if t.kind == "demote"]
        assert demotes and demotes[0].name == "a"
        assert demotes[0].src_tier == "hbm" and demotes[0].dst_tier == "host"
        assert demotes[0].est_seconds > 0     # media time is charged

    def test_spill_to_remote_counts_network_bytes(self):
        st = LocStore(1, hierarchy=small_hierarchy(10))
        for i in range(20):
            st.put(f"o{i}", SimObject(9.0), loc=0)
        assert st.remote_bytes > 0
        assert any(t.kind == "demote" and t.dst == REMOTE_TIER
                   for t in st.transfers)

    def test_oversized_object_cascades_past_small_tiers(self):
        st = LocStore(1, hierarchy=small_hierarchy(100))
        p = st.put("big", SimObject(350.0), loc=0)   # only bb (400) fits it
        assert p.tier_on(0) == "bb"

    def test_skip_tier_cascade_still_counts_remote_spill(self):
        """A victim that outsizes the next tier down spills to the PFS — and
        that crossing must show up in remote_bytes and the Transfer dst."""
        h = StorageHierarchy([TierSpec("host", 200, 100e9),
                              TierSpec("bb", 100, 8e9)],
                             remote=TierSpec("remote", float("inf"), 2e9))
        st = LocStore(1, hierarchy=h)
        st.put("a", SimObject(150.0), loc=0)
        st.put("b", SimObject(150.0), loc=0)   # a: host -> (bb too small) -> PFS
        assert st.stat("a").resident_on(REMOTE_TIER)
        assert st.remote_bytes == 150.0 and st.bytes_moved == 150.0
        (d,) = [t for t in st.transfers if t.kind == "demote"]
        assert d.dst == REMOTE_TIER and d.dst_tier == "remote"

    def test_put_oversized_everywhere_counts_remote_spill(self):
        st = LocStore(1, hierarchy=small_hierarchy(100))
        st.put("huge", SimObject(500.0), loc=0)   # fits no node tier
        assert st.stat("huge").resident_on(REMOTE_TIER)
        assert st.remote_bytes == 500.0
        # but pinning data ON the PFS is its origin, not a movement
        st2 = LocStore(1, hierarchy=small_hierarchy(100))
        st2.put("ext", SimObject(500.0),
                loc=Placement((REMOTE_TIER,), tier="remote"))
        assert st2.remote_bytes == 0.0

    def test_cost_aware_eviction_prefers_large_cold(self):
        h = StorageHierarchy([TierSpec("hbm", 100, 800e9)],
                             remote=TierSpec("remote", float("inf"), 2e9))
        st = LocStore(1, hierarchy=h, eviction_policy="cost",
                      promote_on_access=False)
        st.put("large", SimObject(60.0), loc=0)
        st.put("small", SimObject(10.0), loc=0)
        st.get("large", at=0)                  # large is now the most recent
        st.put("new", SimObject(60.0), loc=0)  # must evict something
        # plain LRU would evict "small" (oldest); cost-aware picks the big one
        assert st.stat("large").resident_on(REMOTE_TIER)
        assert st.stat("small").tier_on(0) == "hbm"


class TestReplicaLifecycle:
    def test_migrate_normalizes_foreign_tier_names(self):
        """A Placement whose tier name isn't in this hierarchy (legacy 'host'
        against an hbm-only store) must land on the node's top tier, not get
        silently stranded on the PFS."""
        h = StorageHierarchy([TierSpec("hbm", 1000.0, 800e9)],
                             remote=TierSpec("remote", float("inf"), 2e9))
        st = LocStore(2, hierarchy=h)
        st.put("x", SimObject(10.0), loc=0)
        st.migrate("x", Placement(nodes=(1,)))      # default tier "host"
        assert st.stat("x").real_loc == 1
        assert st.stat("x").tier_on(1) == "hbm"

    def test_forget_last_replica_deletes_object(self):
        st = LocStore(2)
        st.put("x", SimObject(10.0), loc=0)
        st.forget_replica("x", 0)
        assert not st.exists("x")
        # with a surviving replica the object stays resolvable
        st.put("y", SimObject(10.0), loc=(0, 1))
        st.forget_replica("y", 0)
        assert st.exists("y") and st.stat("y").nodes == (1,)


class TestPromoteOnAccess:
    def test_get_promotes_to_top_tier(self):
        st = LocStore(1, hierarchy=small_hierarchy(100))
        st.put("a", SimObject(90.0), loc=0)
        st.put("b", SimObject(90.0), loc=0)    # a demoted to host
        assert st.stat("a").tier_on(0) == "host"
        _, tr = st.get("a", at=0)
        assert tr.local
        assert st.stat("a").tier_on(0) == "hbm"
        assert st.promotions >= 1
        # promotion shows up in the hop log: host read, then hbm landing
        assert tr.hops[0].src_tier == "host"
        assert tr.hops[-1].dst_tier == "hbm"

    def test_promote_disabled_leaves_tier(self):
        st = LocStore(1, hierarchy=small_hierarchy(100),
                      promote_on_access=False)
        st.put("a", SimObject(90.0), loc=0)
        st.put("b", SimObject(90.0), loc=0)
        st.get("a", at=0)
        assert st.stat("a").tier_on(0) == "host"
        assert st.promotions == 0

    def test_prefetch_engine_targets_tier(self):
        st = LocStore(4, hierarchy=small_hierarchy(100))
        st.put("d", SimObject(50.0), loc=0)
        eng = PrefetchEngine(st)
        eng.submit("d", 3, tier="bb")
        eng.drain()
        assert st.stat("d").tier_on(3) == "bb"
        # device prefetch (default) promotes into hbm
        eng2 = PrefetchEngine(st)
        eng2.submit("d", 2)
        eng2.drain()
        assert st.stat("d").tier_on(2) == "hbm"

    def test_explicit_promote_api(self):
        st = LocStore(2, hierarchy=small_hierarchy(100))
        st.put("a", SimObject(50.0), loc=0, tier="bb")
        assert st.stat("a").tier_on(0) == "bb"
        st.promote("a", 0)
        assert st.stat("a").tier_on(0) == "hbm"
        assert st.promotions == 1
        # pinning DOWN-tier is allowed but is not a promotion
        st.promote("a", 0, tier="bb")
        assert st.stat("a").tier_on(0) == "bb"
        assert st.promotions == 1
        # promote cannot conjure a replica on a node that has none
        with pytest.raises(KeyError):
            st.promote("a", 1)

    def test_promotion_hops_belong_to_the_read_object(self):
        """Victim demotions triggered by a promotion are their own demote
        transfers — the fetch Transfer's hops only describe the read object."""
        st = LocStore(1, hierarchy=small_hierarchy(100))
        st.put("a", SimObject(90.0), loc=0)
        st.put("b", SimObject(80.0), loc=0)    # a demoted to host, b in hbm
        _, tr = st.get("a", at=0)              # promoting a evicts b
        assert tr.name == "a"
        assert all(h.nbytes == 90.0 for h in tr.hops)   # never b's 80 bytes
        assert any(t.name == "b" and t.kind == "demote"
                   and t.src_tier == "hbm" for t in st.transfers)


class TestTransferAccounting:
    def test_local_hit_charges_resident_tier_media_time(self):
        st = LocStore(1, hierarchy=small_hierarchy(100),
                      promote_on_access=False)
        st.put("a", SimObject(80.0), loc=0, tier="bb")
        _, tr = st.get("a", at=0)
        assert tr.local and tr.src_tier == "bb"
        assert tr.est_seconds == pytest.approx(80.0 / 8e9)

    def test_network_fetch_records_tier_path(self):
        st = LocStore(2, hierarchy=small_hierarchy(100))
        st.put("a", SimObject(64.0), loc=0, tier="bb")
        _, tr = st.get("a", at=1)
        assert not tr.local
        assert tr.src_tier == "bb" and tr.dst_tier == "hbm"
        # read-from-bb + write-to-hbm media time
        assert tr.est_seconds == pytest.approx(64.0 / 8e9 + 64.0 / 800e9)
        assert len(tr.hops) == 1 and tr.hops[0].nbytes == 64.0

    def test_per_tier_read_bytes(self):
        st = LocStore(1, hierarchy=small_hierarchy(100),
                      promote_on_access=False)
        st.put("a", SimObject(30.0), loc=0, tier="host")
        st.put("b", SimObject(30.0), loc=0, tier="bb")
        st.get("a", at=0)
        st.get("b", at=0)
        rep = st.tier_report()
        assert rep["host"]["bytes_read"] == 30.0
        assert rep["bb"]["bytes_read"] == 30.0

    def test_flat_hierarchy_keeps_original_accounting(self):
        st = LocStore(4)                       # default: flat two-tier
        st.put("a", SimObject(1000.0), loc=2)
        _, tl = st.get("a", at=2)
        _, tf = st.get("a", at=0)
        assert tl.est_seconds == 0.0           # flat media is free
        assert st.demotions == 0 and st.promotions == 0
        rep = st.movement_report()
        assert rep["bytes_local"] == 1000.0 and rep["bytes_moved"] == 1000.0


class FakeTieredCluster:
    """ClusterView exposing per-tier media bandwidths."""

    def __init__(self, free, locations, tier_bw):
        self._free, self._loc, self._bw = free, locations, tier_bw

    def free_workers(self):
        return list(self._free)

    def locate(self, name):
        return self._loc.get(name)

    def link_gbps(self, src, dst):
        return float("inf") if src == dst else 10e9

    def tier_gbps(self, tier):
        return self._bw.get(tier, float("inf"))

    def worker_speed(self, node):
        return 1.0


class TestTierAwareScheduling:
    def test_tier_changes_placement_decision(self):
        """A replica parked in a crawling burst buffer on node 0 loses to the
        HBM replica on node 1 — the flat model can't tell them apart."""
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        bw = {"hbm": 800e9, "host": 100e9, "bb": 0.1e9}
        raw_sz = wf.sizes["raw"]
        loc = {"raw": Placement(nodes=(0, 1), tier="bb", tiers=("bb", "hbm"))}
        s = LocalityScheduler(wf)
        tiered = FakeTieredCluster([0, 1], loc, bw)
        (a_tiered,) = s.select(["split"], tiered)
        # flat view of the SAME placement: no tier info -> both replicas look
        # free and the first free node wins
        flat = FakeTieredCluster([0, 1], loc, bw)
        flat.tier_gbps = None                  # view exposes no hierarchy
        s2 = LocalityScheduler(wf)
        (a_flat,) = s2.select(["split"], flat)
        assert a_flat.node == 0                # resident replica looks free
        assert a_tiered.node == 1              # tier-aware: HBM replica wins
        assert a_tiered.move_seconds == pytest.approx(raw_sz / 800e9)

    def test_move_seconds_charges_source_tier(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        s = LocalityScheduler(wf)
        bw = {"bb": 1e9}
        loc = {"raw": Placement(nodes=(3,), tier="bb", tiers=("bb",))}
        cl = FakeTieredCluster([0], loc, bw)
        raw_sz = wf.sizes["raw"]
        got = s.move_seconds("split", 0, cl)
        assert got == pytest.approx(raw_sz / 10e9 + raw_sz / 1e9)


class TestSimulatorUnderPressure:
    def _hiers(self, cap):
        flat = StorageHierarchy([TierSpec("host", cap, 100e9)],
                                remote=TierSpec("remote", float("inf"), 0.5e9))
        tiered = StorageHierarchy(
            [TierSpec("hbm", cap / 4, 819e9),
             TierSpec("host", cap, 100e9),
             TierSpec("bb", 16 * cap, 8e9)],
            remote=TierSpec("remote", float("inf"), 0.5e9))
        return flat, tiered

    def test_tiered_moves_fewer_remote_bytes_than_flat(self):
        """The acceptance claim: under capacity pressure the hierarchy keeps
        spilled data node-local, so re-reads skip the PFS."""
        wf = compile_workflow(montage_workflow(16), HPC_CLUSTER)
        flat, tiered = self._hiers(0.5 * GB)
        rf = simulate(wf, LocalityScheduler, n_nodes=4, hw=HPC_CLUSTER,
                      hierarchy=flat)
        rt = simulate(wf, LocalityScheduler, n_nodes=4, hw=HPC_CLUSTER,
                      hierarchy=tiered)
        assert rf.tasks_done == rt.tasks_done == len(wf.graph.tasks)
        assert rt.demotions > 0                 # pressure actually happened
        assert rt.remote_bytes < rf.remote_bytes
        assert rt.io_wait_total < rf.io_wait_total

    def test_default_flat_sim_unchanged(self):
        """No hierarchy argument -> the original two-tier cost model."""
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        r = simulate(wf, ProactiveScheduler, n_nodes=4, hw=HPC_CLUSTER)
        assert r.tasks_done == len(wf.graph.tasks)
        assert r.demotions == 0 and r.bytes_demoted == 0.0

    def test_executor_rejects_store_plus_hierarchy(self):
        from repro.core import LocalityScheduler as LS, WorkflowExecutor
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        with pytest.raises(ValueError):
            WorkflowExecutor(wf, LS(wf), n_nodes=2,
                             store=LocStore(2),
                             hierarchy=tiered_hierarchy())

    def test_failure_handling_with_hierarchy(self):
        wf = compile_workflow(montage_workflow(12), HPC_CLUSTER)
        _, tiered = self._hiers(1 * GB)
        r = simulate(wf, ProactiveScheduler, n_nodes=8, hw=HPC_CLUSTER,
                     hierarchy=tiered, failures=[(1.0, 0)])
        assert r.tasks_done == len(wf.graph.tasks)


class TestCompilerTierModel:
    def test_est_stage_seconds_present_and_tiered(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        # split reads the external "raw" -> staging cost from the PFS
        assert wf.est_stage_seconds["split"] > 0
        assert wf.est_stage_seconds["merge"] == 0.0   # internal inputs only
        expect = wf.hw.move_seconds_tiered(wf.sizes["raw"], REMOTE_TIER, 0,
                                           "remote", "hbm")
        assert wf.est_stage_seconds["split"] == pytest.approx(expect)

    def test_hardware_model_tier_bw_overrides(self):
        hw = HPC_CLUSTER
        assert hw.tier_bw("host") == float("inf")     # flat default: free
        assert hw.tier_bw("remote") == hw.remote_tier_gbps
        hw2 = type(hw)(tier_gbps={"bb": 5e9})
        assert hw2.tier_bw("bb") == 5e9

    def test_default_hierarchy_factory(self):
        h = tiered_hierarchy()
        assert h.names() == ("hbm", "host", "bb", "remote")
        assert h.top == "hbm"
        assert h.next_down("bb") is None       # below bb lies the PFS
