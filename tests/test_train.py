"""Training loop: convergence, checkpoint/restart determinism, elasticity,
optimizer behaviour."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.train import checkpoint as ckpt
from repro.train.elastic import elastic_restore
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import (OptConfig, adamw_update, global_norm,
                                   init_opt_state, schedule)


def test_loss_decreases():
    cfg = get_smoke("granite-3-2b")
    r = train(cfg, TrainConfig(steps=25, batch=4, seq=32))
    assert r.steps_done == 25
    assert r.losses[-1] < r.losses[0] * 0.9


def test_failure_restart_reaches_same_final_loss():
    """Restart replays the same batches: final loss must match no-failure."""
    cfg = get_smoke("minitron-8b")
    with tempfile.TemporaryDirectory() as d1:
        base = train(cfg, TrainConfig(steps=20, batch=4, seq=32,
                                      ckpt_every=10, ckpt_dir=d1))
    with tempfile.TemporaryDirectory() as d2:
        failed = train(cfg, TrainConfig(steps=20, batch=4, seq=32,
                                        ckpt_every=10, ckpt_dir=d2,
                                        simulate_failure_at=15))
    assert failed.restarts == 1
    np.testing.assert_allclose(base.losses[-1], failed.losses[-1],
                               rtol=2e-2)


class TestCheckpoint:
    def test_roundtrip_bf16(self):
        tree = {"a": jnp.ones((4, 4), jnp.bfloat16) * 1.5,
                "b": {"c": jnp.arange(6, dtype=jnp.int32)}}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(tree, d, 3)
            assert ckpt.latest_step(d) == 3
            tgt = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            out = ckpt.restore(d, target=tgt)
        np.testing.assert_array_equal(
            np.asarray(out["a"], np.float32), np.asarray(tree["a"], np.float32))
        np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])

    def test_latest_pointer_tracks_newest(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save({"x": jnp.zeros(2)}, d, 1)
            ckpt.save({"x": jnp.ones(2)}, d, 2)
            assert ckpt.latest_step(d) == 2

    def test_async_checkpointer(self):
        with tempfile.TemporaryDirectory() as d:
            ac = ckpt.AsyncCheckpointer(d)
            ac.save_async({"x": jnp.ones((128, 128))}, 5)
            ac.wait()
            assert ckpt.latest_step(d) == 5

    def test_atomicity_no_tmp_left(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save({"x": jnp.zeros(3)}, d, 7)
            assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_elastic_restore_new_mesh():
    """Checkpoint written ungridded restores onto a (1,1) production-style
    mesh with rule-derived shardings (full reshard path)."""
    cfg = get_smoke("granite-3-2b")
    oc = OptConfig()
    from repro.models import model as M
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(oc, params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save({"p": params, "o": opt}, d, 11)
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        p2, o2, step = elastic_restore(cfg, oc, d, mesh)
    assert step == 11
    a = jax.tree.leaves(params)[0]
    b = jax.tree.leaves(p2)[0]
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(schedule(oc, jnp.asarray(0))) == 0.0
        assert float(schedule(oc, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(schedule(oc, jnp.asarray(100))) == pytest.approx(0.1)

    def test_clipping_bounds_update(self):
        oc = OptConfig(lr=1e-2, clip_norm=1.0, weight_decay=0.0)
        params = {"w": jnp.zeros((4, 4))}
        st = init_opt_state(oc, params)
        huge = {"w": jnp.full((4, 4), 1e6)}
        new_p, st, m = adamw_update(oc, huge, st, params)
        assert float(m["grad_norm"]) > 1e5
        assert float(jnp.abs(new_p["w"]).max()) < 1.0

    def test_no_decay_on_vectors(self):
        oc = OptConfig(lr=1e-1, weight_decay=1.0)
        params = {"w": jnp.ones((4, 4)), "g": jnp.ones((4,))}
        st = init_opt_state(oc, params)
        zeros = jax.tree.map(jnp.zeros_like, params)
        new_p, _, _ = adamw_update(oc, zeros, st, params)
        # matrix decayed, vector untouched (zero grad, no wd on 1-D)
        assert float(new_p["w"][0, 0]) < 1.0
        assert float(new_p["g"][0]) == pytest.approx(1.0)

    def test_moment_dtype_bf16(self):
        oc = OptConfig(moment_dtype="bfloat16")
        st = init_opt_state(oc, {"w": jnp.zeros((2, 2), jnp.bfloat16)})
        assert st["m"]["w"].dtype == jnp.bfloat16

    def test_global_norm(self):
        t = {"a": jnp.ones((3,)), "b": jnp.ones((4,))}
        assert float(global_norm(t)) == pytest.approx(np.sqrt(7.0))


def test_failure_before_first_checkpoint_cold_restarts():
    """A failure BEFORE any checkpoint exists must cold-restart (fresh init,
    deterministic data replay), not crash on a missing manifest."""
    cfg = get_smoke("granite-3-2b")
    with tempfile.TemporaryDirectory() as d:
        r = train(cfg, TrainConfig(steps=12, batch=2, seq=32, ckpt_every=50,
                                   ckpt_dir=d, simulate_failure_at=5))
    assert r.restarts == 1 and r.steps_done == 12
