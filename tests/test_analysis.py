"""Workflow/config linter (PR 9 tentpole, part a).

Every rule gets a trigger fixture (a workflow/config that MUST fire it) and a
clean fixture (one that must NOT) — so a rule can neither rot into a no-op
nor start crying wolf without a test moving.
"""

import dataclasses

import pytest

from repro.analysis.lint import (RULES, Severity, apply_allowlist,
                                 default_allowlist_path, gate, lint,
                                 lint_graph, load_allowlist,
                                 safe_write_modes)
from repro.core import (HPC_CLUSTER, SimConfig, StorageHierarchy, TierSpec,
                        compile_workflow)
from repro.core.dag import TaskGraph
from repro.core.hints import size_hint
from repro.core.workloads import fig2_workflow, pipeline_chain_workflow

GB = float(1 << 30)


def rule_ids(findings, rule=None):
    hits = [f for f in findings if rule is None or f.rule == rule]
    return [(f.rule, f.target) for f in hits]


def fired(findings, rule):
    return [f for f in findings if f.rule == rule]


def chain2() -> TaskGraph:
    """Minimal clean workflow: ext -> t1 -> mid -> t2 -> out(sink)."""
    g = TaskGraph()
    g.add_data("ext", size_bytes=size_hint(GB))
    g.add_task("t1", inputs=("ext",), outputs=("mid",))
    g.add_task("t2", inputs=("mid",), outputs=("out",))
    g.mark_sink("out")
    return g


class TestStructuralRules:
    def test_empty_graph_is_clean(self):
        assert lint_graph(TaskGraph()) == []

    def test_clean_chain_has_no_findings(self):
        assert lint_graph(chain2()) == []

    def test_self_referential_task(self):
        g = TaskGraph()
        g.add_task("loop", inputs=("x",), outputs=("x",))
        hits = fired(lint_graph(g), "waw-race")
        assert any(f.target == "loop" and "own output" in f.message
                   for f in hits)
        assert all(f.severity == Severity.ERROR for f in hits)

    def test_cycle_names_stuck_tasks(self):
        g = TaskGraph()
        g.add_task("t1", inputs=("a",), outputs=("b",))
        g.add_task("t2", inputs=("b",), outputs=("a",))
        hits = fired(lint_graph(g), "waw-race")
        assert any("cycle" in f.message and "t1" in f.message for f in hits)

    def test_duplicate_producer_rejected_then_linted(self):
        g = chain2()
        # the graph API refuses a second producer outright...
        with pytest.raises(ValueError, match="already produced"):
            g.add_task("evil", inputs=(), outputs=("mid",))
        # ...so the race only arises via hand-mutation — which lint catches
        g.data["mid"].producer = "someone_else"
        hits = fired(lint_graph(g), "waw-race")
        assert any("WAW race" in f.message and f.target == "mid"
                   for f in hits)

    def test_consumer_edge_mismatch_both_directions(self):
        g = chain2()
        g.data["mid"].consumers.append("ghost")       # consumer not a reader
        hits = fired(lint_graph(g), "waw-race")
        assert any("ghost" in f.message for f in hits)
        g2 = chain2()
        g2.data["mid"].consumers.clear()              # reader not a consumer
        hits2 = fired(lint_graph(g2), "waw-race")
        assert any("absent from its consumer list" in f.message
                   for f in hits2)

    def test_missing_producer_trigger_and_clean(self):
        g = TaskGraph()
        g.add_task("t", inputs=("orphan",), outputs=("o",))
        g.mark_sink("o")
        assert fired(lint_graph(g), "missing-producer")
        assert not fired(lint_graph(chain2()), "missing-producer")

    def test_dead_dataset_trigger_and_sink_mark_clears(self):
        g = TaskGraph()
        g.add_data("ext", size_bytes=size_hint(GB))
        g.add_task("t", inputs=("ext",), outputs=("dead",))
        assert fired(lint_graph(g), "dead-dataset")
        g.mark_sink("dead")
        assert not fired(lint_graph(g), "dead-dataset")

    def test_mark_sink_unknown_dataset_raises(self):
        with pytest.raises(KeyError):
            TaskGraph().mark_sink("nope")


class TestCapacityAndDurability:
    def tiny_hier(self, cap):
        return StorageHierarchy([TierSpec("host", cap, 100e9)],
                                remote=TierSpec("remote", float("inf"), 1e9))

    def test_capacity_infeasible_trigger(self):
        wf = compile_workflow(pipeline_chain_workflow(2, 3), HPC_CLUSTER)
        cfg = SimConfig(n_nodes=2, hw=HPC_CLUSTER,
                        hierarchy=self.tiny_hier(1e6))
        hits = fired(lint(wf, config=cfg), "capacity-infeasible")
        assert any("working set" in f.message for f in hits)
        assert any(f.target == "cluster" for f in hits)

    def test_capacity_clean_when_generous_or_unbounded(self):
        wf = compile_workflow(pipeline_chain_workflow(2, 3), HPC_CLUSTER)
        roomy = SimConfig(n_nodes=2, hw=HPC_CLUSTER,
                          hierarchy=self.tiny_hier(1e15))
        assert not fired(lint(wf, config=roomy), "capacity-infeasible")
        # an infinite tier means "infeasible" is unprovable: stay silent
        nohier = SimConfig(n_nodes=2, hw=HPC_CLUSTER)
        assert not fired(lint(wf, config=nohier), "capacity-infeasible")

    def test_durability_hazard_trigger_and_clean(self):
        wf = compile_workflow(pipeline_chain_workflow(2, 3), HPC_CLUSTER)
        risky = SimConfig(n_nodes=4, hw=HPC_CLUSTER,
                          failures=((5.0, 1),), durability="none")
        hits = fired(lint(wf, config=risky), "durability-hazard")
        assert len(hits) == 1 and hits[0].target == "config"
        safe = SimConfig(n_nodes=4, hw=HPC_CLUSTER, failures=((5.0, 1),),
                         durability="fsync_on_barrier")
        assert not fired(lint(wf, config=safe), "durability-hazard")
        nofail = SimConfig(n_nodes=4, hw=HPC_CLUSTER, durability="none")
        assert not fired(lint(wf, config=nofail), "durability-hazard")


class TestWriteAroundRule:
    def test_compiler_pins_are_provably_safe(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        assert not fired(lint(wf), "unsafe-write-around")
        assert safe_write_modes(wf) == wf.write_modes

    def test_hand_pinned_multi_consumer_fires(self):
        g = TaskGraph()
        g.add_data("ext", size_bytes=size_hint(GB))
        g.add_task("p", inputs=("ext",), outputs=("shared",))
        g.data["shared"].xattr["write_mode"] = "around"
        g.add_task("c1", inputs=("shared",), outputs=("o1",))
        g.add_task("c2", inputs=("shared",), outputs=("o2",))
        g.mark_sink("o1", "o2")
        hits = fired(lint_graph(g), "unsafe-write-around")
        assert any("2 consumers" in f.message for f in hits)

    def test_stale_write_modes_dict_cannot_smuggle_a_pin(self):
        wf = compile_workflow(fig2_workflow(), HPC_CLUSTER)
        wf.write_modes["ra"] = "around"   # ra feeds merge at a 50/50 split
        assert fired(lint(wf), "unsafe-write-around")
        assert "ra" not in safe_write_modes(wf)


class TestClusterConfigRules:
    def test_zero_ici_bandwidth(self):
        hw = dataclasses.replace(HPC_CLUSTER, ici_gbps=0.0)
        cfg = SimConfig(n_nodes=4, hw=hw)
        hits = fired(lint_graph(chain2(), config=cfg), "unreachable-node")
        assert any(f.target == "hw.ici_gbps" for f in hits)
        clean = SimConfig(n_nodes=4, hw=HPC_CLUSTER)
        assert not fired(lint_graph(chain2(), config=clean),
                         "unreachable-node")

    def test_zero_remote_bandwidth_with_remote_externals(self):
        hw = dataclasses.replace(HPC_CLUSTER, remote_tier_gbps=0.0)
        cfg = SimConfig(n_nodes=4, hw=hw)
        hits = fired(lint_graph(chain2(), config=cfg), "unreachable-node")
        assert any(f.target == "hw.remote_tier_gbps" for f in hits)

    def test_bad_speed_overrides(self):
        cfg = SimConfig(n_nodes=2, hw=HPC_CLUSTER,
                        speeds={5: 1.0, 1: 0.0})
        hits = fired(lint_graph(chain2(), config=cfg), "unreachable-node")
        targets = {f.target for f in hits}
        assert {"node5", "node1"} <= targets
        assert all(f.severity == Severity.WARNING for f in hits)

    def test_zero_capacity_tier_trigger_and_clean(self):
        bad = StorageHierarchy([TierSpec("hbm", 0.0, 800e9),
                                TierSpec("host", 8 * GB, 0.0)],
                               remote=TierSpec("remote", float("inf"), 1e9))
        cfg = SimConfig(n_nodes=2, hw=HPC_CLUSTER, hierarchy=bad)
        hits = fired(lint_graph(chain2(), config=cfg), "zero-capacity-tier")
        assert {f.target for f in hits} == {"hbm", "host"}
        good = StorageHierarchy([TierSpec("host", 8 * GB, 100e9)],
                                remote=TierSpec("remote", float("inf"), 1e9))
        assert not fired(lint_graph(chain2(), config=SimConfig(
            n_nodes=2, hw=HPC_CLUSTER, hierarchy=good)), "zero-capacity-tier")

    def test_gapped_join_schedule(self):
        cfg = SimConfig(n_nodes=4, hw=HPC_CLUSTER, joins=((5.0, 9),))
        hits = fired(lint_graph(chain2(), config=cfg), "gapped-membership")
        assert any("skips ids 4..8" in f.message for f in hits)
        dense = SimConfig(n_nodes=4, hw=HPC_CLUSTER, joins=((5.0, 4),))
        assert not fired(lint_graph(chain2(), config=dense),
                         "gapped-membership")

    def test_failure_of_never_admitted_node(self):
        cfg = SimConfig(n_nodes=4, hw=HPC_CLUSTER, failures=((5.0, 20),))
        hits = fired(lint_graph(chain2(), config=cfg), "gapped-membership")
        assert hits and hits[0].severity == Severity.ERROR
        # a join admitting the node before the failure makes it legitimate
        ok = SimConfig(n_nodes=4, hw=HPC_CLUSTER, joins=((2.0, 20),),
                       failures=((5.0, 20),))
        late = fired(lint_graph(chain2(), config=ok), "gapped-membership")
        assert not [f for f in late if f.severity == Severity.ERROR]


class TestAllowlistAndGate:
    def test_reason_is_mandatory(self, tmp_path):
        p = tmp_path / "allow.json"
        p.write_text('[{"rule": "dead-dataset", "target": "*", "reason": ""}]')
        with pytest.raises(ValueError, match="no reason"):
            load_allowlist(str(p))

    def test_missing_file_is_empty(self, tmp_path):
        assert load_allowlist(str(tmp_path / "absent.json")) == []

    def test_suppression_carries_reason_and_gate_skips_it(self):
        g = TaskGraph()
        g.add_data("ext", size_bytes=size_hint(GB))
        g.add_task("t", inputs=("ext",), outputs=("dead",))
        findings = lint_graph(g, name="wf", allowlist=[
            {"rule": "dead-dataset", "target": "wf:de*",
             "reason": "intentional scratch output"}])
        [f] = fired(findings, "dead-dataset")
        assert f.suppressed and f.reason == "intentional scratch output"
        assert gate(findings) == []
        # an unsuppressed finding of the same severity still gates
        plain = apply_allowlist(lint_graph(g, name="wf"), [])
        assert gate(plain)

    def test_gate_threshold_orders_severities(self):
        g = TaskGraph()
        g.add_task("loop", inputs=("x",), outputs=("x",))   # ERROR
        findings = lint_graph(g)
        assert gate(findings, Severity.ERROR)
        assert not gate([], Severity.INFO)

    def test_repo_allowlist_loads_and_builtins_gate_clean(self):
        # the committed allow-list parses, and every built-in workload lints
        # clean (or reasoned-suppressed) — the same contract CI enforces
        from repro.analysis.__main__ import main
        assert main([]) == 0

    def test_severity_str(self):
        assert str(Severity.WARNING) == "WARNING"

    def test_rules_registry_is_complete(self):
        assert set(RULES) == {
            "waw-race", "missing-producer", "dead-dataset",
            "capacity-infeasible", "durability-hazard",
            "unsafe-write-around", "unreachable-node",
            "oversubscribed-link",
            "zero-capacity-tier", "gapped-membership"}
        assert default_allowlist_path().endswith("analysis_allowlist.json")


class TestStrictValidate:
    def test_strict_rejects_sizeless_consumed_external(self):
        g = TaskGraph()
        g.add_task("t", inputs=("orphan",), outputs=("o",))
        g.validate()                                   # default: tolerated
        with pytest.raises(ValueError, match="orphan"):
            g.validate(strict=True)

    def test_compile_workflow_strict_plumbs_through(self):
        g = TaskGraph()
        g.add_task("t", inputs=("orphan",), outputs=("o",))
        g.mark_sink("o")
        compile_workflow(g, HPC_CLUSTER)               # default still works
        with pytest.raises(ValueError, match="strict validation"):
            compile_workflow(g, HPC_CLUSTER, strict=True)

    def test_strict_accepts_sized_externals(self):
        chain2().validate(strict=True)
