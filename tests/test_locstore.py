"""Unit tests for the location-aware store + distributed location service."""

import threading

import numpy as np
import pytest

from repro.core.locstore import (LocStore, LocationService, Placement,
                                 REMOTE_TIER, SimObject)


class TestPlacementControl:
    def test_s_loc_pins_location(self):
        st = LocStore(8)
        p = st.put("a", SimObject(100), loc=3)
        assert p.real_loc == 3
        assert st.getxattr("a", "real_loc") == 3

    def test_default_policy_is_consistent_hash(self):
        st1, st2 = LocStore(8), LocStore(8)
        p1, p2 = st1.put("x", SimObject(1)), st2.put("x", SimObject(1))
        assert p1.nodes == p2.nodes          # deterministic, Hercules-like

    def test_out_of_range_rejected(self):
        st = LocStore(4)
        with pytest.raises(ValueError):
            st.put("a", SimObject(1), loc=9)

    def test_xattr_roundtrip(self):
        st = LocStore(4)
        st.put("a", SimObject(1), loc=1, xattr={"owner": "task1"})
        assert st.getxattr("a", "owner") == "task1"
        assert st.getxattr("a", "size") == 1.0


class TestLocalityAccounting:
    def test_local_hit_vs_remote_fetch(self):
        st = LocStore(4)
        st.put("a", SimObject(1000), loc=2)
        _, t_local = st.get("a", at=2)
        _, t_far = st.get("a", at=0)
        assert t_local.local and not t_far.local
        rep = st.movement_report()
        assert rep["bytes_local"] == 1000 and rep["bytes_moved"] == 1000
        assert rep["locality_hit_rate"] == 0.5

    def test_replica_serves_nearest(self):
        st = LocStore(8)
        st.put("a", SimObject(10), loc=0)
        st.replicate("a", [5])
        _, t = st.get("a", at=5)
        assert t.local

    def test_migrate_repins_and_counts(self):
        st = LocStore(4)
        st.put("a", SimObject(50), loc=0)
        st.migrate("a", 3)
        assert st.stat("a").real_loc == 3
        assert st.migrations == 1
        assert st.getxattr("a", "migrated_from") == (0,)

    def test_remote_tier(self):
        st = LocStore(4)
        st.put("a", SimObject(10), loc=Placement((REMOTE_TIER,), tier="remote"))
        _, t = st.get("a", at=1)
        assert t.src == REMOTE_TIER and not t.local


class TestLocationService:
    def test_sharding_is_stable_and_balanced(self):
        svc = LocationService(16)
        for i in range(2000):
            svc.record(f"file{i}", Placement((0,)))
        bal = svc.load_balance()
        assert bal["entries"] == 2000
        # blake2-based placement: no shard more than 3x the mean
        assert bal["max_shard"] < 3 * (2000 / 16)

    def test_lookup_miss_is_none(self):
        assert LocationService(4).lookup("nope") is None

    def test_thread_safety(self):
        st = LocStore(8)
        errs = []

        def work(k):
            try:
                for i in range(200):
                    st.put(f"{k}_{i}", SimObject(10), loc=k % 8)
                    st.get(f"{k}_{i}", at=(k + 1) % 8)
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=work, args=(k,)) for k in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        assert len(st.loc.names()) == 1600

    def test_sizeof_jax_numpy(self):
        st = LocStore(2)
        st.put("np", np.zeros((10, 10), np.float32))
        assert st.getxattr("np", "size") == 400.0
