"""dist.hints role semantics + shard_map MoE parity with the global oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.dist.hints import hint, sharding_rules, tp_divides
from repro.launch.mesh import make_local_mesh
from repro.models.moe import _moe_ffn_global, init_moe, moe_ffn


def test_hint_noop_without_rules():
    x = jnp.ones((4, 8))
    y = hint(x, "dp", "tp")
    assert y is x                      # identity, not even a constraint


def test_hint_applies_under_rules():
    mesh = make_local_mesh(1, 1)
    with mesh, sharding_rules(mesh):
        def f(x):
            return hint(x, "dp", "tp") * 2
        out = jax.jit(f)(jnp.ones((4, 8)))
    np.testing.assert_array_equal(np.asarray(out), 2.0)


def test_hint_wrong_rank_asserts():
    mesh = make_local_mesh(1, 1)
    with mesh, sharding_rules(mesh):
        with pytest.raises(AssertionError):
            hint(jnp.ones((4, 8)), "dp")


def test_tp_divides_semantics():
    assert tp_divides(56)              # vacuous without rules
    mesh = make_local_mesh(1, 1)
    with sharding_rules(mesh):
        assert tp_divides(56)          # tp_size == 1 divides everything


def test_hint_degrades_on_indivisible():
    """Roles on indivisible dims must silently replicate, never fail."""
    mesh = make_local_mesh(1, 1)
    with mesh, sharding_rules(mesh):
        out = jax.jit(lambda x: hint(x, "dp", "tp", "seq"))(
            jnp.ones((3, 7, 5)))
    assert out.shape == (3, 7, 5)


class TestShardMapMoEParity:
    """The shard_map expert path must match the global-capacity oracle on a
    trivial (1,1) mesh (same local capacity == same drops == same numerics)."""

    @pytest.mark.parametrize("arch", ["arctic-480b", "deepseek-v3-671b"])
    def test_matches_global(self, arch):
        cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
        p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 16, cfg.d_model)), jnp.float32)
        ref, aux_ref = _moe_ffn_global(cfg, p, x)
        mesh = make_local_mesh(1, 1)
        with mesh, sharding_rules(mesh):
            out, aux = jax.jit(lambda p, x: moe_ffn(cfg, p, x))(p, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)

    def test_grads_flow_through_shard_map(self):
        cfg = dataclasses.replace(get_smoke("arctic-480b"), dtype="float32")
        p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(1).normal(
            size=(1, 8, cfg.d_model)), jnp.float32)
        mesh = make_local_mesh(1, 1)
        with mesh, sharding_rules(mesh):
            g = jax.jit(jax.grad(
                lambda w: moe_ffn(cfg, w, x)[0].sum()))(p)
        leaves = jax.tree.leaves(g)
        assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
        assert any(float(jnp.abs(l).max()) > 0 for l in leaves)


def test_full_train_step_under_mesh_rules():
    """Whole train step (microbatched) lowers and runs under a mesh with
    sharding rules — the dry-run path at toy scale, actually executed."""
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import make_train_step
    from repro.models import init_params
    cfg = get_smoke("deepseek-v3-671b")
    oc = OptConfig()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(oc, params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                   jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    mesh = make_local_mesh(1, 1)
    with mesh, sharding_rules(mesh):
        step = jax.jit(make_train_step(cfg, oc, microbatches=2))
        p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(o2["step"]) == 1
