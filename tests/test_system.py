"""End-to-end behaviour tests for the paper's cross-layer system.

Each test is one of the paper's qualitative claims, checked on the simulator
(the same scheduler objects drive the real executor — see test_executor.py).
"""

import pytest

from repro.core import (FCFSScheduler, HPC_CLUSTER, LocalityScheduler,
                        ProactiveScheduler, compile_workflow, simulate)
from repro.core.workloads import (fig2_workflow, mapreduce_workflow,
                                  montage_workflow, random_layered_workflow)


@pytest.fixture(scope="module")
def wf_random():
    return compile_workflow(random_layered_workflow(8, 16, seed=3),
                            HPC_CLUSTER)


def _run(wf, factory, **kw):
    return simulate(wf, factory, n_nodes=16, hw=HPC_CLUSTER, **kw)


class TestPaperClaims:
    def test_locality_moves_fewer_bytes_than_fcfs(self, wf_random):
        """Claim: locality-aware scheduling reduces data movement."""
        fcfs = _run(wf_random, FCFSScheduler)
        loc = _run(wf_random, LocalityScheduler)
        assert loc.bytes_moved < 0.8 * fcfs.bytes_moved
        assert loc.locality_hit_rate > fcfs.locality_hit_rate

    def test_proactive_cuts_io_wait(self, wf_random):
        """Claim: pipelining inputs ahead of task start hides I/O time."""
        loc = _run(wf_random, LocalityScheduler)
        pro = _run(wf_random, ProactiveScheduler)
        assert pro.io_wait_total < loc.io_wait_total
        assert pro.bytes_prefetched > 0

    def test_cross_layer_strictly_improves(self, wf_random):
        """Claim: each added layer helps (FCFS -> +locality -> +proactive)."""
        fcfs = _run(wf_random, FCFSScheduler)
        loc = _run(wf_random, LocalityScheduler)
        pro = _run(wf_random, ProactiveScheduler)
        assert fcfs.locality_hit_rate < loc.locality_hit_rate \
            <= pro.locality_hit_rate + 1e-9
        assert pro.makespan <= fcfs.makespan * 1.01

    @pytest.mark.parametrize("builder", [fig2_workflow,
                                         lambda: mapreduce_workflow(16, 4),
                                         lambda: montage_workflow(12)])
    def test_all_schedulers_complete_all_workflows(self, builder):
        wf = compile_workflow(builder(), HPC_CLUSTER)
        for factory in (FCFSScheduler, LocalityScheduler, ProactiveScheduler):
            r = simulate(wf, factory, n_nodes=8, hw=HPC_CLUSTER)
            assert r.tasks_done == len(wf.graph.tasks)
            assert r.makespan > 0

    def test_failure_rerun_completes(self, wf_random):
        """Node failures re-run lost producers and still finish."""
        r = simulate(wf_random, ProactiveScheduler, n_nodes=16,
                     hw=HPC_CLUSTER, failures=[(1.0, 0), (100.0, 3)])
        assert r.tasks_done == len(wf_random.graph.tasks)
        assert r.reruns >= 0

    def test_straggler_mitigation_speed_aware(self):
        """[beyond-paper] speed-aware scoring avoids slow workers."""
        wf = compile_workflow(random_layered_workflow(6, 12, seed=7),
                              HPC_CLUSTER)
        slow = {0: 0.05, 1: 0.05}   # two badly-degraded nodes
        base = simulate(wf, lambda w: LocalityScheduler(w),
                        n_nodes=8, hw=HPC_CLUSTER, speeds=slow)
        aware = simulate(wf, lambda w: LocalityScheduler(w, speed_aware=True),
                         n_nodes=8, hw=HPC_CLUSTER, speeds=slow)
        assert aware.makespan < base.makespan

    def test_scales_to_many_nodes(self):
        """The decision path stays correct (and fast) at 1024+ nodes."""
        wf = compile_workflow(mapreduce_workflow(256, 16), HPC_CLUSTER)
        r = simulate(wf, ProactiveScheduler, n_nodes=1024, hw=HPC_CLUSTER)
        assert r.tasks_done == len(wf.graph.tasks)
