"""Elastic cluster membership (PR 8): live node/engine join, rebalance,
re-replication.

Covers the membership lifecycle end to end: storage-layer join/rejoin
determinism, the placement-skew bugfix (alive-list remap instead of linear
probing), risk-aware re-replication ordering toward newcomers, the
simulator's incremental cached-view absorption, router-level engine joins
(deferred-slice adoption, zero-re-prefill rebalance with bit-identical
decode), the ``warm()`` residency-guard parity fix, and a trace-driver
fail-then-join run recovering pre-failure tail latency.
"""

import collections

import numpy as np
import pytest

from repro.core import HPC_CLUSTER
from repro.core.config import ServingConfig
from repro.core.locstore import (LocStore, SimObject, _stable_hash,
                                 tiered_hierarchy)
from repro.core.prefetch import PrefetchEngine
from repro.core.simulator import SimCluster
from repro.serve.engine import Router, ServingEngine, _cache_name
from repro.serve.traffic import (MiB, SyntheticBackend, TraceConfig,
                                 TraceDriver, build_trace_stack,
                                 generate_trace)

KV = 4 * MiB


def _store(n_nodes=4, **kw):
    kw.setdefault("hierarchy", tiered_hierarchy(
        hbm_bytes=4 * KV, host_bytes=8 * KV, bb_bytes=float(1 << 30)))
    kw.setdefault("write_policy", "back")
    kw.setdefault("durability", "flush_before_ack")
    return LocStore(n_nodes, **kw)


def _engine(store, node, max_batch=2, width=4):
    cfg = ServingConfig(max_batch=max_batch, max_seq=1 << 20)
    return ServingEngine(None, None, config=cfg, node=node, store=store,
                         backend=SyntheticBackend(kv_bytes=KV, width=width))


# ---------------------------------------------------------------- storage
class TestStorageJoin:
    def test_rejoin_is_deterministic_and_cold(self):
        st = _store()
        st.put("a", SimObject(KV), loc=1)
        st.pin("a", 1)
        st.drop_node(1)
        rep = st.join_node(1)
        assert rep.rejoined and not rep.grew
        # same node id rejoins with empty tiers and cleared pin refcounts
        for tier in st.hierarchy.names():
            if st.hierarchy.is_node_tier(tier):
                assert st.tier_used(1, tier) == 0.0
        assert not st.is_pinned("a", 1)
        assert st.failed_nodes == frozenset()

    def test_join_event_published(self):
        st = _store()
        seen = []
        st.loc.subscribe(lambda e, k, p: seen.append((e, k)))
        st.drop_node(2)
        st.join_node(2)
        assert seen[-1] == ("join_node", 2)
        assert ("drop_node", 2) in seen

    def test_growth_join_extends_cluster(self):
        st = _store(n_nodes=4)
        rep = st.join_node(7)
        assert rep.grew and not rep.rejoined
        assert st.n_nodes == 8
        st.put("x", SimObject(KV), loc=7)     # the new id accepts placements
        assert st.stat("x").resident_on(7)
        # gapped growth: the skipped ids did NOT join — they sit in the
        # failed set until their own join/revive admits them
        assert st.failed_nodes == frozenset({4, 5, 6})
        assert st.revive_node(5).rejoined
        assert st.failed_nodes == frozenset({4, 6})

    def test_revive_requires_a_failed_node(self):
        st = _store()
        with pytest.raises(ValueError):
            st.revive_node(0)                 # alive: not a revival
        st.drop_node(0)
        assert st.revive_node(0).rejoined

    def test_placement_reopens_to_rejoined_node(self):
        st = _store(n_nodes=4)
        st.drop_node(2)
        assert all(st._default_placement(f"k{i}").nodes[0] != 2
                   for i in range(200))
        st.join_node(2)
        assert any(st._default_placement(f"k{i}").nodes[0] == 2
                   for i in range(200))


class TestPlacementSkew:
    """Satellite bugfix: default placement must stay near-uniform over the
    survivors — the old linear probe handed a dead run's whole hash mass to
    its first surviving successor."""

    @pytest.mark.parametrize("policy", ["hash", "rr"])
    def test_near_uniform_with_half_the_nodes_failed(self, policy):
        n, trials = 8, 8000
        st = LocStore(n, default_policy=policy)
        for node in range(n // 2):            # nodes 0..3 die: a dead RUN,
            st.drop_node(node)                # the linear probe's worst case
        counts = collections.Counter(
            st._default_placement(f"obj-{i}").nodes[0]
            for i in range(trials))
        assert set(counts) <= set(range(n // 2, n))
        expected = trials / (n - n // 2)
        for node, c in counts.items():
            assert abs(c - expected) < 0.15 * expected, (
                f"node {node} got {c} of {trials} placements "
                f"(expected ~{expected:.0f}) — survivor skew")

    def test_identical_to_original_when_healthy(self):
        # alive == range(n): the remap must reproduce hash % n exactly, so
        # healthy-cluster placements (and every test pinning them) hold
        st = LocStore(8)
        for i in range(64):
            name = f"data-{i}"
            assert (st._default_placement(name).nodes[0]
                    == _stable_hash(name) % 8)


class TestRereplication:
    def test_sole_copy_dirty_first_then_clean_largest_first(self):
        st = LocStore(4, write_policy="back")
        st.put("dirty_small", SimObject(10.0), loc=0)
        st.put("dirty_big", SimObject(100.0), loc=1)
        st.put("clean_big", SimObject(900.0), loc=0)
        st.put("replicated", SimObject(50.0), loc=(0, 1))
        st.put("around", SimObject(40.0), loc=2, mode="around")
        st.fsync(["clean_big"])
        st.join_node(3)
        names = [c[0] for c in st.rereplication_candidates(3)]
        # dirty sole copies first (largest first), clean after; multi-replica
        # and write-around objects are never candidates
        assert names == ["dirty_big", "dirty_small", "clean_big"]

    def test_budget_is_greedy_and_skips_too_big(self):
        st = LocStore(3, write_policy="back")
        st.put("huge", SimObject(1000.0), loc=0)
        st.put("mid", SimObject(100.0), loc=0)
        st.put("tiny", SimObject(10.0), loc=1)
        st.join_node(2)
        names = [c[0] for c in
                 st.rereplication_candidates(2, max_bytes=150.0)]
        assert names == ["mid", "tiny"]   # huge skipped, budget keeps filling

    def test_rereplicate_to_lands_copies_and_counts(self):
        st = LocStore(3, write_policy="back")
        st.put("d", SimObject(64.0), loc=0)
        st.join_node(2)
        done = st.rereplicate_to(2)
        assert done == ("d",)
        assert st.stat("d").resident_on(2)
        assert st.stat("d").tier_on(2) == st.hierarchy.bottom
        assert st.rereplications == 1 and st.bytes_rereplicated == 64.0
        assert st.movement_report()["rereplications"] == 1.0

    def test_failed_sources_are_not_candidates(self):
        st = LocStore(4, write_policy="back")
        st.put("gone", SimObject(8.0), loc=1)
        st.drop_node(1)                       # the sole copy died with it
        st.join_node(3)
        assert st.rereplication_candidates(3) == []


# -------------------------------------------------------------- simulator
class TestSimClusterJoin:
    def test_rejoin_absorbs_into_cached_views(self):
        c = SimCluster(4, HPC_CLUSTER, LocStore(4))
        assert list(c.free_workers()) == [0, 1, 2, 3]   # caches built
        c.fail(1)
        assert list(c.alive_nodes()) == [0, 2, 3]
        c.join(1)
        assert list(c.free_workers()) == [0, 1, 2, 3]
        assert list(c.alive_nodes()) == [0, 1, 2, 3]

    def test_growth_join_extends_link_rows_in_place(self):
        c = SimCluster(4, HPC_CLUSTER, LocStore(4))
        row_before, _ = c.link_row(0)
        assert len(row_before) == 4
        c.join(5)
        assert c.n_nodes == 6
        row_after, _ = c.link_row(0)
        assert len(row_after) == 6
        assert row_after[5] == HPC_CLUSTER.link_gbps(0, 5)
        assert list(c.alive_nodes()) == [0, 1, 2, 3, 5]
        # the incremental insert and a from-scratch rebuild must agree on
        # the skipped id: node 4 never joined
        c._alive_cache = None
        assert list(c.alive_nodes()) == [0, 1, 2, 3, 5]
        assert 4 in c.failed

    def test_join_of_live_member_is_a_noop(self):
        c = SimCluster(2, HPC_CLUSTER, LocStore(2))
        c.acquire(0)                          # node 0 is busy
        c.join(0)
        assert list(c.free_workers()) == [1], \
            "a live busy node must stay busy"


# ----------------------------------------------------------------- router
class TestEngineJoin:
    def test_join_validations(self):
        st = _store()
        router = Router([_engine(st, 0)], st)
        with pytest.raises(ValueError):
            router.join_engine(0, _engine(st, 0))        # already present
        with pytest.raises(ValueError):
            router.join_engine(2, _engine(st, 1))        # wrong binding
        with pytest.raises(ValueError):
            router.join_engine(2, _engine(_store(), 2))  # foreign store

    def test_all_engines_down_then_join_adopts_deferred(self):
        st = _store(n_nodes=4)
        a = _engine(st, 0)
        router = Router([a], st)
        sid = a.submit([3, 1, 4])
        for _ in range(2):
            a.step()
        a.park(sid)
        tokens_before = list(a.sessions[sid].tokens)
        rep = router.fail_engine(0)           # NO engine left at all
        assert rep.deferred == (sid,) and rep.lost == ()
        assert router.engines == {}
        assert st.exists(_cache_name(sid))
        jrep = router.join_engine(1, _engine(st, 1))
        assert jrep.adopted == (sid,)
        assert jrep.join.rejoined is False
        eng = router.engines[1]
        assert eng.sessions[sid].slot is not None
        tok = eng.step()
        assert sid in tok, "adopted session decodes on the newcomer"
        assert eng.sessions[sid].tokens[:len(tokens_before)] == tokens_before
        assert eng.prefills == 0, "adoption must not pay a prefill"

    def test_rebalance_is_zero_reprefill_and_bit_identical(self):
        # control: park/resume on one engine, no membership events at all
        ctrl = _engine(_store(), 0)
        sid_c = ctrl.submit([7, 7, 2])
        for _ in range(3):
            ctrl.step()
        ctrl.park(sid_c)
        ctrl.resume(sid_c)
        for _ in range(3):
            ctrl.step()
        want = list(ctrl.sessions[sid_c].tokens[:7])

        st = _store(n_nodes=4)
        a = _engine(st, 0)
        router = Router([a], st)
        sid = a.submit([7, 7, 2])
        extra = a.submit([9, 9])              # a second parked donor session
        for _ in range(3):
            a.step()
        a.park(sid)
        a.park(extra)
        prefills_before = a.prefills
        c = _engine(st, 2)
        jrep = router.join_engine(2, c)
        # 2 parked over 2 engines -> fair share is one each: one moves
        assert jrep.rebalanced == (sid,), \
            "least-recently-active parked session moves first"
        assert (sum(e.prefills for e in router.engines.values())
                == prefills_before), "rebalance must be zero-re-prefill"
        assert sid not in a.sessions and sid in c.sessions
        if c.sessions[sid].slot is None:
            c.resume(sid)
        for _ in range(3):
            c.step()
        assert c.sessions[sid].tokens[:7] == want, \
            "decode after rebalance must be bit-identical"
        assert router.rebalanced_sessions == 1

    def test_rebalance_stages_local_replica_when_saturated(self):
        st = _store(n_nodes=4)
        a = _engine(st, 0, max_batch=4)
        router = Router([a], st)
        for i in range(4):
            s = a.submit([5 + i, 3])
            a.park(s)
        c = _engine(st, 1, max_batch=1)       # joins with ONE slot
        jrep = router.join_engine(1, c)
        assert len(jrep.rebalanced) == 2      # fair = 4 parked // 2 engines
        still_parked = [s for s in jrep.rebalanced
                        if c.sessions[s].slot is None]
        assert still_parked, "one adoptee must exceed the single slot"
        for s in still_parked:
            assert st.stat(_cache_name(s)).resident_on(1), \
                "saturated-target adoptee gets a node-local replica staged"

    def test_migration_before_join_supersedes_deferred_slice(self):
        st = _store(n_nodes=4)
        a = _engine(st, 0)
        b = _engine(st, 1, width=8)           # incompatible slot shape
        router = Router([a, b], st)
        sid = a.submit([2, 2, 2])
        a.park(sid)
        rep = router.fail_engine(0)
        assert rep.deferred == (sid,)
        assert sid in router._unhomed
        # the session re-prefills (migrates) before any compatible join:
        d = router.follow_up(sid, [2, 2, 2, 9])
        assert d.prefilled and d.sid != sid
        assert sid not in router._unhomed
        assert not st.exists(_cache_name(sid)), "stale slice cleaned up"


class TestWarmParity:
    """Satellite bugfix: both warm() paths apply the same residency guard."""

    def _parked_session(self, with_prefetch):
        st = _store(n_nodes=4)
        eng = _engine(st, 0)
        pf = PrefetchEngine(st) if with_prefetch else None
        router = Router([eng], st, prefetch=pf)
        sid = eng.submit([6, 6])
        eng.park(sid)
        return st, router, sid

    @pytest.mark.parametrize("with_prefetch", [False, True],
                             ids=["sync", "prefetch"])
    def test_offnode_only_slice_is_not_warmable(self, with_prefetch):
        st, router, sid = self._parked_session(with_prefetch)
        # strand the slice off-node: its only replica moves to node 2
        st.migrate(_cache_name(sid), 2)
        assert router.warm(sid) is False
        assert router.warmups == 0, \
            "off-node-only slices must not count as warmed on either path"

    @pytest.mark.parametrize("with_prefetch", [False, True],
                             ids=["sync", "prefetch"])
    def test_resident_parked_slice_warms_on_both_paths(self, with_prefetch):
        st, router, sid = self._parked_session(with_prefetch)
        assert router.warm(sid) is True
        assert router.warmups == 1


# ----------------------------------------------------------------- driver
class TestTraceFailThenJoin:
    def _run(self, trace, *, failures=(), joins=()):
        router, store = build_trace_stack(
            n_engines=3, max_batch=8, kv_bytes=KV, tiered=True,
            bb_slots_per_node=64, durability="flush_before_ack")
        driver = TraceDriver(router, trace, warm=True, failures=failures,
                             joins=joins)
        return driver.run(), router, driver

    def test_fail_then_join_restores_pre_failure_p99_ttft(self):
        cfg = TraceConfig(n_sessions=600, followups_per_session=1.5,
                          req_rate=45.0, arrival="bursty", seed=11)
        trace = generate_trace(cfg)
        t_mid = trace[len(trace) // 2].t
        t_join = t_mid + 4.0
        base, _, base_driver = self._run(trace)
        fj, router, driver = self._run(trace, failures=((t_mid, 0),),
                                       joins=((t_join, 0),))
        assert len(router.engines) == 3, "the cluster is back at full size"
        assert driver.counters["joins"] == 1
        s_fj = fj.summary()
        assert s_fj["engine_full_errors"] == 0
        assert (s_fj["failover_resumed"] + s_fj["failover_deferred"]
                + s_fj["failover_lost"]) > 0, "the failure must bite"
        # recovery: once the newcomer's params are loaded and the backlog
        # drains, the p99 TTFT of the remaining traffic is back to the
        # no-failure profile
        settle = t_join + 10.0
        base_p99 = float(np.percentile(
            [lat for _, lat in base_driver.samples], 99))
        rec = [lat for t, lat in driver.samples if t >= settle]
        assert len(rec) > 100, "the trace must extend past the recovery"
        rec_p99 = float(np.percentile(rec, 99))
        assert rec_p99 <= 1.2 * base_p99, (
            f"post-join p99 TTFT {rec_p99 * 1e3:.1f}ms vs no-failure "
            f"{base_p99 * 1e3:.1f}ms — recovery too slow")

    def test_join_grows_capacity_for_new_arrivals(self):
        # long enough that arrivals keep coming well past the newcomer's
        # ready point (join + params load: the engine only becomes routable
        # once the model is resident)
        cfg = TraceConfig(n_sessions=700, followups_per_session=1.0,
                          req_rate=40.0, seed=5)
        trace = generate_trace(cfg)
        t_mid = trace[len(trace) // 2].t
        rep, router, driver = self._run(trace, joins=((t_mid, 3),))
        assert 3 in router.engines, "growth join registers a 4th engine"
        assert driver.counters["joins"] == 1
        assert router.engines[3].prefills > 0, \
            "the newcomer must actually absorb load"
