"""Edge cases for the dist layer: indivisible-dim fallback, hints outside a
rules context, and compressed collectives on degenerate gradients."""

import jax.numpy as jnp
import numpy as np
from jax.sharding import AbstractMesh

from repro.dist import sharding as shd
from repro.dist.compression import quantize_int8
from repro.dist.hints import get_rules, hint, sharding_rules
from repro.launch.mesh import make_local_mesh


def mesh1():
    return AbstractMesh((16, 16), ("data", "model"))


class TestCheckFallback:
    def test_indivisible_dim_drops_axis(self):
        spec = shd._check(mesh1(), (10, 48), ("data", "model"))
        assert tuple(spec) == (None, "model")

    def test_both_indivisible_fully_replicates(self):
        spec = shd._check(mesh1(), (3, 7), ("data", "model"))
        assert tuple(spec) == (None, None)

    def test_tuple_axis_partial_fit(self):
        """(pod, data) on a batch divisible by pod (2) but not pod*data (32)
        keeps the divisible prefix instead of dropping everything."""
        mesh = AbstractMesh((2, 16, 16), ("pod", "data", "model"))
        spec = shd._check(mesh, (2, 64), (("pod", "data"), None))
        assert tuple(spec)[0] == "pod"

    def test_axis_never_used_twice(self):
        spec = shd._check(mesh1(), (32, 32), ("model", "model"))
        assert tuple(spec) == ("model", None)

    def test_unknown_axis_degrades_not_raises(self):
        """Rules naming an axis the mesh doesn't have must replicate."""
        mesh = AbstractMesh((4,), ("data",))
        spec = shd._check(mesh, (64, 64), ("data", "model"))
        assert tuple(spec) == ("data", None)

    def test_short_spec_padded_with_none(self):
        spec = shd._check(mesh1(), (32, 32, 32), ("data",))
        assert tuple(spec) == ("data", None, None)


class TestHintOutsideRules:
    def test_identity_object(self):
        x = jnp.ones((4, 8))
        assert hint(x, "dp", "tp") is x

    def test_no_rank_check_without_rules(self):
        """Outside a rules context hint must not even look at the roles."""
        x = jnp.ones((4, 8))
        assert hint(x, "dp") is x

    def test_rules_context_restored_after_exit(self):
        assert get_rules() is None
        with sharding_rules(make_local_mesh(1, 1)):
            assert get_rules() is not None
        assert get_rules() is None

    def test_nested_rules_restore_outer(self):
        m = make_local_mesh(1, 1)
        with sharding_rules(m) as outer:
            with sharding_rules(m):
                pass
            assert get_rules() is outer


# reuse the 1-device shard_map harness from the main compression tests
from test_compression import _PSUM  # noqa: E402


class TestCompressedPsumDegenerate:
    def test_zero_gradients(self):
        """All-zero gradients: scale 0 must not produce NaNs/Infs."""
        x = jnp.zeros((32,), jnp.float32)
        mean, err = _PSUM(x, jnp.zeros_like(x))
        assert np.all(np.asarray(mean) == 0.0)
        assert np.all(np.asarray(err) == 0.0)

    def test_constant_gradients(self):
        """A constant tensor maps to q = +/-127; the residual is at most one
        float rounding step and the EF invariant mean + err == x is exact."""
        x = jnp.full((16,), -3.5, jnp.float32)
        mean, err = _PSUM(x, jnp.zeros_like(x))
        s = 3.5 / 127.0
        assert np.abs(np.asarray(err)).max() <= s / 2
        np.testing.assert_array_equal(np.asarray(mean + err), np.asarray(x))

    def test_quantize_zero_tensor(self):
        q, s = quantize_int8(jnp.zeros((8,)))
        assert float(s) == 0.0
        assert np.all(np.asarray(q) == 0)
