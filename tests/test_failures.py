"""Failure-path suite: durability windows, atomic node drops, and serving
failover (ISSUE 5).

The paper's compute-on-data-path keeps fresh output on the node that made it,
so a node failure can take the only copy of a dataset — or a parked session's
KV cache — down with it. These tests pin the failure semantics:

* ``drop_node`` is atomic: replicas forgotten, in-flight write-back flushes
  sourced on the dead node cancelled (no phantom PFS copies), pins cleared;
* sole-copy loss re-runs the producer, replicated loss does not;
* dirty loss re-runs, flushed loss does not — per durability policy;
* a transfer cannot "arrive" from a node that died mid-flight;
* a parked session whose engine died resumes bit-identically on a surviving
  engine, without a prefill, when its KV slice was durable.
"""

import dataclasses

import jax
import pytest

from repro.configs import get_smoke
from repro.core.dag import TaskGraph
from repro.core.hints import Complexity, size_hint, task
from repro.core.locstore import (LocStore, Placement, REMOTE_TIER, SimObject,
                                 StorageHierarchy, TierSpec, tiered_hierarchy)
from repro.core.scheduler import LocalityScheduler, ProactiveScheduler
from repro.core.simulator import WorkflowSimulator
from repro.core.wfcompiler import HPC_CLUSTER, compile_workflow
from repro.core.workloads import pipeline_chain_workflow
from repro.models import init_params
from repro.serve.engine import Router, ServingEngine, _cache_name

GB = float(1 << 30)
MB = float(1 << 20)


def small_tiers(cap: float = 1e6) -> StorageHierarchy:
    return tiered_hierarchy(hbm_bytes=cap, host_bytes=cap, bb_bytes=cap)


# --------------------------------------------------------------- store layer
class TestDurabilityWindows:
    def test_pending_writeback_is_not_durable(self):
        st = LocStore(2, hierarchy=small_tiers(), write_policy="back")
        for n in "wxyz":                      # w falls off bb -> queued flush
            st.put(n, SimObject(8e5), loc=0)
        assert st.writeback.has("w")
        assert not st.durable("w"), "queued bytes have not crossed the network"
        st.drain_writebacks()
        assert st.durable("w"), "a drained flush is what durability means"

    def test_flush_before_ack_put_is_durable(self):
        st = LocStore(2, durability="flush_before_ack")
        st.put("a", SimObject(1e6), loc=0)
        assert st.durable("a")
        assert st.fsyncs == 1 and st.fsync_bytes == 1e6
        assert st.transfers[-1].kind == "fsync"

    def test_fsync_on_barrier_window(self):
        st = LocStore(2, durability="fsync_on_barrier")
        st.put("a", SimObject(1e6), loc=0)
        st.put("b", SimObject(2e6), loc=1)
        assert not st.durable("a") and not st.durable("b")
        assert st.barrier() == 2
        assert st.durable("a") and st.durable("b")
        assert st.barrier() == 0, "nothing dirty: the barrier is free"

    def test_flush_before_ack_migrate_keeps_window_closed(self):
        st = LocStore(2, durability="flush_before_ack")
        st.put("a", SimObject(1e6), loc=0)
        st.migrate("a", 1)                    # re-pin drops the PFS replica…
        assert st.durable("a"), "…but the policy re-flushes before returning"

    def test_fsync_supersedes_pending_writeback(self):
        st = LocStore(2, hierarchy=small_tiers(), write_policy="back")
        for n in "wxyz":
            st.put(n, SimObject(8e5), loc=0)
        assert st.writeback.has("w")
        assert st.fsync(["w"]) == 1
        assert st.durable("w")
        assert not st.drain_writebacks(), "the fsync IS the flush"

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="durability"):
            LocStore(2, durability="eventually")


class TestDropNode:
    def test_sole_copy_lost_replicated_survives(self):
        st = LocStore(3)
        st.put("sole", SimObject(1e5), loc=0)
        st.put("dup", SimObject(1e5), loc=(0, 1))
        rep = st.drop_node(0)
        assert rep.lost == ("sole",) and rep.survived == ("dup",)
        assert not st.exists("sole"), "exists() must turn False: re-run"
        assert st.exists("dup") and st.stat("dup").nodes == (1,)

    def test_durable_object_survives_node_loss(self):
        st = LocStore(2, durability="flush_before_ack")
        st.put("a", SimObject(1e6), loc=0)
        rep = st.drop_node(0)
        assert rep.survived == ("a",) and rep.lost == ()
        assert st.exists("a")
        assert st.stat("a").nodes == (REMOTE_TIER,)

    def test_phantom_writeback_cancelled(self):
        """Regression (ISSUE 5 satellite 1): a pending flush sourced on the
        dead node must be cancelled — a later drain must NOT mark the lost
        object durable on the strength of a phantom PFS copy."""
        st = LocStore(2, hierarchy=small_tiers(), write_policy="back")
        for n in "wxyz":
            st.put(n, SimObject(8e5), loc=0)
        assert st.writeback.has("w")          # flush queued, bytes NOT moved
        rep = st.drop_node(0)
        assert rep.cancelled_flushes == 1
        assert rep.phantom_remote_revoked == 1
        assert "w" in rep.lost and "w" in rep.dirty_lost
        assert not st.drain_writebacks(), "cancelled flush must not drain"
        assert not st.exists("w")
        assert st.phantom_durable == 0, "drop_node beat the drain to it"

    def test_drain_defense_in_depth(self):
        """Even when a caller skips drop_node, a drain sourced on a node in
        the failed set must not launder lost bytes into durability."""
        st = LocStore(2, hierarchy=small_tiers(), write_policy="back")
        for n in "wxyz":
            st.put(n, SimObject(8e5), loc=0)
        st._failed_nodes.add(0)               # failure outside drop_node
        assert not st.drain_writebacks()
        assert st.phantom_durable >= 1
        assert not st.durable("w")

    def test_pins_cleared_for_dead_node(self):
        """Regression (satellite 2): a failed node's pin refcounts must not
        keep shielding ghosts in ``_victim``."""
        st = LocStore(2)
        st.put("p", SimObject(1e5), loc=(0, 1))
        st.pin("p", 0)
        st.pin("p", 0)
        st.pin("p", 1)
        rep = st.drop_node(0)
        assert rep.released_pins == 2
        assert not st.is_pinned("p", 0)
        assert st.is_pinned("p", 1), "the survivor's pin stands"

    def test_default_placement_avoids_failed_nodes(self):
        from repro.core.locstore import _stable_hash
        st = LocStore(4)
        home = _stable_hash("obj") % 4        # where the hash would put it
        st.drop_node(home)
        p = st.put("obj", SimObject(1e5))
        assert p.real_loc != home
        assert p.real_loc not in st.failed_nodes

    def test_dirty_lost_accounting(self):
        st = LocStore(2, write_policy="back")
        st.put("d", SimObject(1e5), loc=0)    # dirty: no PFS copy yet
        st.fsync(["d"])
        st.put("e", SimObject(1e5), loc=0)    # dirty
        rep = st.drop_node(0)
        assert "e" in rep.dirty_lost
        assert "d" in rep.survived, "the flushed object survived on the PFS"


# ----------------------------------------------------------- simulator layer
def _chain_wf(depth: int = 6):
    return compile_workflow(pipeline_chain_workflow(4, depth), HPC_CLUSTER)


class TestSimulatorFailures:
    def test_sole_copy_loss_reruns_producer(self):
        g = TaskGraph()
        g.add_data("src", size_bytes=size_hint(256 * MB))
        g.add_task("produce", inputs=("src",), outputs=("mid",),
                   hints=task(compute=Complexity("linear",
                                                 flops_per_byte=2000.0)))
        g.add_task("consume", inputs=("mid",), outputs=("out",),
                   hints=task(compute=Complexity("linear",
                                                 flops_per_byte=2000.0)))
        wf = compile_workflow(g, HPC_CLUSTER)
        base = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=2,
                                 hw=HPC_CLUSTER).run()
        assert base.reruns == 0
        # fail the producing node right after `produce` finishes
        t_fail = base.task_records["produce"]["finish"] + 1e-3
        node = base.task_records["produce"]["node"]
        r = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=2,
                              hw=HPC_CLUSTER, failures=[(t_fail, node)]).run()
        assert r.reruns >= 1, "sole-copy loss must re-run the producer"
        assert r.tasks_done == len(wf.graph.tasks)

    def test_replicated_loss_does_not_rerun(self):
        g = TaskGraph()
        g.add_data("src", size_bytes=size_hint(256 * MB))
        g.add_data("mid", pinned_loc=(0, 1))   # S_LOC: replicate the output
        g.add_task("produce", inputs=("src",), outputs=("mid",),
                   hints=task(compute=Complexity("linear",
                                                 flops_per_byte=2000.0)))
        g.add_task("consume", inputs=("mid",), outputs=("out",),
                   hints=task(compute=Complexity("linear",
                                                 flops_per_byte=2000.0)))
        wf = compile_workflow(g, HPC_CLUSTER)
        base = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=3,
                                 hw=HPC_CLUSTER).run()
        t_fail = base.task_records["produce"]["finish"] + 1e-3
        r = WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=3,
                              hw=HPC_CLUSTER, failures=[(t_fail, 0)]).run()
        # the requeued-if-running task may count one rerun; the replicated
        # dataset itself must not force a producer re-execution
        assert all(not rep.lost or "mid" not in rep.lost
                   for rep in r.drop_reports)
        assert r.tasks_done == len(wf.graph.tasks)

    def test_dirty_loss_reruns_flushed_loss_does_not(self):
        """The headline durability claim: under write-back, a mid-run failure
        re-runs every dirty sole-copy producer; fsync_on_barrier bounds the
        window to one barrier interval, at an io-wait cost."""
        wf = _chain_wf()
        results = {}
        for pol in ("none", "fsync_on_barrier", "flush_before_ack"):
            r = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                  hw=HPC_CLUSTER, write_policy="back",
                                  durability=pol, failures=[(4.0, 0)]).run()
            assert r.tasks_done == len(wf.graph.tasks)
            assert r.phantom_durable == 0
            results[pol] = r
        none, barrier = results["none"], results["fsync_on_barrier"]
        ack = results["flush_before_ack"]
        assert none.dirty_lost > 0, "the failure must hit dirty data"
        assert barrier.dirty_lost == 0 and ack.dirty_lost == 0
        assert barrier.reruns < none.reruns
        assert ack.reruns < none.reruns
        assert barrier.fsyncs > 0 and ack.fsyncs > 0
        assert none.fsyncs == 0

    def test_failure_cancelled_task_releases_pins(self):
        """Regression (satellite 2): prefetch pins of a task cancelled by the
        failure must be released — task-finish unpin never fires for it."""
        wf = _chain_wf()
        sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                hw=HPC_CLUSTER, write_policy="back",
                                failures=[(4.0, 0), (4.5, 2)])
        r = sim.run()
        assert r.tasks_done == len(wf.graph.tasks)
        assert sim.store.movement_report()["pins"] == 0, "leaked pin refcounts"

    def test_transfer_from_dead_node_aborts(self):
        """Regression (satellite 3): an in-flight prefetch whose SOURCE node
        dies must not 'arrive' and materialize a replica."""
        C = lambda: Complexity("linear", flops_per_byte=2000.0)  # noqa: E731
        g = TaskGraph()
        g.add_data("seed", size_bytes=size_hint(256 * MB))
        g.add_data("big0", size_bytes=size_hint(5 * GB))
        g.add_data("big1", size_bytes=size_hint(4 * GB))
        g.add_task("warm", inputs=("seed",), outputs=("w",),
                   hints=task(compute=C()))
        g.add_task("consume", inputs=("w", "big0", "big1"), outputs=("out",),
                   hints=task(compute=C()))
        wf = compile_workflow(g, HPC_CLUSTER)
        sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=3,
                                hw=HPC_CLUSTER, external_loc="scattered",
                                failures=[(1.0, 1)])
        # deterministic geometry: warm on node 2; consume preassigned to
        # node 0 (big0's 5 GB gravity) so big1 prefetches node 1 -> node 0,
        # a ~3 s transfer that is mid-flight when node 1 dies at t=1
        sim.store.migrate("seed", 2)
        sim.store.migrate("big0", 0)
        sim.store.migrate("big1", 1)
        r = sim.run()
        assert r.prefetch_aborts >= 1, "the dead-source transfer arrived"
        assert r.tasks_done == len(wf.graph.tasks)
        # big1 was re-staged from the PFS, not from the ghost of node 1
        assert sim.store.stat("big1").resident_on(0) or \
            sim.store.stat("big1").nodes == (REMOTE_TIER,)

    def test_fsync_rides_demand_lane(self):
        """fsync-on-barrier's cost is real: the same workload pays more
        io-wait than durability='none' because flushes block the demand NIC."""
        wf = _chain_wf()
        free = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                 hw=HPC_CLUSTER, write_policy="back").run()
        paid = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=4,
                                 hw=HPC_CLUSTER, write_policy="back",
                                 durability="fsync_on_barrier").run()
        assert paid.fsyncs > 0
        assert paid.io_wait_total >= free.io_wait_total
        assert paid.makespan >= free.makespan

    def test_risk_aware_priority_orders_at_risk_consumers(self):
        """Durability as a scheduling signal: with equal upward ranks, the
        consumer of sole-copy non-durable bytes outranks one whose input is
        already safe on the PFS."""
        C = lambda: Complexity("linear", flops_per_byte=2000.0)  # noqa: E731
        g = TaskGraph()
        g.add_data("risky", size_bytes=size_hint(1 * GB))
        g.add_data("safe", size_bytes=size_hint(1 * GB))
        g.add_task("eat_risky", inputs=("risky",), outputs=("o1",),
                   hints=task(compute=C()))
        g.add_task("eat_safe", inputs=("safe",), outputs=("o2",),
                   hints=task(compute=C()))
        wf = compile_workflow(g, HPC_CLUSTER)
        sched = LocalityScheduler(wf, risk_aware=True)
        sim = WorkflowSimulator(wf, sched, n_nodes=1, hw=HPC_CLUSTER)
        sim.store.migrate("risky", 0)          # sole node-local copy: dirty
        assert not sim.store.durable("risky")
        assert sim.store.durable("safe")       # external on the PFS
        sched.note_ready("eat_risky")
        sched.note_ready("eat_safe")
        ranks = sorted(["eat_safe", "eat_risky"],
                       key=lambda t: sched._queue_key(t, sim.cluster))
        assert ranks[0] == "eat_risky"


# -------------------------------------------------------------- serving layer
@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _failover_store(kv: float, durability: str = "flush_before_ack"):
    return LocStore(2, hierarchy=tiered_hierarchy(
        hbm_bytes=2 * kv, host_bytes=2 * kv, bb_bytes=float(1 << 30)),
        write_policy="back", durability=durability)


class TestServingFailover:
    def test_failover_resumes_bit_identical_no_prefill(self, setup):
        cfg, params = setup
        kv = ServingEngine(cfg, params, max_batch=2, max_seq=64).slot_bytes()

        # control: same park/resume lifecycle, no failure, single engine
        ctrl = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                             store=_failover_store(kv))
        sid_c = ctrl.submit([5, 6, 7])
        for _ in range(3):
            ctrl.step()
        ctrl.park(sid_c)
        ctrl.resume(sid_c)
        for _ in range(3):
            ctrl.step()
        want = ctrl.sessions[sid_c].tokens[:7]

        store = _failover_store(kv)
        a = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                          store=store)
        b = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=1,
                          store=store)
        router = Router([a, b], store)
        sid = a.submit([5, 6, 7])
        for _ in range(3):
            a.step()
        a.park(sid)
        assert store.durable(_cache_name(sid))
        prefills = a.prefills + b.prefills
        rep = router.fail_engine(0)
        assert rep.resumed == (sid,) and rep.lost == ()
        assert router.failover_resumes == 1
        assert a.prefills + b.prefills == prefills, \
            "failover must save the re-prefill"
        assert b.sessions[sid].slot is not None
        for _ in range(3):
            b.step()
        assert b.sessions[sid].tokens[:7] == want, \
            "decode after failover must be bit-identical"
        assert store.getxattr(_cache_name(sid), "engine") == 1

    def test_live_slot_session_is_lost(self, setup):
        cfg, params = setup
        kv = ServingEngine(cfg, params, max_batch=2, max_seq=64).slot_bytes()
        store = _failover_store(kv)
        a = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                          store=store)
        b = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=1,
                          store=store)
        router = Router([a, b], store)
        sid = a.submit([1, 2])                # live: KV is engine memory
        rep = router.fail_engine(0)
        assert rep.lost == (sid,) and rep.resumed == ()
        assert router.failover_lost == 1
        assert not store.exists(_cache_name(sid))

    def test_parked_inside_open_window_is_lost(self, setup):
        cfg, params = setup
        kv = ServingEngine(cfg, params, max_batch=2, max_seq=64).slot_bytes()
        store = _failover_store(kv, durability="none")
        a = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                          store=store)
        b = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=1,
                          store=store)
        router = Router([a, b], store)
        sid = a.submit([1, 2, 3])
        a.park(sid)
        assert not store.durable(_cache_name(sid))
        rep = router.fail_engine(0)
        assert rep.lost == (sid,), \
            "an un-flushed parked slice dies with its node"

    def test_saturated_survivor_adopts_parked_not_lost(self, setup):
        """Capacity pressure must not forfeit a durable replica: when the
        surviving engine has no free slot, the failed-over session is
        adopted PARKED (no slot needed) and resumes on a later turn."""
        cfg, params = setup
        kv = ServingEngine(cfg, params, max_batch=1, max_seq=64).slot_bytes()
        store = LocStore(2, hierarchy=tiered_hierarchy(
            hbm_bytes=2 * kv, host_bytes=2 * kv, bb_bytes=float(1 << 30)),
            write_policy="back", durability="flush_before_ack")
        a = ServingEngine(cfg, params, max_batch=1, max_seq=64, node=0,
                          store=store)
        b = ServingEngine(cfg, params, max_batch=1, max_seq=64, node=1,
                          store=store)
        router = Router([a, b], store, allow_park=False)
        sid = a.submit([5, 6, 7])
        for _ in range(2):
            a.step()
        a.park(sid)
        want_next = None
        busy = b.submit([4, 4])               # saturate the survivor
        rep = router.fail_engine(0)
        assert rep.resumed == (sid,), "a full engine is still a valid home"
        assert b.sessions[sid].slot is None, "adopted parked, not resumed"
        assert store.exists(_cache_name(sid)), "the durable slice survives"
        assert store.getxattr(_cache_name(sid), "engine") == 1, "re-homed"
        b.finish(busy)                        # a slot frees up later…
        assert b.resume(sid)                  # …and the session re-hydrates
        tok = b.step()
        want_next = tok.get(sid)
        assert want_next is not None, "decode continues after late resume"

    def test_incompatible_slot_shape_deferred_not_lost(self, setup):
        # a durable slice no *currently registered* engine can load is not
        # forfeited: it parks unhomed (failover_deferred) and the next
        # compatible join_engine adopts it without a prefill
        cfg, params = setup
        kv = ServingEngine(cfg, params, max_batch=2, max_seq=64).slot_bytes()
        store = _failover_store(kv)
        a = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=0,
                          store=store)
        b = ServingEngine(cfg, params, max_batch=2, max_seq=32, node=1,
                          store=store)       # different max_seq: shape clash
        router = Router([a, b], store)
        sid = a.submit([5, 6, 7])
        a.park(sid)
        rep = router.fail_engine(0)
        assert rep.deferred == (sid,) and rep.resumed == () and rep.lost == ()
        assert router.failover_deferred == 1 and router.failover_lost == 0
        assert store.exists(_cache_name(sid)), \
            "the durable slice must survive the no-compatible-home window"
        # a compatible engine joins: the deferred session is adopted
        c = ServingEngine(cfg, params, max_batch=2, max_seq=64, node=2,
                          store=store)
        jrep = router.join_engine(2, c)
        assert jrep.adopted == (sid,)
        assert router.failover_resumes == 1
        assert c.sessions[sid].slot is not None, "free slot: resumed"
