"""Per-architecture smoke + consistency tests (all 10 assigned archs).

The strongest invariant: for every family, ``prefill(S-1) + decode_step``
must equal ``prefill(S)`` at the last position — this exercises every cache /
recurrent-state path (KV caches, MLA absorbed decode, Mamba chunked-vs-step
equivalence, RWKV state carry, cross-attn caches) against the parallel
formulation.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke
from repro.models import (decode_step, init_decode_state, init_params,
                          loss_fn, param_count, prefill)

S = 24
B = 2


def f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


def make_batch(cfg, rng, seq=S):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, seq)),
                                   jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frames, cfg.d_model)), cfg.dtype)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), cfg.dtype)
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(7)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_shapes_and_finite(arch, rng):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    assert param_count(cfg) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_gradients_finite_and_nonzero(arch, rng):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    grads = jax.jit(jax.grad(
        lambda p: loss_fn(cfg, p, batch)[0]))(params)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    gnorm = float(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves)) ** 0.5
    assert gnorm > 1e-6


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_plus_decode_matches_full_prefill(arch, rng):
    """decode(prefill(S-1), tok_{S-1}) == prefill(S) — the cache invariant.

    MoE archs run with a non-dropping capacity factor: capacity drops are
    computed over the whole prefill batch but never at decode (batch of 1),
    so equality only holds when nothing is dropped — the invariant under test
    is the CACHE path, not capacity semantics."""
    cfg = f32(get_smoke(arch))
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, rng)
    max_seq = S + 8

    full_logits, _ = jax.jit(
        lambda p, b: prefill(cfg, p, b, max_seq))(params, batch)

    short = dict(batch)
    short["tokens"] = batch["tokens"][:, : S - 1]
    short["labels"] = batch["labels"][:, : S - 1]
    _, state = jax.jit(
        lambda p, b: prefill(cfg, p, b, max_seq))(params, short)
    step_logits, _ = jax.jit(
        lambda p, st, t: decode_step(cfg, p, st, t))(
            params, state, batch["tokens"][:, S - 1: S])

    a = np.asarray(full_logits[:, -1], np.float32)
    b = np.asarray(step_logits[:, -1], np.float32)
    # compare normalized log-probs (absolute logits can drift by a constant)
    a = a - a.max(-1, keepdims=True)
    b = b - b.max(-1, keepdims=True)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_fresh_decode_state_usable(arch, rng):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    st = init_decode_state(cfg, B, 16)
    logits, st2 = jax.jit(lambda p, s, t: decode_step(cfg, p, s, t))(
        params, st, jnp.zeros((B, 1), jnp.int32))
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(st2["pos"][0]) == 1


def test_vocab_padding_masks_logits(rng):
    """granite vocab 49155 -> padded; pad logits must be -inf-ish."""
    cfg = get_smoke("granite-3-2b")        # vocab=503 -> padded 512
    params = init_params(cfg, jax.random.PRNGKey(0))
    st = init_decode_state(cfg, B, 8)
    logits, _ = decode_step(cfg, params, st, jnp.zeros((B, 1), jnp.int32))
    pad = np.asarray(logits[..., cfg.vocab:], np.float32)
    assert (pad < -1e20).all()


def test_moe_routing_responds_to_input(rng):
    """Different tokens must route to different experts (not degenerate)."""
    from repro.models.moe import moe_ffn
    from repro.models.moe import init_moe
    cfg = get_smoke("arctic-480b")
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_model)), jnp.float32)
    out, aux = moe_ffn(cfg, p, x)
    assert out.shape == x.shape
    assert float(aux) > 0
    # permuting tokens permutes outputs (routing is per-token)
    perm = jnp.asarray([0, 2, 1] + list(range(3, 16)))
    out_p, _ = moe_ffn(cfg, p, x[:, perm])
    np.testing.assert_allclose(np.asarray(out[:, perm]), np.asarray(out_p),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_differs_from_full(rng):
    """gemma local layers actually mask: long-range key must not attend."""
    import dataclasses as dc
    cfg = f32(get_smoke("gemma3-12b"))
    cfg_full = dc.replace(cfg, sliding_window=10_000)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, np.random.default_rng(0), seq=40)
    l1, _ = loss_fn(cfg, params, batch)
    l2, _ = loss_fn(cfg_full, params, batch)
    assert abs(float(l1) - float(l2)) > 1e-6
