"""Equivalence suite for the indexed scheduler path (PR 6 tentpole).

The indexed mode (placement mirror, move-cost term cache, ready-queue heap,
event-maintained preplace eligibility, simulator candidate index) must be
**decision-identical** to the full-rescan reference path — same assignment
for every task, same timing, same SimResult counters, bit for bit. These
tests run both modes on seeded workflows under the nastiest store
configuration we have (node failures, tight tier caps forcing evictions,
async write-back, coordinated eviction, fsync-on-barrier durability) and
compare everything.
"""

import dataclasses

import pytest

from repro.core import (ClusterTopology, FCFSScheduler, HPC_CLUSTER,
                        LocalityScheduler, ProactiveScheduler,
                        compile_workflow)
from repro.core.locstore import StorageHierarchy, TierSpec
from repro.core.simulator import WorkflowSimulator
from repro.core.workloads import mapreduce_workflow, random_layered_workflow

FAILURES = [(20.0, 1), (60.0, 3)]
# a full membership cycle: node 1 fails, rejoins live (clearing the failed
# mark and re-replicating sole copies toward it), node 3 fails later and
# stays down, and node 9 is a growth join beyond the initial n_nodes=8.
# Times sit inside even the shortest workflow's makespan (~20s) so every
# event actually fires.
MEMBERSHIP = {"failures": [(4.0, 1), (12.0, 3)],
              "joins": [(8.0, 1), (16.0, 9)]}


def tight_hierarchy():
    """Per-node caps small enough that replication + prefetch force
    evictions and write-back spills during the runs below."""
    return StorageHierarchy(
        [TierSpec("hbm", 6e9, 800e9), TierSpec("bb", 12e9, 10e9)],
        remote=TierSpec("remote", float("inf"), 0.5e9))


def build_workflow(kind):
    if kind == "mapreduce":
        g = mapreduce_workflow(12, 6, 2e9, flops_per_byte=4.0)
    else:
        g = random_layered_workflow(6, 10, seed=3, fan_in=3)
    return compile_workflow(g, HPC_CLUSTER)


def build_scheduler(kind, wf):
    if kind == "proactive":
        return ProactiveScheduler(wf, risk_aware=True)
    if kind == "locality":
        return LocalityScheduler(wf, speed_aware=True)
    return FCFSScheduler(wf)


def run_once(wf_kind, sched_kind, *, indexed, failures, joins=(),
             topology=None):
    wf = build_workflow(wf_kind)
    sim = WorkflowSimulator(
        wf, build_scheduler(sched_kind, wf),
        n_nodes=8, hw=HPC_CLUSTER, indexed=indexed,
        failures=list(failures), joins=list(joins),
        hierarchy=tight_hierarchy(),
        write_policy="back", coordinated_eviction=True,
        durability="fsync_on_barrier", topology=topology)
    return sim.run()


def scalar_counters(result):
    return {f.name: getattr(result, f.name)
            for f in dataclasses.fields(result)
            if isinstance(getattr(result, f.name), (int, float))}


@pytest.mark.parametrize("wf_kind", ["mapreduce", "random_layered"])
@pytest.mark.parametrize("sched_kind", ["proactive", "locality", "fcfs"])
@pytest.mark.parametrize("with_failures", [False, True],
                         ids=["healthy", "failures"])
def test_indexed_path_is_decision_identical(wf_kind, sched_kind,
                                            with_failures):
    failures = FAILURES if with_failures else []
    ref = run_once(wf_kind, sched_kind, indexed=False, failures=failures)
    idx = run_once(wf_kind, sched_kind, indexed=True, failures=failures)
    # assignment-for-assignment: node, start, finish, every recorded field
    assert idx.task_records == ref.task_records
    # and every scalar counter (makespan, bytes moved/local/remote,
    # evictions, writebacks, reruns, ...) — not approximately: exactly
    assert scalar_counters(idx) == scalar_counters(ref)


@pytest.mark.parametrize("wf_kind", ["mapreduce", "random_layered"])
@pytest.mark.parametrize("sched_kind", ["proactive", "locality", "fcfs"])
def test_indexed_path_identical_across_membership_cycle(wf_kind, sched_kind):
    """A fail -> rejoin -> fail -> growth-join cycle: the join_node event
    must let the indexed mirrors / candidate index / cached cluster views
    absorb the newcomer with the exact decisions the full-rescan reference
    makes — including the background re-replication transfers toward it."""
    ref = run_once(wf_kind, sched_kind, indexed=False, **MEMBERSHIP)
    idx = run_once(wf_kind, sched_kind, indexed=True, **MEMBERSHIP)
    assert idx.task_records == ref.task_records
    assert scalar_counters(idx) == scalar_counters(ref)
    assert idx.joins == 2
    assert idx.rereplications > 0, \
        "the cycle must actually stage copies toward the newcomers"
    assert [r.node for r in idx.join_reports] == [1, 9]
    assert idx.join_reports[0].rejoined and not idx.join_reports[0].grew
    assert idx.join_reports[1].grew and not idx.join_reports[1].rejoined


@pytest.mark.parametrize("wf_kind", ["mapreduce", "random_layered"])
@pytest.mark.parametrize("sched_kind", ["proactive", "locality", "fcfs"])
@pytest.mark.parametrize("mode", ["healthy", "failures", "membership"])
def test_flat_topology_is_bit_identical(wf_kind, sched_kind, mode):
    """A ``one_switch`` topology contributes structure only: the
    HardwareModel keeps its scalar link model and the simulator its legacy
    per-NIC lanes, so every config in this suite must produce the exact
    same task records and scalar counters with and without it — the
    flat-equivalence guarantee the topology module documents."""
    kw = {"failures": []}
    if mode == "failures":
        kw = {"failures": FAILURES}
    elif mode == "membership":
        kw = dict(MEMBERSHIP)
    ref = run_once(wf_kind, sched_kind, indexed=True, **kw)
    flat = run_once(wf_kind, sched_kind, indexed=True,
                    topology=ClusterTopology.one_switch(8), **kw)
    assert flat.task_records == ref.task_records
    assert scalar_counters(flat) == scalar_counters(ref)
    assert flat.cross_spine_bytes == 0.0
    assert flat.link_bytes == {}


def test_indexed_is_the_default_and_reference_is_reachable():
    """The simulator turns the indexed path on by default; the reference
    path stays reachable for future equivalence work."""
    wf = build_workflow("mapreduce")
    sim = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=8,
                            hw=HPC_CLUSTER)
    assert sim.indexed is True
    ref = WorkflowSimulator(wf, ProactiveScheduler(wf), n_nodes=8,
                            hw=HPC_CLUSTER, indexed=False)
    assert ref.indexed is False
