"""Topology-aware cluster model (PR 10 tentpole).

Four layers of proof: the link-graph math itself (paths, min-capacity
bandwidth, growth fallback); the cross-layer behavior under a real two-tier
fabric (rack-spread placement, per-link contention charging, aware-vs-blind
scheduling, predictive re-replication ahead of a flagged failure); the lint
rules that audit a topology before a run (trigger + clean pair each); and
the sanitizer checks that catch an injected desync in the topology-derived
caches, naming the first divergent entry. Flat-topology bit-equivalence
lives in tests/test_sched_equivalence.py.
"""

import pytest

from repro.analysis import sanitize
from repro.analysis.lint import lint
from repro.analysis.sanitize import SanitizerError
from repro.core import (ClusterTopology, HPC_CLUSTER, LocalityScheduler,
                        NodeProfile, ProactiveScheduler, SimConfig,
                        StorageHierarchy, TierSpec, WorkflowSimulator,
                        compile_workflow)
from repro.core.locstore import LocStore, SimObject
from repro.core.workloads import mapreduce_workflow, pipeline_chain_workflow

TIGHT = StorageHierarchy(
    [TierSpec("hbm", 6e9, 800e9), TierSpec("bb", 12e9, 10e9)],
    remote=TierSpec("remote", float("inf"), 0.5e9))

INF = float("inf")


# ------------------------------------------------------------- link graph
class TestTopologyModel:
    def test_two_tier_shapes_and_racks(self):
        topo = ClusterTopology.two_tier(2, 4, nic_gbps=1.25e9,
                                        oversubscription=4.0)
        assert topo.n_nodes == 8 and topo.n_racks == 2
        assert topo.rack_of == (0, 0, 0, 0, 1, 1, 1, 1)
        assert topo.same_rack(0, 3) and not topo.same_rack(3, 4)
        assert not topo.same_rack(0, -1)        # the PFS is in no rack
        assert topo.up_gbps == (0.3125e9, 0.3125e9)
        assert topo.up_capacity_gbps == (1.25e9, 1.25e9)

    def test_link_gbps_is_min_capacity_on_path(self):
        topo = ClusterTopology.two_tier(2, 4, nic_gbps=1.25e9,
                                        oversubscription=4.0, pfs_gbps=0.5e9)
        assert topo.link_gbps(0, 1) == 1.25e9           # rack-local: NIC
        assert topo.link_gbps(0, 4) == 0.3125e9         # cross-rack: uplink
        assert topo.link_gbps(0, -1) == 0.3125e9        # PFS via the uplink
        assert topo.link_gbps(3, 3) == INF              # self-transfer

    def test_links_enumerates_the_path(self):
        topo = ClusterTopology.two_tier(2, 2)
        assert topo.links(0, 1) == (0, 1)
        assert topo.links(0, 3) == (0, 3, ("up", 0), ("up", 1))
        assert topo.links(2, -1) == (2, ("up", 1), ("pfs",))

    def test_profiles_feed_speeds_nics_and_classes(self):
        profs = [NodeProfile(speed=0.5, cls="old-gen", nic_gbps=0.625e9),
                 NodeProfile(), NodeProfile(cls="spot"), NodeProfile()]
        topo = ClusterTopology.two_tier(2, 2, profiles=profs)
        assert topo.speed(0) == 0.5 and topo.speed(1) == 1.0
        assert topo.nic(0) == 0.625e9 and topo.nic(1) == 1.25e9
        assert topo.node_class(2) == "spot"
        assert topo.speeds() == {0: 0.5}
        # the slow NIC caps even a rack-local transfer from node 0
        assert topo.link_gbps(0, 1) == 0.625e9

    def test_growth_join_fallback(self):
        topo = ClusterTopology.two_tier(2, 2)
        # node 4 joined after the topology was frozen: round-robin rack,
        # default NIC, nominal profile
        assert topo.rack(4) == 0 and topo.rack(5) == 1
        assert topo.nic(4) == 1.25e9
        assert topo.speed(4) == 1.0 and topo.node_class(4) == "standard"

    def test_one_switch_is_flat(self):
        topo = ClusterTopology.one_switch(4)
        assert topo.flat and topo.n_racks == 1
        assert topo.link_gbps(0, 3) == INF and topo.link_gbps(0, -1) == INF
        assert topo.same_rack(0, 3)

    def test_validation(self):
        with pytest.raises(ValueError, match="rack_of"):
            ClusterTopology(n_nodes=2, rack_of=(0,), nic_gbps=(1e9, 1e9),
                            up_gbps=(1e9,), up_capacity_gbps=(1e9,),
                            oversub=(1.0,))
        with pytest.raises(ValueError, match="rack id"):
            ClusterTopology(n_nodes=2, rack_of=(0, 5), nic_gbps=(1e9, 1e9),
                            up_gbps=(1e9,), up_capacity_gbps=(1e9,),
                            oversub=(1.0,))
        with pytest.raises(ValueError, match="oversubscription"):
            ClusterTopology.two_tier(2, 2, oversubscription=0.0)


# --------------------------------------------------------- cross-layer sim
def aware_vs_blind(aware):
    wf = compile_workflow(mapreduce_workflow(12, 6, 2e9, flops_per_byte=4.0),
                          HPC_CLUSTER)
    topo = ClusterTopology.two_tier(2, 4, nic_gbps=1.25e9,
                                    oversubscription=4.0)
    sim = WorkflowSimulator(wf, LocalityScheduler(wf, speed_aware=True),
                            n_nodes=8, hw=HPC_CLUSTER, topology=topo,
                            topology_aware=aware, external_loc="scattered",
                            hierarchy=TIGHT, sanitize=True, sanitize_every=1)
    return sim, sim.run()


class TestTopologyCharging:
    def test_transfers_are_charged_per_link(self):
        sim, r = aware_vs_blind(True)
        # shuffle traffic crossed the spine and the ledger says where
        assert r.cross_spine_bytes > 0
        assert ("up", 0) in r.link_bytes and ("up", 1) in r.link_bytes
        assert any(isinstance(k, int) for k in r.link_bytes)   # NIC lanes
        # cross-spine is a subset of all charged bytes
        up = r.link_bytes[("up", 0)] + r.link_bytes[("up", 1)]
        assert r.cross_spine_bytes <= up + 1e-6

    def test_aware_beats_blind_on_oversubscribed_spine(self):
        """The whole point of the refactor: a scheduler/store that sees the
        topology moves fewer bytes across the oversubscribed spine and
        finishes sooner than one that plans with the flat model while the
        network charges real paths."""
        _, aware = aware_vs_blind(True)
        _, blind = aware_vs_blind(False)
        assert aware.cross_spine_bytes < blind.cross_spine_bytes
        assert aware.makespan < blind.makespan

    def test_topology_size_mismatch_is_refused(self):
        wf = compile_workflow(mapreduce_workflow(4, 2), HPC_CLUSTER)
        with pytest.raises(ValueError, match="topology"):
            WorkflowSimulator(wf, LocalityScheduler(wf), n_nodes=8,
                              hw=HPC_CLUSTER,
                              topology=ClusterTopology.two_tier(2, 2))


class TestRackAwareStore:
    def test_default_placement_spreads_across_racks(self):
        topo = ClusterTopology.two_tier(2, 4)
        store = LocStore(8, default_policy="rr", topology=topo)
        racks = [topo.rack(store.put(f"d{i}", SimObject(1)).nodes[0])
                 for i in range(8)]
        # round-robin placement alternates racks instead of filling rack 0
        assert racks[:4] == [0, 1, 0, 1]

    def test_rereplication_prefers_the_other_rack(self):
        topo = ClusterTopology.two_tier(2, 2)
        store = LocStore(4, topology=topo)
        store.put("near", SimObject(5), loc=1)    # rack 0, same as 0
        store.put("far", SimObject(5), loc=2)     # rack 1
        cands = store.rereplication_candidates(0)
        # equal risk and size: the cross-rack source ranks first — copying
        # it to node 0 buys rack-domain diversity
        assert [c[0] for c in cands] == ["far", "near"]

    def test_only_src_restricts_to_the_suspect(self):
        store = LocStore(4)
        store.put("a", SimObject(1), loc=1)
        store.put("b", SimObject(1), loc=2)
        cands = store.rereplication_candidates(0, only_src=1)
        assert [c[0] for c in cands] == ["a"]
        assert store.rereplicate_to(0, only_src=2) == ("b",)


# ------------------------------------------------- predictive re-replication
def predictive_run(predict):
    wf = compile_workflow(pipeline_chain_workflow(8, 6), HPC_CLUSTER)
    sim = WorkflowSimulator(wf, ProactiveScheduler(wf, risk_aware=True),
                            n_nodes=4, hw=HPC_CLUSTER, hierarchy=TIGHT,
                            failures=[(8.0, 1)], predict_failures=predict,
                            predict_lead_s=3.0, sanitize=True,
                            sanitize_every=1)
    return sim.run()


class TestPredictiveRereplication:
    def test_predictive_beats_reactive(self):
        """Flagging the failing node ``predict_lead_s`` early and draining
        its sole copies to another rack-domain must strictly reduce the
        data lost with the node — fewer reruns and dirty losses than the
        purely reactive run of the same schedule."""
        pred = predictive_run(True)
        react = predictive_run(False)
        assert pred.predictive_rereplications > 0
        assert pred.bytes_predictively_rereplicated > 0
        assert react.predictive_rereplications == 0
        assert (pred.dirty_lost + pred.reruns
                < react.dirty_lost + react.reruns)

    def test_predict_off_is_the_default(self):
        c = SimConfig.from_kwargs(n_nodes=4, hw=HPC_CLUSTER)
        assert c.predict_failures is False and c.topology_aware is True


# ------------------------------------------------------------------- lint
def lint_config(topology, n_nodes=None, **kw):
    return SimConfig.from_kwargs(
        n_nodes=topology.n_nodes if n_nodes is None else n_nodes,
        hw=HPC_CLUSTER, topology=topology, **kw)


class TestTopologyLint:
    WF = compile_workflow(mapreduce_workflow(8, 4, 2e9), HPC_CLUSTER)

    def rules(self, findings, rule):
        return [f for f in findings if f.rule == rule]

    def test_unreachable_node_flags_dead_links(self):
        topo = ClusterTopology(n_nodes=4, rack_of=(0, 0, 1, 1),
                               nic_gbps=(0.0, 1e9, 1e9, 1e9),
                               up_gbps=(1e9, 0.0),
                               up_capacity_gbps=(4e9, 0.0),
                               oversub=(1.0, 1.0))
        out = self.rules(lint(self.WF, config=lint_config(topo)),
                         "unreachable-node")
        targets = {f.target for f in out}
        assert "node0" in targets          # zero NIC
        assert "rack1" in targets          # zero uplink, two racks

    def test_unreachable_node_flags_size_mismatch_and_dead_pfs(self):
        topo = ClusterTopology.two_tier(2, 2, pfs_gbps=0.0)
        out = self.rules(
            lint(self.WF, config=lint_config(topo, n_nodes=8,
                                             external_loc="remote")),
            "unreachable-node")
        targets = {f.target for f in out}
        assert "topology.n_nodes" in targets
        assert "topology.pfs_gbps" in targets

    def test_unreachable_node_clean_on_healthy_topology(self):
        topo = ClusterTopology.two_tier(2, 4, oversubscription=4.0)
        out = self.rules(lint(self.WF, config=lint_config(topo)),
                         "unreachable-node")
        assert out == []

    def test_oversubscribed_link_triggers_on_starved_pfs(self):
        topo = ClusterTopology.two_tier(2, 4, pfs_gbps=1e4)
        out = self.rules(
            lint(self.WF, config=lint_config(topo, external_loc="remote")),
            "oversubscribed-link")
        assert any(f.target == "pfs" for f in out)

    def test_oversubscribed_link_triggers_on_thin_uplinks(self):
        topo = ClusterTopology.two_tier(2, 4, oversubscription=1e6)
        out = self.rules(
            lint(self.WF, config=lint_config(topo, external_loc="remote")),
            "oversubscribed-link")
        assert {f.target for f in out} >= {"rack0", "rack1"}

    def test_oversubscribed_link_factor_is_configurable(self):
        topo = ClusterTopology.two_tier(2, 4, pfs_gbps=1e4)
        cfg = lint_config(topo, external_loc="remote")
        assert self.rules(lint(self.WF, config=cfg), "oversubscribed-link")
        relaxed = lint(self.WF, config=cfg,
                       params={"oversub-factor": 1e12})
        assert self.rules(relaxed, "oversubscribed-link") == []

    def test_oversubscribed_link_clean_on_adequate_fabric(self):
        topo = ClusterTopology.two_tier(2, 4, pfs_gbps=2e9)
        out = self.rules(
            lint(self.WF, config=lint_config(topo, external_loc="remote")),
            "oversubscribed-link")
        assert out == []


# -------------------------------------------------------------- sanitizer
class TestTopologySanitizer:
    """The topology-derived caches, corrupted after a clean aware run, are
    caught — and the error names the first divergent entry."""

    @pytest.fixture(scope="class")
    def ran(self):
        sim, _ = aware_vs_blind(True)
        return sim

    def test_link_path_desync(self, ran):
        cache = ran._path_cache
        assert cache, "an aware run must populate the path table"
        sanitize.check_link_paths(cache, ran._topo_real)   # clean before
        key = sorted(cache)[0]
        stash = cache[key]
        cache[key] = stash + (("up", 99),)
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_link_paths(cache, ran._topo_real)
        finally:
            cache[key] = stash
        assert ei.value.check == "link-path" and ei.value.key == key

    def test_link_path_cache_must_be_empty_without_topology(self):
        with pytest.raises(SanitizerError) as ei:
            sanitize.check_link_paths({(0, 1): (0, 1)}, None)
        assert ei.value.check == "link-path"

    def test_link_row_desync(self, ran):
        rows = ran.cluster._link_rows
        if not rows:
            pytest.skip("run left no cached link rows")
        sanitize.check_link_rows(ran.cluster)              # clean before
        src = sorted(rows)[0]
        row, _ = rows[src]
        dst = (src + 1) % ran.cluster.n_nodes
        row[dst] += 1.0
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_link_rows(ran.cluster)
        finally:
            row[dst] -= 1.0
        assert ei.value.check == "link-row"
        assert ei.value.key == (src, dst)

    def test_link_row_uniform_marker_desync(self, ran):
        rows = ran.cluster._link_rows
        if not rows:
            pytest.skip("run left no cached link rows")
        src = sorted(rows)[0]
        row, uniform = rows[src]
        rows[src] = (row, 123.456)
        try:
            with pytest.raises(SanitizerError) as ei:
                sanitize.check_link_rows(ran.cluster)
        finally:
            rows[src] = (row, uniform)
        assert ei.value.check == "link-row"
        assert ei.value.key == (src, "uniform")
