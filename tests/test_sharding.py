"""Sharding rules: divisibility-aware specs on the production mesh shapes.

Uses AbstractMesh — axis sizes without devices — so the 16×16 and 2×16×16
rules are testable on a 1-CPU container.
"""

import jax
import pytest
from jax.sharding import AbstractMesh

from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.specs import (batch_specs_for, decode_specs_for,
                                params_specs_for)
from repro.configs.base import SHAPES


def mesh1():
    return AbstractMesh((16, 16), ("data", "model"))


def mesh2():
    return AbstractMesh((2, 16, 16), ("pod", "data", "model"))


def flat_with_paths(tree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


@pytest.mark.parametrize("mesh_fn", [mesh1, mesh2])
@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v3-671b",
                                  "zamba2-7b", "rwkv6-1.6b",
                                  "llama-3.2-vision-90b"])
def test_param_specs_divide(arch, mesh_fn):
    """Every assigned axis must divide its dim (else XLA errors at lower)."""
    mesh = mesh_fn()
    cfg = get_config(arch)
    shapes = params_specs_for(cfg)
    specs = shd.param_specs(cfg, shapes, mesh)
    for (path, leaf), (_, spec) in zip(flat_with_paths(shapes),
                                       flat_with_paths(specs)):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            assert dim % shd.axis_size(mesh, ax) == 0, (path, leaf.shape, spec)


def test_embed_sharded_vocab_model():
    cfg = get_config("granite-3-2b")
    shapes = params_specs_for(cfg)
    specs = shd.param_specs(cfg, shapes, mesh1())
    assert tuple(specs["embed"]["tok"]) == ("model", "data")


def test_expert_weights_get_ep():
    cfg = get_config("deepseek-v3-671b")
    shapes = params_specs_for(cfg)
    specs = shd.param_specs(cfg, shapes, mesh1())
    # stacked moe blocks: (n_layers, E, d, ff) -> FSDP on ff (d is the first
    # einsum's contraction dim; see dist.sharding._EXPERT_RULES)
    assert tuple(specs["moe_blocks"]["moe"]["w1"]) == \
        (None, "model", None, "data")
    # shared expert is a normal mlp
    assert tuple(specs["moe_blocks"]["moe"]["shared"]["w1"]) == \
        (None, "data", "model")


def test_batch_specs_shard_dp_when_divisible():
    cfg = get_config("granite-3-2b")
    b = batch_specs_for(cfg, SHAPES["train_4k"])
    spec = shd.batch_specs(cfg, b, mesh2())
    assert tuple(spec["tokens"])[0] == ("pod", "data")
    # long_500k batch=1 cannot shard
    b1 = batch_specs_for(cfg, SHAPES["long_500k"])
    spec1 = shd.batch_specs(cfg, b1, mesh2())
    assert tuple(spec1["tokens"])[0] is None


class TestDecodeStateSpecs:
    def test_gqa_kv8_falls_back_to_seq_sharding(self):
        cfg = get_config("granite-3-2b")     # kv=8 < model=16
        state, _ = decode_specs_for(cfg, SHAPES["decode_32k"])
        specs = shd.decode_state_specs(cfg, state, mesh1())
        k = tuple(specs["k"])                # (L, B, S, kv, hd)
        assert k[1] == "data" and k[2] == "model" and k[3] is None

    def test_gqa_kv16_shards_heads(self):
        cfg = get_config("gemma3-27b")       # kv=16 == model
        state, _ = decode_specs_for(cfg, SHAPES["decode_32k"])
        specs = shd.decode_state_specs(cfg, state, mesh1())
        k = tuple(specs["k"])
        assert k[3] == "model" and k[1] == "data"

    def test_long_500k_batch1_seq_takes_dp(self):
        cfg = get_config("gemma3-27b")
        state, _ = decode_specs_for(cfg, SHAPES["long_500k"])
        specs = shd.decode_state_specs(cfg, state, mesh1())
        k = tuple(specs["k"])                # B=1: seq gets data axes
        assert k[1] is None
        assert k[2] == "data" or k[2] == ("data",)

    def test_mla_latent_cache(self):
        cfg = get_config("deepseek-v3-671b")
        state, _ = decode_specs_for(cfg, SHAPES["decode_32k"])
        specs = shd.decode_state_specs(cfg, state, mesh1())
        c_kv = tuple(specs["moe_cache"][0])  # (L, B, S, c)
        assert c_kv[1] == "data" and c_kv[2] == "model"

    def test_rwkv_state_heads_sharded(self):
        cfg = get_config("rwkv6-1.6b")
        state, _ = decode_specs_for(cfg, SHAPES["decode_32k"])
        specs = shd.decode_state_specs(cfg, state, mesh1())
        assert tuple(specs["wkv"])[2] == "model"   # (L,B,H,K,K)


def test_check_never_assigns_indivisible():
    mesh = mesh1()
    spec = shd._check(mesh, (10, 48), ("data", "model"))
    assert tuple(spec) == (None, "model")   # 10 % 16 != 0 -> dropped
