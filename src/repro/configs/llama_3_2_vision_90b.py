"""llama-3.2-vision-90b — 100 layers = 20 groups of (4 self + 1 gated
cross-attn image layer). Vision frontend STUBBED: input_specs provides
precomputed patch embeddings (B, n_patches, d).
[hf:meta-llama/Llama-3.2-11B-Vision family; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256,
    cross_every=5, n_patches=1601, rope_theta=5e5,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-smoke", family="vlm",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, cross_every=3, n_patches=16,
    )
