"""ModelConfig — one dataclass covering all 10 assigned architecture families.

Every assigned architecture is expressed as a frozen :class:`ModelConfig`;
``src/repro/configs/<arch>.py`` holds the exact published numbers, and each
provides ``smoke()`` — the same family at toy scale for CPU tests.

Families:
  dense     — granite-3-2b, minitron-8b (plain GQA decoder)
  localglobal — gemma3-12b/27b (5:1 sliding-window:global attention)
  hybrid    — zamba2-7b (Mamba2 backbone + periodically-applied shared
              attention block)
  rwkv      — rwkv6-1.6b (attn-free, data-dependent decay)
  encdec    — whisper-medium (audio frontend stubbed to frame embeddings)
  moe       — deepseek-v3-671b (MLA + 1 shared/256 routed top-8 + MTP),
              arctic-480b (dense-residual + 128 routed top-2)
  vlm       — llama-3.2-vision-90b (cross-attention image layers; patch
              embeddings stubbed)
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "localglobal", "hybrid", "rwkv", "encdec", "moe",
                 "vlm"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    @property
    def cache_dim(self) -> int:
        """Per-token decode cache: compressed kv latent + shared rope key."""
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                    # 0 -> d_model // n_heads

    # -- attention pattern ----------------------------------------------------
    sliding_window: int = 0              # gemma3 local window (0 = none)
    global_every: int = 0                # gemma3: 1 global per this many layers
    rope_theta: float = 1e4

    # -- MoE --------------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                    # expert hidden (d_ff = dense hidden)
    n_shared_experts: int = 0            # deepseek shared expert(s)
    dense_residual: bool = False         # arctic: dense FFN in parallel w/ MoE
    first_dense_layers: int = 0          # deepseek: leading dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # -- MLA / MTP ----------------------------------------------------------------
    mla: MLAConfig | None = None
    mtp_depth: int = 0                   # deepseek multi-token-prediction heads

    # -- SSM hybrid (zamba2) -----------------------------------------------------
    ssm_state: int = 0                   # Mamba2 state dim per head
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0                  # shared attn applied after every k SSM layers
    ssm_head_dim: int = 64

    # -- RWKV ---------------------------------------------------------------------
    rwkv_head_dim: int = 64

    # -- encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0
    n_frames: int = 1500                 # stubbed audio frame embeddings

    # -- VLM (llama-3.2-vision) ----------------------------------------------------
    cross_every: int = 0                 # 1 cross-attn layer per this many self layers
    n_patches: int = 1601                # stubbed image patch embeddings (1 tile)

    # -- numerics / misc -------------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # ------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / linear-attn / local-attn hybrid)."""
        return self.family in ("rwkv", "hybrid", "localglobal")

    def validate(self) -> None:
        assert self.d_model > 0 and self.n_layers > 0 and self.vocab > 0
        if self.family not in ("rwkv",):
            assert self.n_heads > 0
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, \
                "q heads must be a multiple of kv heads (GQA)"
        if self.is_moe:
            assert 0 < self.experts_per_token <= self.n_experts
            assert self.moe_d_ff > 0
        if self.family == "localglobal":
            assert self.sliding_window > 0 and self.global_every > 0
        if self.family == "hybrid":
            assert self.ssm_state > 0 and self.attn_every > 0
        if self.family == "encdec":
            assert self.encoder_layers > 0
        if self.family == "vlm":
            assert self.cross_every > 0


# ---------------------------------------------------------------- input shapes
@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) cell and which step it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, InputShape] = {s.name: s for s in
                                 (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(cfg: ModelConfig) -> list[InputShape]:
    """The assigned shape set, with the documented skips applied.

    ``long_500k`` runs only for sub-quadratic families (SSM / linear-attn /
    local-attn hybrid) — the pure full-attention archs skip it, as recorded in
    DESIGN.md §Arch-applicability.
    """
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        out.append(LONG_500K)
    return out
