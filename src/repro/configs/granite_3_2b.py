"""granite-3-2b — IBM Granite 3.0 2B base: dense GQA decoder.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=49155, rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke", family="dense",
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab=503,  # deliberately non-multiple-of-256 (pad path)
    )
