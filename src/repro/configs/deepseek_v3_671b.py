"""deepseek-v3-671b — MLA + MoE (1 shared + 256 routed, top-8) + MTP.
First 3 layers dense (d_ff=18432); MoE expert hidden = 2048.
[arXiv:2412.19437; hf]"""
from repro.configs.base import MLAConfig, ModelConfig

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,                  # dense layers
    vocab=129280,
    n_experts=256, experts_per_token=8, moe_d_ff=2048,
    n_shared_experts=1, first_dense_layers=3,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1, rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=192, vocab=512,
        n_experts=8, experts_per_token=2, moe_d_ff=64,
        n_shared_experts=1, first_dense_layers=2,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        mtp_depth=1,
    )
