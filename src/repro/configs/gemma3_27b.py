"""gemma3-27b — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b", family="localglobal",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262144,
    sliding_window=1024, global_every=6, rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke", family="localglobal",
        n_layers=8, d_model=96, n_heads=6, n_kv_heads=3,
        d_ff=192, vocab=512, sliding_window=8, global_every=4,
    )
