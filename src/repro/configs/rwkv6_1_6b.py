"""rwkv6-1.6b — "Finch": attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="rwkv6-1.6b", family="rwkv",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, rwkv_head_dim=64,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke", family="rwkv",
        n_layers=3, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=128, vocab=512, rwkv_head_dim=32,
    )
