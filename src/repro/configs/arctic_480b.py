"""arctic-480b — Snowflake Arctic: 128 routed experts top-2 + dense residual
FFN in parallel in every layer. [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, experts_per_token=2, moe_d_ff=4864,
    dense_residual=True, rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=512,
        n_experts=8, experts_per_token=2, moe_d_ff=96,
        dense_residual=True,
    )
