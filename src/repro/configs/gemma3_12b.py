"""gemma3-12b — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="gemma3-12b", family="localglobal",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144,
    sliding_window=1024, global_every=6, rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-smoke", family="localglobal",
        n_layers=6, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512, sliding_window=16, global_every=3,
    )
