"""Architecture registry: ``get_config(name)`` / ``get_smoke(name)``.

Every assigned architecture is selectable by id (``--arch <id>``); smoke()
variants are the same family at CPU-test scale.
"""

from repro.configs.base import (InputShape, ModelConfig, SHAPES, shapes_for,
                                TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

from repro.configs import (arctic_480b, deepseek_v3_671b, gemma3_12b,
                           gemma3_27b, granite_3_2b, llama_3_2_vision_90b,
                           minitron_8b, rwkv6_1_6b, whisper_medium, zamba2_7b)

_MODULES = {
    "granite-3-2b": granite_3_2b,
    "minitron-8b": minitron_8b,
    "gemma3-12b": gemma3_12b,
    "gemma3-27b": gemma3_27b,
    "zamba2-7b": zamba2_7b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "whisper-medium": whisper_medium,
    "deepseek-v3-671b": deepseek_v3_671b,
    "arctic-480b": arctic_480b,
    "llama-3.2-vision-90b": llama_3_2_vision_90b,
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].FULL


def get_smoke(name: str) -> ModelConfig:
    return _MODULES[name].smoke()


__all__ = ["ARCH_NAMES", "get_config", "get_smoke", "ModelConfig",
           "InputShape", "SHAPES", "shapes_for", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K"]
