"""zamba2-7b — Mamba2 backbone + shared attention block every 6 layers.
81 SSM layers = 13 groups of 6 + 3 tail; the attention/MLP block params are
SHARED across all 13 application points (zamba's trick). [arXiv:2411.15242;
unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    attn_every=6, rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512,
        ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=32,
        attn_every=3,  # 2 groups + 2 tail layers
    )
