"""whisper-medium — encoder-decoder; conv audio frontend STUBBED: input_specs
provides precomputed frame embeddings (B, 1500, d). [arXiv:2212.04356;
unverified]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    encoder_layers=24, n_frames=1500,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, encoder_layers=2, n_frames=32,
    )
