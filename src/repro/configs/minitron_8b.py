"""minitron-8b — NVIDIA Minitron 8B (pruned Nemotron-4): dense GQA.
[arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000, rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-smoke", family="dense",
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2,
        d_ff=384, vocab=512,
    )
