"""Batched serving engine with location-aware, tier-aware session routing.

Continuous batching over a fixed pool of decode slots: each session owns one
batch slot of the shared KV-cache state; prefill admits sessions, decode steps
all active slots at once (one jitted ``decode_step`` regardless of how many
sessions are live — idle slots are masked).

The cross-layer part (paper → inference): a session's KV cache IS the paper's
"file". The :class:`Router` records each session's placement in the
distributed :class:`~repro.core.locstore.LocationService`; follow-up requests
look the session up and land on the engine/node that holds its cache
(compute-on-data-path), instead of re-prefilling elsewhere — the measured
saving is an entire prefill per follow-up turn (see bench_serving).

Session caches are first-class replicas in the tiered
:class:`~repro.core.locstore.LocStore` with their TRUE byte size (the batch-1
slice of the pooled decode state), so capacity accounting and eviction see
them:

* an **active** session's cache is pinned in the store's top tier (HBM);
* an **idle** session can be *parked* (:meth:`ServingEngine.park`): its KV
  slice is read out of the engine slot and demoted to the burst-buffer tier,
  freeing the slot for another session — under ``write_policy="back"`` the
  store's :class:`~repro.core.locstore.WriteBackQueue` flushes it to the PFS
  off the critical path if the burst buffer overflows too;
* a follow-up to a parked session *resumes* it: the store promotes the cache
  back to the top tier and the engine re-hydrates the slot from the stored
  slice — no re-prefill, which is the entire point.

The :class:`Router` is pressure- and tier-aware: a locality hit on a
saturated engine is priced (media time to promote the parked cache, plus the
demotions the promotion will cause, per ``store.tier_report(node=...)``)
against a migrate-and-re-prefill on a free engine (the engine's *measured*
prefill seconds), and the cheaper side wins.

**Failover** (:meth:`Router.fail_engine`): when an engine node dies, the
storage layer takes the atomic hit (``store.drop_node``) and every parked
session whose KV slice still has a surviving replica — on another node or as
a real (durability-policy-flushed) PFS copy — is *re-hydrated on a surviving
engine* with a matching slot shape instead of re-prefilled; decode continues
bit-identically. Sessions live in a slot, or parked inside an open
durability window, are lost and need a fresh prefill.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.config import ServingConfig
from repro.core.locstore import DropReport, JoinReport, LocStore, Placement
from repro.core.prefetch import PrefetchEngine
from repro.models import model as M

Pytree = Any


@dataclasses.dataclass
class KVSlice:
    """One session's KV-cache slice as a store object with a true byte size.

    ``state`` is the batch-1 decode-state pytree for a parked session, or
    ``None`` while the session is live in an engine slot (the store then
    holds a correctly-*sized* placeholder — capacity accounting and eviction
    must see the real bytes either way; the zero-byte registration of the
    pre-tiered engine hid serving traffic from the storage layer entirely).
    """

    state: Pytree | None
    nbytes: float


@dataclasses.dataclass
class Session:
    sid: int
    slot: int | None              # None while parked (KV lives in the store)
    prompt_len: int
    tokens: list[int]
    done: bool = False
    last_active: int = 0          # engine activity clock at last touch


def _cache_name(sid: int) -> str:
    return f"kvcache:session:{sid}"


def _state_signature(state: Pytree) -> tuple:
    """The slot-compatibility fingerprint: pytree structure + per-leaf shape
    and dtype (one definition — ``slot_signature`` and ``compatible_state``
    must never drift apart)."""
    return (jax.tree.structure(state),
            tuple((tuple(leaf.shape), str(leaf.dtype))
                  for leaf in jax.tree.leaves(state)))


class JaxComputeBackend:
    """The real model-compute backend (and the default): jitted
    prefill/decode over the pooled decode state, slot extraction via jax
    scatter/gather.

    The engine delegates every compute- and state-layout-touching operation
    to its backend, so the routing/park/resume/failover machinery can also be
    driven by a compute-free stand-in (``repro.serve.traffic.SyntheticBackend``)
    at 10^5-session scale — the storage-layer behaviour (true KV byte sizes,
    tier residency, eviction) is identical either way.
    """

    def __init__(self, cfg: ModelConfig, max_seq: int) -> None:
        cfg.validate()
        self.cfg = cfg
        self.max_seq = max_seq
        self._decode = jax.jit(lambda p, s, t: M.decode_step(cfg, p, s, t))
        self._prefill1 = jax.jit(lambda p, b: M.prefill(cfg, p, b, max_seq))
        self._template: Pytree | None = None

    def init_state(self, batch: int) -> Pytree:
        return M.init_decode_state(self.cfg, batch, self.max_seq)

    def slot_template(self) -> Pytree:
        """Batch-1 decode state: the shape key for slot reads/writes."""
        if self._template is None:
            self._template = M.init_decode_state(self.cfg, 1, self.max_seq)
        return self._template

    def slot_nbytes(self) -> float:
        """True size in bytes of one session's KV-cache slice."""
        return float(sum(leaf.nbytes
                         for leaf in jax.tree.leaves(self.slot_template())))

    def prefill(self, params: Pytree, prompt: list[int],
                extras: dict | None) -> tuple[int, Pytree, float]:
        """Prefill one prompt; returns (first token, batch-1 state, measured
        wall seconds) — the seconds feed the router's migrate pricing."""
        batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
        batch["labels"] = batch["tokens"]
        if self.cfg.family == "encdec":
            e = (extras or {}).get("frames")
            batch["frames"] = (jnp.asarray(e, jnp.bfloat16) if e is not None
                               else jnp.zeros((1, self.cfg.n_frames,
                                               self.cfg.d_model), jnp.bfloat16))
        if self.cfg.family == "vlm":
            e = (extras or {}).get("patches")
            batch["patches"] = (jnp.asarray(e, jnp.bfloat16) if e is not None
                                else jnp.zeros((1, self.cfg.n_patches,
                                                self.cfg.d_model),
                                               jnp.bfloat16))
        t0 = time.perf_counter()
        logits, fresh = self._prefill1(params, batch)
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        return int(jnp.argmax(logits[0, -1])), fresh, dt

    def decode(self, params: Pytree, state: Pytree,
               tokens: np.ndarray) -> tuple[np.ndarray, Pytree]:
        """One pooled decode step; returns (argmax token per slot, state)."""
        logits, state = self._decode(params, state, jnp.asarray(tokens))
        return np.asarray(jnp.argmax(logits[:, -1], axis=-1)), state

    def write_slot(self, pooled: Pytree, single: Pytree, slot: int) -> Pytree:
        return _write_slot(pooled, single, slot)

    def read_slot(self, pooled: Pytree, template: Pytree, slot: int) -> Pytree:
        return _read_slot(pooled, template, slot)


@dataclasses.dataclass(frozen=True)
class FailoverReport:
    """What :meth:`Router.fail_engine` did when an engine node died.

    ``resumed`` sessions were re-homed onto a surviving engine from the
    surviving LocStore/PFS replica of their parked KV slice (into a slot, or
    still parked when the engine is saturated) — each one is an entire
    prefill NOT paid. ``lost`` sessions need a fresh prefill: they
    were live in a slot (the authoritative KV died with the engine) or their
    parked slice had no surviving replica (it was still inside the durability
    window). ``deferred`` sessions kept a durable, compatible-in-principle
    slice that no *currently registered* engine can load (including the
    all-engines-down window) — the slice stays parked-unhomed and the next
    compatible :meth:`Router.join_engine` adopts it. ``drop`` is the storage
    layer's atomic account of the failure."""

    node: int
    resumed: tuple[int, ...]
    lost: tuple[int, ...]
    drop: DropReport
    deferred: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class EngineJoinReport:
    """What :meth:`Router.join_engine` did when an engine node (re)joined.

    ``adopted`` sessions were parked-unhomed by an earlier failover (their
    durable slice had no compatible home) and re-homed onto the newcomer —
    each one a prefill NOT paid. ``rebalanced`` sessions were moved off
    saturated survivors to level parked load. ``join`` is the storage
    layer's membership report."""

    node: int
    adopted: tuple[int, ...]
    rebalanced: tuple[int, ...]
    join: JoinReport


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """What :meth:`Router.follow_up` / :meth:`Router.route` decided for one
    turn — the typed sibling of :class:`FailoverReport`.

    ``kind`` is one of:

    * ``"new"``        — no session id given: fresh admission;
    * ``"hit_live"``   — locality hit, session still in its slot (free);
    * ``"hit_parked"`` — locality hit, parked session resumed in place
                         (storage promotion, no prefill);
    * ``"migrate"``    — the holder was priced out (or the cache is gone):
                         re-prefilled on another engine, ``sid`` changed.

    ``resumed`` is True when a parked session was re-hydrated into a slot;
    ``prefilled`` when the turn paid a fresh prefill.
    """

    engine: "ServingEngine"
    sid: int
    kind: str
    resumed: bool = False
    prefilled: bool = False


class ServingEngine:
    """One engine == one node's worth of serving capacity."""

    _SID = itertools.count()      # session ids are GLOBALLY unique: the
    # location service keys caches by sid, so ids must not collide across
    # engines (the router depends on it).
    _CLOCK = itertools.count(1)   # activity ticks are ALSO global: the
    # router compares Session.last_active across engines to pick a
    # cluster-wide LRU park victim, so per-engine clocks would make a busy
    # engine's idle sessions look fresher than a quiet engine's active one.

    def __init__(self, cfg: ModelConfig | None, params: Pytree, *,
                 config: ServingConfig | None = None, node: int = 0,
                 store: LocStore | None = None, backend=None,
                 max_batch: int | None = None, max_seq: int | None = None,
                 eos_id: int | None = None, idle_tier: str | None = None,
                 ) -> None:
        # documented path: one frozen ServingConfig (shared with the Router).
        # Legacy path: the original flat keywords, mapped through
        # ServingConfig.from_kwargs. Mixing them is rejected.
        legacy = {k: v for k, v in dict(max_batch=max_batch, max_seq=max_seq,
                                        eos_id=eos_id,
                                        idle_tier=idle_tier).items()
                  if v is not None}
        if config is None:
            config = ServingConfig.from_kwargs(**legacy)
        elif legacy:
            raise TypeError("ServingEngine: pass config= OR the legacy "
                            f"keywords, not both: {sorted(legacy)}")
        self.config = config
        self.cfg = cfg
        self.params = params
        self.max_batch = config.max_batch
        self.max_seq = config.max_seq
        self.node = node
        self.store = store
        self.eos_id = config.eos_id
        self.idle_tier = config.idle_tier
        if backend is None:
            if cfg is None:
                raise TypeError("ServingEngine: cfg=None requires an "
                                "explicit backend=")
            backend = JaxComputeBackend(cfg, self.max_seq)
        self.backend = backend
        self.state = backend.init_state(self.max_batch)
        self.sessions: dict[int, Session] = {}
        # sessions currently holding a slot, by sid — the router's cluster-wide
        # LRU park scan must not walk every session the engine has ever served
        self._slotted: dict[int, Session] = {}
        self._free_slots = list(range(self.max_batch))
        self.steps = 0
        self.prefills = 0
        self.parks = 0
        self.resumes = 0
        self.rehydrates = 0
        self.prefill_seconds: float | None = None   # EMA of measured prefills
        self._clock = 0
        self._slot_nbytes: float | None = None
        # runtime invariant sanitizer (repro.analysis.sanitize): slot and
        # placeholder cross-checks after every state transition — opt-in via
        # config.sanitize, falling back to the REPRO_SANITIZE env var
        if config.sanitize is None:
            from repro.analysis.sanitize import env_enabled
            self._sanitize = env_enabled()
        else:
            self._sanitize = bool(config.sanitize)

    def _sanitize_check(self) -> None:
        if self._sanitize:
            from repro.analysis import sanitize as _san
            _san.check_engine(self)

    # ---------------------------------------------------------- KV geometry
    def _slot_template(self) -> Pytree:
        """Batch-1 decode state: the shape key for slot reads/writes and the
        true per-session KV byte size."""
        return self.backend.slot_template()

    def slot_bytes(self) -> float:
        """Size in bytes of one session's KV-cache slice (the backend's
        answer — the real leaf bytes for the JAX backend, the *modeled* KV
        size for a synthetic one; the store accounts whichever it is)."""
        if self._slot_nbytes is None:
            self._slot_nbytes = float(self.backend.slot_nbytes())
        return self._slot_nbytes

    def slot_signature(self) -> tuple:
        """Shape/dtype fingerprint of one slot's KV state — two engines can
        exchange parked sessions iff their signatures match (same model
        geometry and ``max_seq``)."""
        return _state_signature(self._slot_template())

    def compatible_state(self, state: Pytree) -> bool:
        """True when ``state`` (a parked batch-1 KV slice) fits this engine's
        slots exactly — the failover slot-shape compatibility check."""
        try:
            sig = _state_signature(state)
        except Exception:  # noqa: BLE001 - foreign object: not adoptable
            return False
        return sig == self.slot_signature()

    def _cache_xattr(self, sid: int) -> dict[str, Any]:
        return {"engine": self.node, "size": self.slot_bytes(), "sid": sid}

    def _touch(self, sess: Session) -> None:
        # _clock remembers the newest tick THIS engine issued — park_idle
        # measures staleness against the engine's own latest activity
        self._clock = sess.last_active = next(ServingEngine._CLOCK)

    # ------------------------------------------------------------ admission
    def can_admit(self) -> bool:
        return bool(self._free_slots)

    def parked_sids(self) -> list[int]:
        return [s.sid for s in self.sessions.values()
                if not s.done and s.slot is None]

    def submit(self, prompt: list[int], extras: dict | None = None) -> int:
        """Prefill a prompt into a free slot; returns session id."""
        if not self._free_slots:
            raise RuntimeError("engine full")
        slot = self._free_slots.pop()
        sid = next(ServingEngine._SID)
        first, fresh, dt = self.backend.prefill(self.params, prompt, extras)
        # measured prefill cost — the router prices migrations with this
        self.prefill_seconds = (dt if self.prefill_seconds is None
                                else 0.5 * self.prefill_seconds + 0.5 * dt)
        self.prefills += 1
        # copy the single-session state into this slot of the pooled state
        self.state = self.backend.write_slot(self.state, fresh, slot)
        sess = Session(sid=sid, slot=slot, prompt_len=len(prompt),
                       tokens=[first])
        self.sessions[sid] = sess
        self._slotted[sid] = sess
        self._touch(sess)
        if self.store is not None:
            # live session: a correctly-SIZED placeholder pinned in the top
            # tier — eviction and tier_report() must account the real bytes
            self.store.put(_cache_name(sid),
                           KVSlice(None, self.slot_bytes()), loc=self.node,
                           xattr=self._cache_xattr(sid))
        self._sanitize_check()
        return sid

    # ------------------------------------------------------ park / resume
    def park(self, sid: int) -> None:
        """Evict an idle session from its engine slot into the storage
        hierarchy: the KV slice moves to ``idle_tier`` (burst buffer), the
        slot frees up for another session. The session is NOT finished — a
        later :meth:`resume` re-hydrates it without a prefill."""
        if self.store is None:
            raise RuntimeError("parking sessions requires a LocStore")
        s = self.sessions[sid]
        if s.done:
            raise RuntimeError(f"session {sid} already finished")
        if s.slot is None:
            return                                   # already parked
        state = self.backend.read_slot(self.state, self._slot_template(),
                                       s.slot)
        self.store.put(_cache_name(sid), KVSlice(state, self.slot_bytes()),
                       loc=self.node, tier=self.idle_tier,
                       xattr=self._cache_xattr(sid))
        self._free_slots.append(s.slot)
        s.slot = None
        self._slotted.pop(sid, None)
        self.parks += 1
        self._sanitize_check()

    def park_lru(self) -> int | None:
        """Park the least-recently-active slotted session (to make room).
        Returns its sid, or None when no session can be parked."""
        if not self._slotted or self.store is None:
            return None
        victim = min(self._slotted.values(), key=lambda s: s.last_active)
        self.park(victim.sid)
        return victim.sid

    def park_idle(self, max_idle: int) -> list[int]:
        """Park every session idle for more than ``max_idle`` activity ticks
        (the serving loop's idle-demotion sweep). Returns parked sids."""
        out = []
        for s in list(self._slotted.values()):
            if not s.done and self._clock - s.last_active > max_idle:
                self.park(s.sid)
                out.append(s.sid)
        return out

    def adopt(self, sid: int, *, prompt_len: int, tokens: list[int]) -> bool:
        """Take over a session parked by a FAILED engine: register it here
        and re-hydrate it from the surviving store replica — the cross-engine
        failover that replaces a full re-prefill. With a free slot the
        session resumes immediately; on a saturated engine it stays PARKED
        (a parked session needs no slot — the next follow-up resumes it).
        Returns False (nothing registered) when the stored slice is missing,
        still a live-session placeholder, or shaped for an incompatible
        engine."""
        if self.store is None or not self.store.exists(_cache_name(sid)):
            return False
        if sid in self.sessions:
            raise RuntimeError(f"session {sid} already lives on engine "
                               f"{self.node}")
        value, _ = self.store.get(_cache_name(sid))   # metadata read
        if not isinstance(value, KVSlice) or value.state is None \
                or not self.compatible_state(value.state):
            return False
        self.sessions[sid] = Session(sid=sid, slot=None,
                                     prompt_len=prompt_len,
                                     tokens=list(tokens))
        if self._free_slots:
            self.resume(sid)
        else:
            # no capacity right now: the session stays parked here — re-home
            # the cache metadata so the router routes its next turn to us
            p = self.store.stat(_cache_name(sid))
            p.xattr.update(self._cache_xattr(sid))
            self.store.loc.record(_cache_name(sid), p)
        return True

    def resume(self, sid: int) -> bool:
        """Bring a parked session back into a slot WITHOUT re-prefilling:
        the store promotes the KV slice back to the top tier and the engine
        writes it into a free slot. Returns True if a re-hydration happened
        (False: the session was already live)."""
        s = self.sessions[sid]
        if s.done:
            raise RuntimeError(f"session {sid} already finished")
        if s.slot is not None:
            self._touch(s)
            return False
        if not self._free_slots:
            raise RuntimeError("engine full")
        value, _ = self.store.get(_cache_name(sid), at=self.node)
        if not isinstance(value, KVSlice) or value.state is None:
            raise RuntimeError(f"session {sid} has no parked KV state")
        slot = self._free_slots.pop()
        self.state = self.backend.write_slot(self.state, value.state, slot)
        s.slot = slot
        self._slotted[sid] = s
        self._touch(s)
        self.resumes += 1
        self.rehydrates += 1
        # live again: swap the stored slice back to a sized placeholder in
        # the top tier (the authoritative KV is in the engine slot now)
        self.store.put(_cache_name(sid), KVSlice(None, self.slot_bytes()),
                       loc=self.node, xattr=self._cache_xattr(sid))
        self._sanitize_check()
        return True

    # ---------------------------------------------------------------- decode
    def step(self) -> dict[int, int]:
        """One decode step for every live session; returns {sid: new_token}."""
        live = [s for s in self._slotted.values() if not s.done]
        if not live:
            return {}
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for s in live:
            tokens[s.slot, 0] = s.tokens[-1]
        arg, self.state = self.backend.decode(self.params, self.state, tokens)
        self.steps += 1
        out: dict[int, int] = {}
        for s in live:
            tok = int(arg[s.slot])
            s.tokens.append(tok)
            out[s.sid] = tok
            self._touch(s)
            if tok == self.eos_id or \
                    s.prompt_len + len(s.tokens) >= self.max_seq - 1:
                self.finish(s.sid)
        self._sanitize_check()
        return out

    def finish(self, sid: int) -> list[int]:
        s = self.sessions[sid]
        if not s.done:
            s.done = True
            if s.slot is not None:
                self._free_slots.append(s.slot)
                s.slot = None
            self._slotted.pop(sid, None)
            if self.store is not None:
                self.store.delete(_cache_name(sid))
            self._sanitize_check()
        return s.tokens

    def generate(self, prompt: list[int], max_new: int = 16) -> list[int]:
        sid = self.submit(prompt)
        while not self.sessions[sid].done and \
                len(self.sessions[sid].tokens) < max_new:
            self.step()
        self.finish(sid)
        return self.sessions[sid].tokens[:max_new]


def _write_slot(pooled: Pytree, single: Pytree, slot: int) -> Pytree:
    """Insert a batch-1 decode state into slot ``slot`` of the pooled state.

    Every state leaf layout puts batch right after the stacked layer dims; we
    detect the batch dim as the first axis whose size == 1 in ``single`` but
    differs in ``pooled``."""

    def ins(p, s):
        if p.shape == s.shape:   # max_batch == 1: the single state IS the slot
            return s.astype(p.dtype)
        axis = next(i for i, (a, b) in enumerate(zip(p.shape, s.shape))
                    if a != b and b == 1)
        idx = [slice(None)] * p.ndim
        idx[axis] = slice(slot, slot + 1)
        return p.at[tuple(idx)].set(s.astype(p.dtype))

    return jax.tree.map(ins, pooled, single)


def _read_slot(pooled: Pytree, template: Pytree, slot: int) -> Pytree:
    """Extract slot ``slot`` of the pooled state as a batch-1 state — the
    exact inverse of :func:`_write_slot` (``template`` is any batch-1 state,
    used only for its shapes)."""

    def ext(p, s):
        if p.shape == s.shape:   # max_batch == 1: the pooled state IS the slot
            return p
        axis = next(i for i, (a, b) in enumerate(zip(p.shape, s.shape))
                    if a != b and b == 1)
        idx = [slice(None)] * p.ndim
        idx[axis] = slice(slot, slot + 1)
        return p[tuple(idx)]

    return jax.tree.map(ext, pooled, template)


class Router:
    """Location-, tier- and pressure-aware request router (paper layer 3).

    ``engine_for(session_id)`` queries the location service for the node
    holding the session's KV cache. A locality hit is only taken when the
    holder can actually serve it: a session still in a slot is free to
    continue; a *parked* session needs a slot and a promotion, so the router
    prices the resume (tier media time via ``hierarchy.bw`` — the cluster
    view's ``tier_gbps`` — plus the demotions the promotion will cause at the
    engine's measured tier pressure, ``store.tier_report(node=...)``) against
    a migrate-and-re-prefill on the best other engine (its *measured*
    ``prefill_seconds``), and falls through when migrating is cheaper
    (``locality_evictions``). New sessions go to the least-loaded engine with
    a free slot; when every slot in the cluster is taken, the router parks
    the least-recently-active session somewhere (``allow_park``) instead of
    raising "engine full". Hit accounting backs bench_serving.
    """

    def __init__(self, engines: list[ServingEngine], store: LocStore, *,
                 prefetch: PrefetchEngine | None = None,
                 config: ServingConfig | None = None,
                 allow_park: bool | None = None) -> None:
        if config is None:
            config = ServingConfig(
                allow_park=True if allow_park is None else allow_park)
        elif allow_park is not None:
            raise TypeError("Router: pass config= OR allow_park=, not both")
        self.config = config
        self.engines = {e.node: e for e in engines}
        self.store = store
        self.prefetch = prefetch
        self.allow_park = config.allow_park
        self.locality_hits = 0
        self.locality_misses = 0
        self.locality_evictions = 0   # hit engine full/saturated: migrated
        self.migrations = 0
        self.warmups = 0
        self.failover_resumes = 0     # sessions re-hydrated across engines
        self.failover_lost = 0        # sessions needing a fresh prefill
        self.failover_deferred = 0    # durable slices parked-unhomed, waiting
        # for a compatible join_engine
        self.engine_joins = 0
        self.rebalanced_sessions = 0
        # sid -> (prompt_len, tokens) of sessions whose durable slice
        # survived a failover but had no compatible home at the time
        self._unhomed: dict[int, tuple[int, list[int]]] = {}
        # cross-engine invariant checks after route/failover/join transitions
        if config.sanitize is None:
            from repro.analysis.sanitize import env_enabled
            self._sanitize = env_enabled()
        else:
            self._sanitize = bool(config.sanitize)

    def _sanitize_check(self) -> None:
        if self._sanitize:
            from repro.analysis import sanitize as _san
            _san.check_router(self)

    # ------------------------------------------------------------ cost model
    def _path_seconds(self, p: Placement, kv: float, dst: int) -> float:
        """Seconds to move ``kv`` bytes from the nearest replica of ``p`` to
        node ``dst`` over the cluster network. Zero when a replica already
        sits on ``dst`` or when the store has no real topology attached
        (flat / ``None`` keeps the legacy media-only pricing bit-identical).
        """
        topo = getattr(self.store, "topology", None)
        if topo is None or topo.flat or not p.nodes or dst in p.nodes:
            return 0.0
        bw = max(topo.link_gbps(src, dst) for src in p.nodes)
        if bw == float("inf"):
            return 0.0
        if bw <= 0.0:
            return float("inf")
        return kv / bw

    def _resume_cost(self, eng: ServingEngine, name: str) -> float:
        """Seconds to bring a parked session's KV back into the holder's top
        tier: media read of the tier it is parked in + top-tier write, plus —
        when the engine is saturated — the park of a victim session and the
        demotions the promotion causes under top-tier pressure. When the
        store carries a real :class:`~repro.core.topology.ClusterTopology`
        and no replica lives on the engine's node, the network hop from the
        nearest replica is charged too (a cross-spine resume is not free)."""
        hier = self.store.hierarchy
        p = self.store.stat(name)
        kv = float(p.xattr.get("size", 0.0))
        tier = p.tier_on(eng.node)
        cost = hier.media_seconds(kv, tier) + hier.media_seconds(kv, hier.top)
        cost += self._path_seconds(p, kv, eng.node)
        idle_tier = hier.normalize(eng.idle_tier)
        if not eng.can_admit():
            # a victim session must be parked first (top read + idle write)
            cost += (hier.media_seconds(kv, hier.top)
                     + hier.media_seconds(kv, idle_tier))
        top_used = self.store.tier_used(eng.node, hier.top)
        if top_used + kv > hier.capacity(hier.top):
            # promotion at pressure: the store will demote someone else
            cost += hier.media_seconds(kv, idle_tier)
        return cost

    def _migrate_cost(self, exclude: ServingEngine) -> float:
        """Seconds to re-prefill on the best other engine with a free slot,
        using each engine's measured prefill time (inf until one exists —
        never migrate onto an engine we know nothing about)."""
        costs = [e.prefill_seconds
                 for e in self.engines.values()
                 if e is not exclude and e.can_admit()
                 and e.prefill_seconds is not None]
        return min(costs) if costs else float("inf")

    # -------------------------------------------------------------- routing
    def engine_for(self, sid: int | None = None) -> ServingEngine:
        passed_over: ServingEngine | None = None
        if sid is not None and self.store.exists(_cache_name(sid)):
            node = self.store.getxattr(_cache_name(sid), "engine")
            eng = self.engines.get(node)
            sess = eng.sessions.get(sid) if eng is not None else None
            if sess is not None and not sess.done:
                if sess.slot is not None:
                    self.locality_hits += 1      # live in a slot: free
                    return eng
                # parked: needs a slot. Full + no parkable victim, or a
                # migrate priced cheaper than the promotion -> fall through.
                can_serve = (eng.can_admit()
                             or (self.allow_park and bool(eng._slotted)))
                if can_serve and (self.config.resume_bias
                                  * self._resume_cost(eng, _cache_name(sid))
                                  <= self._migrate_cost(eng)):
                    self.locality_hits += 1
                    return eng
                self.locality_evictions += 1
                passed_over = eng                # the decision was to migrate
        self.locality_misses += sid is not None
        free = [e for e in self.engines.values()
                if e.can_admit() and e is not passed_over]
        if not free:
            if self.allow_park:
                # park the least-recently-active session cluster-wide
                candidates = [e for e in self.engines.values() if e._slotted]
                if candidates:
                    eng = min(candidates, key=lambda e: min(
                        s.last_active for s in e._slotted.values()))
                    eng.park_lru()
                    return eng
            raise RuntimeError("all engines full")
        return max(free, key=lambda e: len(e._free_slots))

    def ensure_active(self, eng: ServingEngine, sid: int) -> bool:
        """Make a routed-to session live in a slot (parking a victim if the
        engine is full). Returns True if a parked session was re-hydrated."""
        sess = eng.sessions[sid]
        if sess.slot is not None:
            return False
        if not eng.can_admit():
            if not self.allow_park or eng.park_lru() is None:
                raise RuntimeError("engine full")
        return eng.resume(sid)

    def route(self, sid: int | None = None) -> RouteDecision:
        """The typed routing decision for one turn: which engine, which kind
        of hit, without side effects beyond what ``engine_for`` does (park a
        cluster-wide LRU victim to make room). ``follow_up`` executes it."""
        eng = self.engine_for(sid)
        if sid is None:
            return RouteDecision(engine=eng, sid=-1, kind="new")
        sess = eng.sessions.get(sid)
        if sess is not None and not sess.done:
            kind = "hit_live" if sess.slot is not None else "hit_parked"
            return RouteDecision(engine=eng, sid=sid, kind=kind)
        return RouteDecision(engine=eng, sid=sid, kind="migrate")

    def follow_up(self, sid: int, history: list[int]) -> RouteDecision:
        """Route one follow-up turn end-to-end. On a locality hit the session
        is resumed in place (no prefill); otherwise it migrates: the old
        engine drops it and the target re-prefills ``history``. Returns a
        :class:`RouteDecision` — ``decision.sid`` changes on a migration."""
        d = self.route(sid)
        eng = d.engine
        if d.kind in ("hit_live", "hit_parked"):
            resumed = self.ensure_active(eng, sid)
            self._sanitize_check()
            return dataclasses.replace(d, resumed=resumed)
        # migration: the cache holder (if any) discards its copy
        for e in self.engines.values():
            s = e.sessions.get(sid)
            if s is not None and not s.done:
                e.finish(sid)
        if sid in self._unhomed:
            # a deferred failover session re-prefilled before any compatible
            # engine joined: its parked-unhomed slice is superseded
            del self._unhomed[sid]
            if self.store.exists(_cache_name(sid)):
                self.store.delete(_cache_name(sid))
        self.migrations += 1
        if not eng.can_admit():     # engine_for made room already unless flat
            raise RuntimeError("engine full")
        new_sid = eng.submit(history)
        self._sanitize_check()
        return dataclasses.replace(d, sid=new_sid, prefilled=True)

    # -------------------------------------------------------------- failover
    def fail_engine(self, node: int) -> FailoverReport:
        """Handle the death of one engine node, cross-layer.

        The storage layer takes the atomic hit first (``store.drop_node``:
        forget the node's replicas, cancel its in-flight flushes, release its
        pins), then every non-finished session of the dead engine is triaged:

        * **parked, replica survived** (another node or a real PFS copy — the
          durability policy's doing): re-homed onto a surviving engine whose
          slot shape matches, *without* a prefill — into a slot when one is
          free, otherwise still parked (the next follow-up resumes it);
        * **live in a slot** (the authoritative KV was engine memory) or
          **parked inside the durability window** (sole replica died):
          reported ``lost`` — the caller re-prefills from conversation
          history if it wants the session back.
        """
        eng = self.engines.pop(node, None)
        if eng is None:
            raise KeyError(f"no engine on node {node}")
        drop = self.store.drop_node(node)
        resumed: list[int] = []
        lost: list[int] = []
        deferred: list[int] = []
        for sid, sess in list(eng.sessions.items()):
            if sess.done:
                continue
            sess.done = True              # the home engine is gone either way
            name = _cache_name(sid)
            value: KVSlice | None = None
            if sess.slot is None and self.store.exists(name):
                v, _ = self.store.get(name)             # metadata read
                if isinstance(v, KVSlice) and v.state is not None:
                    value = v
            target: ServingEngine | None = None
            if value is not None:
                # surviving engine with a matching slot shape, cheapest KV
                # move from the surviving replica first (under a real
                # topology; the term is a constant 0.0 otherwise so the
                # order reduces to most-free-slots), then most free slots —
                # a full engine is still a valid home: the session can
                # stay parked there, so capacity never forfeits a
                # surviving durable replica
                p = self.store.stat(name)
                kv = float(p.xattr.get("size", 0.0))
                target = next(
                    (cand for cand in sorted(self.engines.values(),
                                             key=lambda e:
                                             (self._path_seconds(p, kv,
                                                                 e.node),
                                              -len(e._free_slots)))
                     if cand.compatible_state(value.state)), None)
            if target is not None and target.adopt(
                    sid, prompt_len=sess.prompt_len, tokens=sess.tokens):
                resumed.append(sid)
                self.failover_resumes += 1
            elif value is not None:
                # the slice is durable and loadable in principle — no
                # *currently registered* engine matches (possibly none is
                # left at all). Deleting it would forfeit a prefill's worth
                # of work the durability policy just paid to keep: park it
                # unhomed and let the next compatible join_engine adopt it.
                deferred.append(sid)
                self.failover_deferred += 1
                self._unhomed[sid] = (sess.prompt_len, list(sess.tokens))
            else:
                lost.append(sid)
                self.failover_lost += 1
                if self.store.exists(name):
                    # only unusable slices land here: a live-session
                    # placeholder (state=None) whose authoritative KV died
                    # in the engine's slot memory
                    self.store.delete(name)
        self._sanitize_check()
        return FailoverReport(node=node, resumed=tuple(resumed),
                              lost=tuple(lost), drop=drop,
                              deferred=tuple(deferred))

    # ------------------------------------------------------------ membership
    def join_engine(self, node: int, engine: ServingEngine, *,
                    rebalance: bool = True) -> EngineJoinReport:
        """Admit a new engine node, cross-layer (the arrival half of
        :meth:`fail_engine`).

        The storage layer joins first (``store.join_node``: clear the failed
        mark, reopen default placement, publish the ``join_node`` event),
        then the engine registers for routing, adopts every parked-unhomed
        session whose deferred slice its slots can load (the other half of
        the ``failover_deferred`` contract), and — unless ``rebalance=False``
        — pulls parked sessions off saturated survivors to level load
        (:meth:`rebalance_parked`). Cold-start pricing (params load) is the
        trace driver's job: the router only decides placement."""
        if node in self.engines:
            raise ValueError(f"node {node} already has an engine")
        if engine.node != node:
            raise ValueError(f"engine is bound to node {engine.node}, "
                             f"asked to join as {node}")
        if engine.store is not self.store:
            raise ValueError("joining engine must share the router's store")
        join = self.store.join_node(node)
        self.engines[node] = engine
        adopted: list[int] = []
        for sid, (prompt_len, tokens) in sorted(self._unhomed.items()):
            name = _cache_name(sid)
            if not self.store.exists(name):
                del self._unhomed[sid]       # slice vanished: nothing to adopt
                continue
            value, _ = self.store.get(name)             # metadata read
            if not isinstance(value, KVSlice) or value.state is None \
                    or not engine.compatible_state(value.state):
                continue                     # wait for a matching engine
            if engine.adopt(sid, prompt_len=prompt_len, tokens=tokens):
                del self._unhomed[sid]
                adopted.append(sid)
                self.failover_resumes += 1
        rebalanced = (tuple(self.rebalance_parked(engine))
                      if rebalance else ())
        self.engine_joins += 1
        self._sanitize_check()
        return EngineJoinReport(node=node, adopted=tuple(adopted),
                                rebalanced=rebalanced, join=join)

    def rebalance_parked(self, target: ServingEngine, *,
                         max_sessions: int | None = None) -> list[int]:
        """Move parked sessions from the most-loaded engines onto ``target``
        until parked load is level (each engine at the cluster-wide mean) —
        zero re-prefill: the KV slice moves through the store, decode
        continues bit-identically. Least-recently-active sessions move
        first (they are the least likely to be resumed where they are).
        When the target cannot slot an adoptee immediately, its slice is
        additionally replicated onto the target node's idle tier so the
        eventual resume is node-local. Returns moved sids."""
        others = [e for e in self.engines.values() if e is not target]
        if not others:
            return []
        donors = {e: sorted(e.parked_sids(),
                            key=lambda s, e=e: e.sessions[s].last_active,
                            reverse=True)
                  for e in others}
        total = (sum(len(v) for v in donors.values())
                 + len(target.parked_sids()))
        fair = total // len(self.engines)
        want = fair - len(target.parked_sids())
        if max_sessions is not None:
            want = min(want, max_sessions)
        moved: list[int] = []
        while want > 0:
            donor = max(others, key=lambda e: (len(donors[e]), -e.node))
            if len(donors[donor]) <= fair:
                break                        # everyone is at (or under) fair
            sid = donors[donor].pop()        # least-recently-active first
            sess = donor.sessions.get(sid)
            name = _cache_name(sid)
            if sess is None or sess.done or sess.slot is not None \
                    or not self.store.exists(name) or sid in target.sessions:
                continue
            value, _ = self.store.get(name)             # metadata read
            if not isinstance(value, KVSlice) or value.state is None \
                    or not target.compatible_state(value.state):
                continue
            del donor.sessions[sid]
            if not target.adopt(sid, prompt_len=sess.prompt_len,
                                tokens=sess.tokens):
                donor.sessions[sid] = sess   # restore the registration
                continue
            if target.sessions[sid].slot is None:
                # adopted parked (target saturated): stage a local replica
                # so the eventual resume/warm reads node-local bytes
                self.store.replicate(name, [target.node],
                                     tier=target.idle_tier)
            moved.append(sid)
            self.rebalanced_sessions += 1
            want -= 1
        return moved

    def warm(self, sid: int) -> bool:
        """Promote a parked session's KV back toward the top tier ahead of
        its next turn (the serving analogue of the proactive prefetch) — the
        predictive-warming driver (``repro.serve.traffic``) calls this ahead
        of each predicted follow-up. With a :class:`PrefetchEngine` attached
        the promotion runs on its background thread; without one it happens
        synchronously in the store (wall-clock-free — the trace driver models
        the media time itself). No-op for unknown, finished, or live-in-slot
        sessions, and for slices whose only replica is off-node (remote/other
        node): those resume through the normal ``get(at=...)`` path."""
        name = _cache_name(sid)
        if not self.store.exists(name):
            return False
        node = self.store.getxattr(name, "engine")
        eng = self.engines.get(node)
        sess = eng.sessions.get(sid) if eng is not None else None
        if sess is None or sess.done or sess.slot is not None:
            return False
        p = self.store.stat(name)
        if not p.resident_on(node):
            # off-node-only slice: a warm cannot help — both paths must
            # agree (the prefetch path used to count these as warmups,
            # making the stat depend on whether a PrefetchEngine happened
            # to be attached)
            return False
        if self.prefetch is not None:
            self.prefetch.submit(name, node, tier=self.store.hierarchy.top)
            self.warmups += 1
            return True
        if p.tier_on(node) != self.store.hierarchy.top:
            self.store.promote(name, node, tier=self.store.hierarchy.top)
        self.warmups += 1
        return True
