"""Batched serving engine with location-aware session routing.

Continuous batching over a fixed pool of decode slots: each session owns one
batch slot of the shared KV-cache state; prefill admits sessions, decode steps
all active slots at once (one jitted ``decode_step`` regardless of how many
sessions are live — idle slots are masked).

The cross-layer part (paper → inference): a session's KV cache IS the paper's
"file". The :class:`Router` records each session's placement in the
distributed :class:`~repro.core.locstore.LocationService`; follow-up requests
look the session up and land on the engine/node that holds its cache
(compute-on-data-path), instead of re-prefilling elsewhere — the measured
saving is an entire prefill per follow-up turn (see bench_serving).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.locstore import LocStore
from repro.models import model as M

Pytree = Any


@dataclasses.dataclass
class Session:
    sid: int
    slot: int
    prompt_len: int
    tokens: list[int]
    done: bool = False


class ServingEngine:
    """One engine == one node's worth of serving capacity."""

    _SID = itertools.count()      # session ids are GLOBALLY unique: the
    # location service keys caches by sid, so ids must not collide across
    # engines (the router depends on it).

    def __init__(self, cfg: ModelConfig, params: Pytree, *, max_batch: int = 4,
                 max_seq: int = 128, node: int = 0,
                 store: LocStore | None = None, eos_id: int = -1) -> None:
        cfg.validate()
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.node = node
        self.store = store
        self.eos_id = eos_id
        self.state = M.init_decode_state(cfg, max_batch, max_seq)
        self.sessions: dict[int, Session] = {}
        self._free_slots = list(range(max_batch))
        self._decode = jax.jit(
            lambda p, s, t: M.decode_step(cfg, p, s, t))
        self._prefill1 = jax.jit(
            lambda p, b: M.prefill(cfg, p, b, max_seq))
        self.steps = 0
        self.prefills = 0

    # ------------------------------------------------------------ admission
    def can_admit(self) -> bool:
        return bool(self._free_slots)

    def submit(self, prompt: list[int], extras: dict | None = None) -> int:
        """Prefill a prompt into a free slot; returns session id."""
        if not self._free_slots:
            raise RuntimeError("engine full")
        slot = self._free_slots.pop()
        sid = next(ServingEngine._SID)
        batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
        batch["labels"] = batch["tokens"]
        if self.cfg.family == "encdec":
            e = (extras or {}).get("frames")
            batch["frames"] = (jnp.asarray(e, jnp.bfloat16) if e is not None
                               else jnp.zeros((1, self.cfg.n_frames,
                                               self.cfg.d_model), jnp.bfloat16))
        if self.cfg.family == "vlm":
            e = (extras or {}).get("patches")
            batch["patches"] = (jnp.asarray(e, jnp.bfloat16) if e is not None
                                else jnp.zeros((1, self.cfg.n_patches,
                                                self.cfg.d_model),
                                               jnp.bfloat16))
        logits, fresh = self._prefill1(self.params, batch)
        self.prefills += 1
        # copy the single-session state into this slot of the pooled state
        self.state = _write_slot(self.state, fresh, slot)
        first = int(jnp.argmax(logits[0, -1]))
        sess = Session(sid=sid, slot=slot, prompt_len=len(prompt),
                       tokens=[first])
        self.sessions[sid] = sess
        if self.store is not None:
            name = f"kvcache:session:{sid}"
            size = float(len(prompt) * self.cfg.d_model * 2)
            self.store.put(name, memoryview(b""), loc=self.node,
                           xattr={"engine": self.node, "size": size})
        return sid

    # ---------------------------------------------------------------- decode
    def step(self) -> dict[int, int]:
        """One decode step for every live session; returns {sid: new_token}."""
        live = [s for s in self.sessions.values() if not s.done]
        if not live:
            return {}
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for s in live:
            tokens[s.slot, 0] = s.tokens[-1]
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(tokens))
        self.steps += 1
        out: dict[int, int] = {}
        arg = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in live:
            tok = int(arg[s.slot])
            s.tokens.append(tok)
            out[s.sid] = tok
            if tok == self.eos_id or \
                    s.prompt_len + len(s.tokens) >= self.max_seq - 1:
                self.finish(s.sid)
        return out

    def finish(self, sid: int) -> list[int]:
        s = self.sessions[sid]
        if not s.done:
            s.done = True
            self._free_slots.append(s.slot)
            if self.store is not None:
                self.store.delete(f"kvcache:session:{sid}")
        return s.tokens

    def generate(self, prompt: list[int], max_new: int = 16) -> list[int]:
        sid = self.submit(prompt)
        while not self.sessions[sid].done and \
                len(self.sessions[sid].tokens) < max_new:
            self.step()
        self.finish(sid)
        return self.sessions[sid].tokens[:max_new]


def _write_slot(pooled: Pytree, single: Pytree, slot: int) -> Pytree:
    """Insert a batch-1 decode state into slot ``slot`` of the pooled state.

    Every state leaf layout puts batch right after the stacked layer dims; we
    detect the batch dim as the first axis whose size == 1 in ``single`` but
    differs in ``pooled``."""

    def ins(p, s):
        if p.shape == s.shape:   # max_batch == 1: the single state IS the slot
            return s.astype(p.dtype)
        axis = next(i for i, (a, b) in enumerate(zip(p.shape, s.shape))
                    if a != b and b == 1)
        idx = [slice(None)] * p.ndim
        idx[axis] = slice(slot, slot + 1)
        return p.at[tuple(idx)].set(s.astype(p.dtype))

    return jax.tree.map(ins, pooled, single)


class Router:
    """Location-aware request router over several engines (paper layer 3).

    ``route(session_id)`` queries the location service for the node holding
    the session's KV cache; new sessions go to the least-loaded engine with a
    free slot. Hit accounting backs bench_serving."""

    def __init__(self, engines: list[ServingEngine], store: LocStore) -> None:
        self.engines = {e.node: e for e in engines}
        self.store = store
        self.locality_hits = 0
        self.locality_misses = 0

    def engine_for(self, sid: int | None = None) -> ServingEngine:
        if sid is not None and self.store.exists(f"kvcache:session:{sid}"):
            node = self.store.getxattr(f"kvcache:session:{sid}", "engine")
            if node in self.engines:
                self.locality_hits += 1
                return self.engines[node]
        self.locality_misses += sid is not None
        free = [e for e in self.engines.values() if e.can_admit()]
        if not free:
            raise RuntimeError("all engines full")
        return max(free, key=lambda e: len(e._free_slots))
