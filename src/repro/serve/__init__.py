"""Serving — location-aware engines, routing, and trace-driven evaluation.

The curated public surface (PR 7): engine/router machinery from
:mod:`repro.serve.engine`, traffic generation and the discrete-event driver
from :mod:`repro.serve.traffic`, plus the shared :class:`ServingConfig`.
"""

from repro.core.config import ServingConfig
from repro.serve.engine import (EngineJoinReport, FailoverReport,
                                JaxComputeBackend, KVSlice,
                                RouteDecision, Router, ServingEngine, Session)
from repro.serve.traffic import (CostModel, InterArrivalPredictor, Request,
                                 SyntheticBackend, TraceConfig, TraceDriver,
                                 TraceReport, build_trace_stack,
                                 generate_trace, latency_percentiles,
                                 trace_stats)

__all__ = [
    "ServingConfig",
    "EngineJoinReport", "FailoverReport", "JaxComputeBackend", "KVSlice",
    "RouteDecision",
    "Router", "ServingEngine", "Session",
    "CostModel", "InterArrivalPredictor", "Request", "SyntheticBackend",
    "TraceConfig", "TraceDriver", "TraceReport", "build_trace_stack",
    "generate_trace", "latency_percentiles", "trace_stats",
]
