"""Trace-driven serving: realistic traffic at 10^5-session scale (PR 7).

Two halves, both seeded and wall-clock-free:

* :func:`generate_trace` — a request-trace generator with the workload shape
  the serving literature actually measures against (the depsched simulator's
  ``init_req_queue(req_rate, zipf=...)`` idiom): **Zipf** session popularity
  (a few hot conversations get most follow-ups), **Poisson** or **bursty**
  (2-state Markov-modulated) arrivals, and **heavy-tailed** (lognormal)
  prompt/output lengths.

* :class:`TraceDriver` — a discrete-event driver that pushes the trace
  through the full :class:`~repro.serve.engine.Router` /
  :class:`~repro.serve.engine.ServingEngine` park/resume/warm/failover
  lifecycle in *virtual* time, recording per-request TTFT, resume latency and
  queue delay with p50/p95/p99 summaries. It is also ``Router.warm()``'s
  missing caller: per-session inter-arrival EMAs
  (:class:`InterArrivalPredictor`) schedule warms ahead of predicted
  follow-ups, and the driver reports how much resume latency the warms
  actually hid (warm-hit rate, wasted warms).

Compute is replaced by :class:`SyntheticBackend` — a tiny numpy pytree whose
*modeled* KV byte size is what the store accounts — so 10^5+ sessions are
tractable while the storage layer (true byte capacities, tier residency,
eviction cascades, write-back) behaves exactly as with the JAX backend.
Service times come from :class:`CostModel` plus the hierarchy's media times,
never the wall clock, so every run is bit-reproducible.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.config import ServingConfig
from repro.core.locstore import LocStore, StorageHierarchy, TierSpec
from repro.serve.engine import Router, ServingEngine, _cache_name

MiB = float(1 << 20)
GiB = float(1 << 30)


# --------------------------------------------------------------------- trace
@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs of the request-trace generator (all defaults are modest; the
    benchmark scales ``n_sessions`` to 10^5 in full mode)."""

    n_sessions: int = 10_000
    followups_per_session: float = 1.5   # mean follow-up turns per session
    req_rate: float = 200.0              # mean arrivals per virtual second
    arrival: str = "poisson"             # "poisson" | "bursty"
    burst_factor: float = 8.0            # in-burst rate multiplier
    burst_fraction: float = 0.1          # stationary fraction of time in burst
    burst_persistence: float = 0.98      # P(stay in burst at each arrival)
    zipf_alpha: float = 1.1              # session-popularity skew
    prompt_median: float = 96.0          # lognormal median, first-turn prompt
    prompt_sigma: float = 0.9
    followup_median: float = 24.0        # lognormal median, follow-up prompt
    followup_sigma: float = 0.6
    output_median: float = 48.0          # lognormal median, output length
    output_sigma: float = 0.7
    max_prompt: int = 2048
    max_output: int = 1024
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Request:
    """One arrival in the trace. ``session`` is the trace-level conversation
    id (0 = first conversation opened, also the most popular under Zipf);
    ``turn`` 0 is the opening request. ``final`` marks the session's last
    trace appearance so the driver can release its slot/cache."""

    rid: int
    t: float
    session: int
    turn: int
    prompt_len: int
    output_len: int
    final: bool = False


def _lengths(rng: np.random.Generator, n: int, median: float, sigma: float,
             cap: int) -> np.ndarray:
    """Heavy-tailed token counts: lognormal with the given median, clipped
    to [1, cap]."""
    raw = rng.lognormal(mean=float(np.log(median)), sigma=sigma, size=n)
    return np.clip(raw, 1, cap).astype(np.int64)


def _arrival_times(cfg: TraceConfig, rng: np.random.Generator,
                   n: int) -> np.ndarray:
    """Cumulative arrival times for ``n`` requests at mean rate
    ``req_rate``. Bursty mode modulates a 2-state Markov chain whose
    stationary burst share is ``burst_fraction``; the base rate is scaled so
    the *long-run* mean rate still equals ``req_rate``."""
    if cfg.arrival == "poisson":
        gaps = rng.exponential(1.0 / cfg.req_rate, n)
    elif cfg.arrival == "bursty":
        bf, factor = cfg.burst_fraction, cfg.burst_factor
        # the chain's stationary burst share bf is per-*event*, so the
        # long-run mean gap is ((1-bf) + bf/factor) / base — scale base so
        # that equals 1/req_rate
        base = cfg.req_rate * ((1.0 - bf) + bf / factor)
        stay = min(max(cfg.burst_persistence, 0.0), 1.0)
        # enter-prob chosen so the chain's stationary burst share is bf
        p_enter = min(1.0, bf * (1.0 - stay) / max(1.0 - bf, 1e-12))
        u = rng.random(n)
        rates = np.empty(n)
        in_burst = False
        for i in range(n):
            in_burst = (u[i] < stay) if in_burst else (u[i] < p_enter)
            rates[i] = base * factor if in_burst else base
        gaps = rng.exponential(1.0, n) / rates
    else:
        raise ValueError(f"unknown arrival process {cfg.arrival!r}")
    return np.cumsum(gaps)


def generate_trace(cfg: TraceConfig) -> list[Request]:
    """Deterministic (seeded) request trace: ``n_sessions`` openings plus
    ``round(n_sessions * followups_per_session)`` follow-ups, interleaved
    uniformly over one arrival process. Follow-ups target sessions by Zipf
    rank over the sessions opened *so far* (rank 0 = the oldest session),
    so popularity is skewed and every targeted session already exists."""
    rng = np.random.default_rng(cfg.seed)
    n_follow = int(round(cfg.n_sessions * cfg.followups_per_session))
    n = cfg.n_sessions + n_follow
    times = _arrival_times(cfg, rng, n)

    # bounded-Zipf inverse CDF over session popularity ranks
    weights = 1.0 / np.arange(1, cfg.n_sessions + 1) ** cfg.zipf_alpha
    cdf = np.cumsum(weights)
    cdf /= cdf[-1]
    ranks = np.searchsorted(cdf, rng.random(n), side="right")

    new_flag = np.zeros(n, bool)
    new_flag[rng.choice(n, cfg.n_sessions, replace=False)] = True
    if not new_flag[0]:                       # slot 0 must open a session
        j = int(np.argmax(new_flag))
        new_flag[[0, j]] = new_flag[[j, 0]]

    prompts = _lengths(rng, n, cfg.prompt_median, cfg.prompt_sigma,
                       cfg.max_prompt)
    follows = _lengths(rng, n, cfg.followup_median, cfg.followup_sigma,
                       cfg.max_prompt)
    outputs = _lengths(rng, n, cfg.output_median, cfg.output_sigma,
                       cfg.max_output)

    reqs: list[Request] = []
    turns: dict[int, int] = {}
    opened = 0
    for i in range(n):
        if new_flag[i]:
            sess = opened
            opened += 1
            plen = int(prompts[i])
        else:
            sess = int(min(ranks[i], opened - 1))
            plen = int(follows[i])
        turn = turns.get(sess, -1) + 1
        turns[sess] = turn
        reqs.append(Request(rid=i, t=float(times[i]), session=sess, turn=turn,
                            prompt_len=plen, output_len=int(outputs[i])))
    last = {r.session: r.rid for r in reqs}
    return [dataclasses.replace(r, final=last[r.session] == r.rid)
            for r in reqs]


def trace_stats(trace: Sequence[Request]) -> dict[str, float]:
    """Summary statistics the tests sanity-check the generator against."""
    times = np.array([r.t for r in trace])
    gaps = np.diff(times)
    counts: dict[int, int] = {}
    for r in trace:
        counts[r.session] = counts.get(r.session, 0) + 1
    ordered = sorted(counts.values(), reverse=True)
    total = float(len(trace))
    mean_gap = float(gaps.mean()) if len(gaps) else 0.0
    cv = float(gaps.std() / mean_gap) if mean_gap else 0.0
    top10 = max(1, len(ordered) // 10)
    return {
        "requests": total,
        "sessions": float(len(counts)),
        "followups": total - len(counts),
        "mean_gap": mean_gap,
        "cv_gap": cv,
        "top1_share": ordered[0] / total,
        "top10pct_share": sum(ordered[:top10]) / total,
        "duration": float(times[-1]) if len(times) else 0.0,
    }


def latency_percentiles(values: Sequence[float],
                        qs: Sequence[float] = (50.0, 95.0, 99.0)
                        ) -> dict[str, float]:
    """{"p50": ..., "p95": ..., "p99": ...} over ``values`` (0.0 when
    empty); linear-interpolation percentiles, same convention as numpy."""
    if len(values) == 0:
        return {f"p{q:g}": 0.0 for q in qs}
    arr = np.asarray(values, float)
    return {f"p{q:g}": float(np.percentile(arr, q)) for q in qs}


# ----------------------------------------------------------------- predictor
class InterArrivalPredictor:
    """Per-session EMA of inter-arrival gaps, with a global-EMA prior for
    sessions seen once — the learning half of predictive warming."""

    def __init__(self, alpha: float = 0.4) -> None:
        self.alpha = alpha
        self._last: dict[int, float] = {}
        self._ema: dict[int, float] = {}
        self._global: float | None = None

    def observe(self, session: int, t: float) -> float | None:
        """Record an arrival; returns the observed gap (None on first)."""
        last = self._last.get(session)
        self._last[session] = t
        if last is None:
            return None
        gap = t - last
        ema = self._ema.get(session)
        self._ema[session] = (gap if ema is None
                              else self.alpha * gap + (1 - self.alpha) * ema)
        self._global = (gap if self._global is None
                        else 0.05 * gap + 0.95 * self._global)
        return gap

    def predict(self, session: int) -> float | None:
        """Predicted gap to the session's next arrival (global prior until a
        per-session gap has been seen; None before any gap at all)."""
        return self._ema.get(session, self._global)

    def last_seen(self, session: int) -> float | None:
        """Timestamp of the session's last observed arrival (None if never
        seen) — lets a late subscriber (e.g. a joining engine seeding warms
        for migrated sessions) anchor ``predict()`` to the real clock."""
        return self._last.get(session)


# ----------------------------------------------------------------- synthetic
@dataclasses.dataclass(frozen=True)
class CostModel:
    """Modeled service times (seconds) — stands in for the measured JAX
    prefill/decode at trace scale. Values approximate a mid-size model on a
    single accelerator; only their *ratios* to the hierarchy's media times
    matter for routing decisions."""

    prefill_base_s: float = 0.012
    prefill_per_token_s: float = 0.00035
    decode_per_token_s: float = 0.010
    # cold-start cost of an engine joining mid-trace: loading model params
    # onto the accelerator before the first request can be served
    join_params_load_s: float = 8.0

    def prefill_seconds(self, n_tokens: int) -> float:
        return self.prefill_base_s + self.prefill_per_token_s * n_tokens

    def decode_seconds(self, n_tokens: int) -> float:
        return self.decode_per_token_s * n_tokens


class SyntheticBackend:
    """Compute-free :class:`~repro.serve.engine.ServingEngine` backend.

    State is a tiny numpy pytree (a per-slot prompt fingerprint + step
    counter) and decode is a pure function of it, so park/resume and
    cross-engine failover stay **bit-identical** exactly as with the JAX
    backend — while ``slot_nbytes`` reports the *modeled* KV size
    (``kv_bytes``), which is what the store's capacity accounting and
    eviction see. ``prefill`` returns modeled seconds from ``prefill_cost``
    so the router's migrate pricing works on the same scale as the
    hierarchy's media times.
    """

    def __init__(self, *, kv_bytes: float = 64 * MiB, vocab: int = 32_768,
                 width: int = 4,
                 prefill_cost: Callable[[int], float] | None = None) -> None:
        self.kv_bytes = float(kv_bytes)
        self.vocab = vocab
        self.width = width
        self.prefill_cost = prefill_cost or CostModel().prefill_seconds
        self._template: dict[str, np.ndarray] | None = None

    def init_state(self, batch: int) -> dict[str, np.ndarray]:
        return {"fp": np.zeros((batch, self.width), np.int64),
                "step": np.zeros((batch, 1), np.int32)}

    def slot_template(self) -> dict[str, np.ndarray]:
        if self._template is None:
            self._template = self.init_state(1)
        return self._template

    def slot_nbytes(self) -> float:
        return self.kv_bytes

    def prefill(self, params, prompt: list[int],
                extras) -> tuple[int, dict[str, np.ndarray], float]:
        arr = np.asarray(prompt, np.int64)
        fp = int((int(arr.sum()) * 1_000_003 + len(prompt) * 8191
                  + (int(arr[0]) + 1) * 131 + int(arr[-1]) + 1)
                 % (1 << 31))
        state = {"fp": np.full((1, self.width), fp, np.int64),
                 "step": np.full((1, 1), len(prompt), np.int32)}
        return fp % self.vocab, state, self.prefill_cost(len(prompt))

    def decode(self, params, state: dict[str, np.ndarray],
               tokens: np.ndarray) -> tuple[np.ndarray, dict[str, np.ndarray]]:
        out = (state["fp"][:, 0] * 31 + tokens[:, 0].astype(np.int64)
               + state["step"][:, 0] * 7) % self.vocab
        state["step"] = state["step"] + 1
        return out, state

    @staticmethod
    def write_slot(pooled: dict, single: dict, slot: int) -> dict:
        for k, p in pooled.items():
            s = single[k]
            if p.shape == s.shape:
                p[...] = s
            else:
                p[slot:slot + 1] = s
        return pooled

    @staticmethod
    def read_slot(pooled: dict, template: dict, slot: int) -> dict:
        out = {}
        for k, p in pooled.items():
            if p.shape == template[k].shape:
                out[k] = p.copy()
            else:
                out[k] = p[slot:slot + 1].copy()
        return out


def build_trace_stack(*, n_engines: int = 4, max_batch: int = 8,
                      kv_bytes: float = 64 * MiB, tiered: bool = True,
                      bb_slots_per_node: int = 64,
                      cost: CostModel | None = None,
                      allow_park: bool | None = None,
                      write_policy: str = "back",
                      durability: str = "none",
                      topology=None) -> tuple[Router, LocStore]:
    """A synthetic-backend serving cluster sized for trace runs.

    ``tiered=True``: per-node HBM holding exactly the live slots + a burst
    buffer holding ``bb_slots_per_node`` parked sessions, spilling to a
    2 GB/s remote PFS — the memory-pressure regime where parking pays.
    ``tiered=False``: the flat unbounded store (flat pinning baseline);
    parking is disabled unless ``allow_park`` overrides. Pass
    ``durability="flush_before_ack"`` when the trace includes node failures
    and parked sessions should survive them (a park then always leaves a
    PFS copy behind, so ``Router.fail_engine`` can re-home them).
    ``topology`` (a :class:`~repro.core.topology.ClusterTopology`) makes the
    router's resume-vs-migrate pricing and failover re-homing charge real
    network paths; ``None`` or a flat topology keeps legacy pricing.
    """
    cost = cost or CostModel()
    if tiered:
        hier = StorageHierarchy(
            [TierSpec("hbm", max_batch * kv_bytes, 819e9),
             TierSpec("bb", bb_slots_per_node * kv_bytes, 8e9)],
            remote=TierSpec("remote", float("inf"), 2e9))
        store = LocStore(n_engines, hierarchy=hier, write_policy=write_policy,
                         durability=durability, topology=topology)
    else:
        store = LocStore(n_engines, topology=topology)
    cfg = ServingConfig(max_batch=max_batch, max_seq=1 << 20,
                        allow_park=tiered if allow_park is None else allow_park)
    engines = [ServingEngine(None, None, config=cfg, node=i, store=store,
                             backend=SyntheticBackend(
                                 kv_bytes=kv_bytes,
                                 prefill_cost=cost.prefill_seconds))
               for i in range(n_engines)]
    router = Router(engines, store, config=cfg)
    return router, store


# -------------------------------------------------------------------- driver
_ARRIVAL, _WARM, _FAIL, _WAKE, _JOIN, _JOIN_READY = 0, 1, 2, 3, 4, 5


@dataclasses.dataclass
class _SessState:
    sid: int | None = None        # engine session id (changes on migration)
    history: int = 0              # conversation tokens accumulated so far
    done_t: float = 0.0           # virtual time the previous answer finishes
    warm_done: float | None = None   # pending predictive warm completes at
    warm_src: str | None = None      # tier the warm promoted from
    alive: bool = False
    # follow-ups whose trace timestamp lands before the previous answer
    # finished decoding: the client hasn't seen the answer yet, so the turn
    # is deferred (FIFO per session) and woken at ``done_t`` — otherwise a
    # hot session's self-wait would drag the engine busy-clock into the
    # future and head-of-line-block every unrelated arrival behind it
    pending: collections.deque = dataclasses.field(
        default_factory=collections.deque)
    waking: bool = False          # a _WAKE event for this session is queued


@dataclasses.dataclass
class TraceReport:
    """Everything one trace run measured; ``summary()`` flattens it into the
    ``key=value`` metrics the benchmark rows and trend gate consume."""

    requests: int
    sessions: int
    sim_seconds: float
    ttft_ms: dict[str, float]          # p50/p95/p99 time-to-first-token
    queue_ms: dict[str, float]         # p50/p95/p99 queueing delay
    resume_ms: dict[str, float]        # p50/p95/p99 over resumed turns only
    counters: Mapping[str, float]

    def summary(self) -> dict[str, float]:
        out: dict[str, float] = {
            "requests": float(self.requests),
            "sessions": float(self.sessions),
            "sim_seconds": self.sim_seconds,
        }
        for label, series in (("ttft", self.ttft_ms), ("queue", self.queue_ms),
                              ("resume", self.resume_ms)):
            for q, v in series.items():
                out[f"{q}_{label}_ms"] = v
        out.update({k: float(v) for k, v in self.counters.items()})
        warms = out.get("warms", 0.0)
        hits = out.get("warm_hits", 0.0)
        out["warm_hit_rate"] = hits / warms if warms else 0.0
        out["wasted_warms"] = max(warms - hits, 0.0)
        return out


def _tokens(n: int, session: int, turn: int) -> list[int]:
    """A deterministic ``n``-token prompt for (session, turn) — content only
    matters for the synthetic fingerprint, length for the modeled cost."""
    v = (session * 2_654_435_761 + turn * 97 + 13) % 32_000 + 7
    return [v] * max(int(n), 1)


class TraceDriver:
    """Discrete-event serving driver over virtual time.

    Engines are modeled as serial admission resources (prefill and resume
    occupy the engine; decode overlaps via continuous batching), sessions
    serialize their own turns, and every service time is modeled
    (:class:`CostModel` + the hierarchy's media times) — never measured — so
    runs are deterministic and wall-clock-free.

    Per request it records **queue delay** (arrival -> service start),
    **TTFT** (arrival -> first new token: queue + prefill-or-resume + one
    decode step) and, for resumed turns, **resume latency** (media time to
    bring the parked KV slice back to the top tier, minus whatever a
    completed predictive warm already hid).
    """

    def __init__(self, router: Router, trace: Sequence[Request], *,
                 cost: CostModel | None = None, warm: bool = False,
                 predictor: InterArrivalPredictor | None = None,
                 warm_lead: float = 0.05,
                 failures: Sequence[tuple[float, int]] = (),
                 joins: Sequence[tuple[float, int]] = (),
                 engine_factory: Callable[[int], ServingEngine] | None = None,
                 drain_every: int = 256, max_history: int = 2048) -> None:
        self.router = router
        self.store = router.store
        self.hier = self.store.hierarchy
        self.trace = trace
        self.cost = cost or CostModel()
        self.warm_enabled = warm
        self.predictor = predictor or InterArrivalPredictor()
        self.warm_lead = warm_lead
        self.failures = list(failures)
        self.joins = list(joins)
        self.engine_factory = engine_factory
        self.drain_every = drain_every
        self.max_history = max_history
        any_engine = next(iter(router.engines.values()))
        # template for join-built engines — captured now so joins still work
        # in the all-engines-down window
        self._engine_template = any_engine
        self.kv = any_engine.slot_bytes()
        self._sess: dict[int, _SessState] = {}
        self._by_sid: dict[int, int] = {}
        self._busy: dict[int, float] = {}
        self._events: list[tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self._ttft: list[float] = []
        self._queue: list[float] = []
        self._resume: list[float] = []
        # (effective issue time, ttft seconds) per request, in completion
        # order — the recovery-window analysis in bench_membership needs the
        # time series, not just end-of-run percentiles
        self.samples: list[tuple[float, float]] = []
        self._t_end = 0.0
        self.counters: dict[str, float] = {
            k: 0.0 for k in ("new_sessions", "followups", "live_hits",
                             "resumes", "migrations", "lost_reprefills",
                             "finished", "force_finished",
                             "engine_full_errors", "warms", "warm_hits",
                             "resume_hidden_s", "failover_resumed",
                             "failover_lost", "failover_deferred",
                             "joins", "adopted_on_join", "rebalanced")}

    # ------------------------------------------------------------- plumbing
    def _media(self, tier: str) -> float:
        return self.hier.media_seconds(self.kv, tier)

    def _force_finish_lru(self) -> bool:
        """Flat-pinning relief valve: evict (finish) the cluster-wide LRU
        slotted session so admission can proceed — its conversation cache is
        gone; a later follow-up pays a full history re-prefill."""
        best: tuple[ServingEngine, object] | None = None
        for e in self.router.engines.values():
            for sess in e._slotted.values():
                if best is None or sess.last_active < best[1].last_active:
                    best = (e, sess)
        if best is None:
            return False
        eng, sess = best
        eng.finish(sess.sid)
        tsid = self._by_sid.get(sess.sid)
        if tsid is not None:
            st = self._sess.get(tsid)
            if st is not None and st.sid == sess.sid:
                st.alive = False
        self.counters["force_finished"] += 1
        return True

    def _admit(self, prompt: list[int]) -> tuple[ServingEngine, int]:
        while True:
            try:
                eng = self.router.engine_for()
                return eng, eng.submit(prompt)
            except RuntimeError:
                self.counters["engine_full_errors"] += 1
                if not self._force_finish_lru():
                    raise

    def _follow_up(self, sid: int, history: list[int]):
        while True:
            try:
                return self.router.follow_up(sid, history)
            except RuntimeError:
                self.counters["engine_full_errors"] += 1
                if not self._force_finish_lru():
                    raise

    def _record(self, t_eff: float, start: float, svc: float,
                resume_lat: float | None) -> None:
        """Latency is measured from ``t_eff`` — the *effective* issue time.
        A follow-up whose trace timestamp lands before the session's
        previous answer finished decoding cannot have been sent yet (the
        client is still reading); that shift is think time, not server
        latency. ``start - t_eff`` is therefore pure engine-queue wait."""
        self._queue.append(start - t_eff)
        ttft = (start - t_eff) + svc + self.cost.decode_seconds(1)
        self._ttft.append(ttft)
        self.samples.append((t_eff, ttft))
        if resume_lat is not None:
            self._resume.append(resume_lat)

    # --------------------------------------------------------------- events
    def _handle_fail(self, t: float, node: int) -> None:
        if node not in self.router.engines:
            return
        rep = self.router.fail_engine(node)
        self._busy.pop(node, None)
        self.counters["failover_resumed"] += len(rep.resumed)
        self.counters["failover_lost"] += len(rep.lost)
        self.counters["failover_deferred"] += len(rep.deferred)

    def _make_engine(self, node: int) -> ServingEngine:
        """Build the engine for a join: the caller's factory, or a clone of
        the construction-time template (same config/params/backend, fresh
        per-engine state) bound to the joining node."""
        if self.engine_factory is not None:
            return self.engine_factory(node)
        ref = self._engine_template
        return ServingEngine(ref.cfg, ref.params, config=ref.config,
                             node=node, store=self.store,
                             backend=ref.backend)

    def _handle_join(self, t: float, node: int) -> None:
        """The node announces itself: its params load starts now, but
        membership flips only when the load completes (saxml-style — a
        server is not routable until the model is resident). Joining the
        router at announce time would let the rebalance yank sessions onto
        a cold engine whose queue then head-of-line-blocks behind the whole
        params load."""
        if node in self.router.engines:
            return                       # already a live member
        heapq.heappush(self._events,
                       (t + self.cost.join_params_load_s, next(self._seq),
                        _JOIN_READY, node))

    def _handle_join_ready(self, t: float, node: int) -> None:
        if node in self.router.engines:
            return                       # already a live member
        eng = self._make_engine(node)
        rep = self.router.join_engine(node, eng)
        self.counters["joins"] += 1
        self.counters["adopted_on_join"] += len(rep.adopted)
        self.counters["rebalanced"] += len(rep.rebalanced)
        if not self.warm_enabled:
            return
        # seed the warm predictor for migrated sessions: their next arrival
        # is predicted from the pre-failure issue pattern, anchored at the
        # last observed arrival
        for sid in (*rep.adopted, *rep.rebalanced):
            session = self._by_sid.get(sid)
            if session is None:
                continue
            st = self._sess.get(session)
            if st is None or not st.alive or st.sid != sid:
                continue
            gap = self.predictor.predict(session)
            if gap is None:
                continue
            last = self.predictor.last_seen(session)
            anchor = last if last is not None else t
            tw = max(anchor + gap - self.warm_lead, t + 1e-6)
            heapq.heappush(self._events,
                           (tw, next(self._seq), _WARM, session))

    def _handle_warm(self, t: float, session: int) -> None:
        s = self._sess.get(session)
        if s is None or not s.alive or s.sid is None:
            return
        name = _cache_name(s.sid)
        if not self.store.exists(name):
            return
        node = self.store.getxattr(name, "engine")
        p = self.store.stat(name)
        src = p.tier_on(node) if p.resident_on(node) else "remote"
        if src == self.hier.top:
            return                       # already in the top tier
        if self.router.warm(s.sid):
            self.counters["warms"] += 1
            s.warm_done = t + self._media(src) + self._media(self.hier.top)
            s.warm_src = src

    def _handle_arrival(self, t: float, req: Request) -> None:
        s = self._sess.setdefault(req.session, _SessState())
        self.predictor.observe(req.session, t)   # the client's issue pattern
        if s.pending or t < s.done_t:
            # previous answer still decoding — the client hasn't seen it,
            # so this turn can't have been issued yet; defer it (FIFO)
            s.pending.append(req)
            if not s.waking:
                s.waking = True
                heapq.heappush(self._events,
                               (s.done_t, next(self._seq), _WAKE,
                                req.session))
            return
        self._process(t, req)

    def _handle_wake(self, t: float, session: int) -> None:
        s = self._sess[session]
        s.waking = False
        if not s.pending:
            return
        self._process(t, s.pending.popleft())
        if s.pending:
            s.waking = True
            heapq.heappush(self._events,
                           (s.done_t, next(self._seq), _WAKE, session))

    def _process(self, t: float, req: Request) -> None:
        s = self._sess[req.session]
        t_eff = max(t, s.done_t)
        if not s.alive:
            # opening turn — or a force-finished/failed session coming back:
            # then the whole conversation history is re-prefilled (the cost
            # flat pinning pays for every one of its evictions)
            lost = s.history > 0
            plen = (min(s.history, self.max_history) if lost
                    else req.prompt_len)
            eng, sid = self._admit(_tokens(plen, req.session, req.turn))
            self._by_sid[sid] = req.session
            s.sid = sid
            s.alive = True
            svc = self.cost.prefill_seconds(plen)
            resume_lat = None
            self.counters["lost_reprefills" if lost else "new_sessions"] += 1
        else:
            self.counters["followups"] += 1
            name = _cache_name(s.sid)
            tier_before = None
            if self.store.exists(name):
                node = self.store.getxattr(name, "engine")
                p = self.store.stat(name)
                tier_before = (p.tier_on(node) if p.resident_on(node)
                               else "remote")
            hist = _tokens(min(s.history, self.max_history),
                           req.session, req.turn)
            d = self._follow_up(s.sid, hist)
            eng = d.engine
            if d.prefilled:
                self.counters["migrations"] += 1
                self._by_sid[d.sid] = req.session
                s.sid = d.sid
                svc = self.cost.prefill_seconds(len(hist))
                resume_lat = None
            elif d.resumed:
                self.counters["resumes"] += 1
                top = self.hier.top
                src = tier_before or top
                base = self._media(src) + self._media(top)
                if (s.warm_done is not None and s.warm_src is not None
                        and tier_before == top):
                    # predictive warm promoted the slice before we arrived;
                    # pay only the in-flight remainder (if any) + top media
                    would = self._media(s.warm_src) + self._media(top)
                    resume_lat = (max(0.0, s.warm_done - t_eff)
                                  + self._media(top))
                    self.counters["warm_hits"] += 1
                    self.counters["resume_hidden_s"] += max(
                        0.0, would - resume_lat)
                else:
                    resume_lat = base
                svc = resume_lat
            else:                         # hit_live: still in its slot
                self.counters["live_hits"] += 1
                svc = 0.0
                resume_lat = None
        s.warm_done = s.warm_src = None
        start = max(t_eff, self._busy.get(eng.node, 0.0))
        self._busy[eng.node] = start + svc
        self._record(t_eff, start, svc, resume_lat)
        s.done_t = start + svc + self.cost.decode_seconds(req.output_len)
        s.history += req.prompt_len + req.output_len
        self._t_end = max(self._t_end, s.done_t)
        if req.final:
            eng.finish(s.sid)
            s.alive = False
            self.counters["finished"] += 1
        elif self.warm_enabled:
            gap = self.predictor.predict(req.session)
            if gap is not None:
                tw = max(t + gap - self.warm_lead, s.done_t, t + 1e-6)
                heapq.heappush(self._events,
                               (tw, next(self._seq), _WARM, req.session))

    # ------------------------------------------------------------------ run
    def run(self) -> TraceReport:
        self._events = [(r.t, next(self._seq), _ARRIVAL, r)
                        for r in self.trace]
        for t, node in self.failures:
            self._events.append((float(t), next(self._seq), _FAIL, int(node)))
        # joins pushed after failures: a same-instant fail-then-join cycle
        # processes the failure first (seq breaks the time tie)
        for t, node in self.joins:
            self._events.append((float(t), next(self._seq), _JOIN, int(node)))
        heapq.heapify(self._events)
        processed = 0
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            if kind == _ARRIVAL:
                self._handle_arrival(t, payload)
            elif kind == _WARM:
                self._handle_warm(t, payload)
            elif kind == _WAKE:
                self._handle_wake(t, payload)
            elif kind == _JOIN:
                self._handle_join(t, payload)
            elif kind == _JOIN_READY:
                self._handle_join_ready(t, payload)
            else:
                self._handle_fail(t, payload)
            processed += 1
            if self.drain_every and processed % self.drain_every == 0:
                self.store.drain_writebacks()
                # the per-transfer ledger is for small-run tests; at 10^5+
                # sessions it is pure memory growth (counters are separate)
                del self.store.transfers[:]
        self.store.drain_writebacks()
        sessions = len({r.session for r in self.trace})
        return TraceReport(
            requests=len(self.trace), sessions=sessions,
            sim_seconds=self._t_end,
            ttft_ms={k: v * 1e3
                     for k, v in latency_percentiles(self._ttft).items()},
            queue_ms={k: v * 1e3
                      for k, v in latency_percentiles(self._queue).items()},
            resume_ms={k: v * 1e3
                       for k, v in latency_percentiles(self._resume).items()},
            counters=dict(self.counters),
        )
