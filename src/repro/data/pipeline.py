"""Location-aware, prefetching input pipeline — the paper's machinery feeding
the training loop.

Two cooperating pieces:

* :class:`SyntheticCorpus` — deterministic token shards (seeded, reproducible
  across restarts: shard i is always the same bytes, so elastic restarts
  resume mid-epoch without data loss). Stands in for a tokenized web corpus.

* :class:`PrefetchingLoader` — the paper's proactive pipelining at step grain:
  a background thread *pre-materializes* batch k+1..k+depth and device_puts
  them (location = the consuming host/device) while step k computes. The
  train loop's I/O wait is then ~0 (measured in bench_prefetch): exactly the
  paper's claim, realized with JAX async dispatch instead of Hercules.

The workflow integration (`epoch_workflow`) expresses a training epoch as a
TaskGraph — load tasks hinted with ``@size``/``@io_ratio``, step tasks with
``@compute-complexity`` — so the core scheduler/simulator can reason about a
REAL workload shape (used by bench_scheduler's "training epoch" scenario).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dag import TaskGraph
from repro.core.hints import Complexity, size_hint, task

Pytree = Any


class SyntheticCorpus:
    """Deterministic sharded token stream."""

    def __init__(self, vocab: int, shard_tokens: int = 1 << 16,
                 seed: int = 0) -> None:
        self.vocab = vocab
        self.shard_tokens = shard_tokens
        self.seed = seed

    def shard(self, i: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, i))
        # zipf-ish marginal so the loss curve is non-trivial
        z = rng.zipf(1.3, self.shard_tokens).astype(np.int64)
        return (z % self.vocab).astype(np.int32)

    def batches(self, batch: int, seq: int, start_step: int = 0
                ) -> Iterator[dict[str, np.ndarray]]:
        need = batch * (seq + 1)
        per_shard = self.shard_tokens // need
        step = start_step
        while True:
            sid, off = divmod(step, max(per_shard, 1))
            data = self.shard(sid)[off * need:(off + 1) * need]
            if len(data) < need:
                step += 1
                continue
            x = data.reshape(batch, seq + 1)
            yield {"tokens": x[:, :-1], "labels": x[:, 1:]}
            step += 1


class PrefetchingLoader:
    """Double-buffered (depth-N) async loader with device placement."""

    def __init__(self, it: Iterator[dict[str, np.ndarray]], *,
                 depth: int = 2,
                 place: Callable[[Pytree], Pytree] | None = None) -> None:
        self.it = it
        self.place = place or (lambda b: jax.tree.map(jax.numpy.asarray, b))
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.waits = 0            # times the consumer found the queue empty
        self.loads = 0
        self._thread = threading.Thread(target=self._work, daemon=True,
                                        name="xflow-data-prefetch")
        self._thread.start()

    def _work(self) -> None:
        try:
            for batch in self.it:
                if self._stop.is_set():
                    return
                self.q.put(self.place(batch))   # async device transfer
                self.loads += 1
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        if self.q.empty():
            self.waits += 1
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def epoch_workflow(cfg: ModelConfig, *, n_steps: int, n_dp: int,
                   batch: int, seq: int, step_flops: float) -> TaskGraph:
    """A training epoch as a hinted TaskGraph (consumed by core/scheduler)."""
    g = TaskGraph()
    batch_bytes = batch // n_dp * (seq + 1) * 4
    g.add_data("corpus", size_bytes=size_hint(n_steps * n_dp * batch_bytes))
    g.add_data("params0", size_bytes=size_hint(2e9))
    prev = "params0"
    for s in range(n_steps):
        parts = []
        for d in range(n_dp):
            b = f"batch_{s}_{d}"
            g.add_task(f"load_{s}_{d}", inputs=("corpus",), outputs=(b,),
                       hints=task(compute="const",
                                  io_ratio=1.0 / (n_steps * n_dp)))
            parts.append(b)
        out = f"params{s + 1}"
        g.add_task(f"step_{s}", inputs=(prev, *parts), outputs=(out,),
                   hints=task(procs=n_dp, io_ratio=1.0,
                              compute=Complexity("const",
                                                 flops_per_byte=step_flops)))
        prev = out
    return g
