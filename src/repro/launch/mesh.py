"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing jax;
everything else (tests, benches) sees the real single CPU device and builds
1×1 meshes via :func:`make_local_mesh`.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading pod axis (2 pods).

    Axis semantics: ``pod`` = cross-pod DP over DCN; ``data`` = in-pod DP +
    FSDP; ``model`` = TP/EP over ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — run "
            "under launch/dryrun.py (it forces 512 host devices) or on a pod")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real local devices (CPU tests / examples)."""
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n])
