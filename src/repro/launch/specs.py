"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these. Modality frontends are stubs per the assignment: whisper gets
precomputed frame embeddings, llama-vision gets patch embeddings.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M

Pytree = Any


def batch_specs_for(cfg: ModelConfig, shape: InputShape) -> Pytree:
    B, S = shape.global_batch, shape.seq_len
    batch: dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model),
                                               dt)
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches,
                                                 cfg.d_model), dt)
    return batch


def decode_specs_for(cfg: ModelConfig, shape: InputShape) -> tuple[Pytree, Pytree]:
    """(state_specs, token_specs) for one serve_step with a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    state = jax.eval_shape(lambda: M.init_decode_state(cfg, B, S))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return state, tokens


def params_specs_for(cfg: ModelConfig) -> Pytree:
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Pytree]:
    """Everything the chosen step consumes, as ShapeDtypeStructs."""
    out = {"params": params_specs_for(cfg)}
    if shape.kind in ("train", "prefill"):
        out["batch"] = batch_specs_for(cfg, shape)
    if shape.kind == "decode":
        state, tokens = decode_specs_for(cfg, shape)
        out["state"], out["tokens"] = state, tokens
    return out
