import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Per-cell dry-run profiler — the §Perf loop's microscope.

Compiles ONE (arch × shape × mesh) cell exactly as launch/dryrun.py does and
prints the top collectives and top dot instructions (with while-loop
multiplicities), so a hillclimb iteration can see exactly which op its last
change moved.

  python -m repro.launch.profile_cell --arch arctic-480b --shape train_4k \
      --mesh pod1 [--save results/cell.hlo]
"""

import argparse

from repro.launch import hlo_analysis as H


def profile(arch: str, shape_name: str, mesh_kind: str,
            save: str | None = None, top: int = 14,
            seq_parallel: bool = False) -> None:
    import jax
    from repro.configs import SHAPES, get_config
    from repro.dist import sharding as shd
    from repro.dist.hints import sharding_rules
    from repro.launch.dryrun import microbatches_for, opt_config_for
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs
    from repro.train.optimizer import init_opt_state
    from repro.train.train_step import (make_prefill_step, make_serve_step,
                                        make_train_step)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    specs = input_specs(cfg, shape)
    with mesh:
        if shape.kind == "train":
            mb, acc = microbatches_for(cfg, shape)
            step = make_train_step(
                cfg, opt_config_for(cfg), microbatches=mb, accum_dtype=acc,
                grad_specs=shd.param_specs(cfg, specs["params"], mesh))
            p = specs["params"]
            o = jax.eval_shape(lambda: init_opt_state(opt_config_for(cfg), p))
            in_sh = (shd.named(mesh, shd.param_specs(cfg, p, mesh)),
                     shd.named(mesh, {"m": shd.param_specs(cfg, p, mesh),
                                      "v": shd.param_specs(cfg, p, mesh),
                                      "step": jax.sharding.PartitionSpec()}),
                     shd.named(mesh, shd.batch_specs(cfg, specs["batch"],
                                                     mesh)))
            args = (p, o, specs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, shape.seq_len)
            p = specs["params"]
            in_sh = (shd.named(mesh, shd.param_specs(cfg, p, mesh)),
                     shd.named(mesh, shd.batch_specs(cfg, specs["batch"],
                                                     mesh)))
            args = (p, specs["batch"])
        else:
            step = make_serve_step(cfg)
            p = specs["params"]
            in_sh = (shd.named(mesh, shd.param_specs(cfg, p, mesh)),
                     shd.named(mesh, shd.decode_state_specs(
                         cfg, specs["state"], mesh)),
                     shd.named(mesh, shd.batch_specs(
                         cfg, {"t": specs["tokens"]}, mesh))["t"])
            args = (p, specs["state"], specs["tokens"])
        with sharding_rules(mesh, seq_parallel=seq_parallel):
            compiled = jax.jit(step, in_shardings=in_sh).lower(*args).compile()
    txt = compiled.as_text()
    if save:
        with open(save, "w") as f:
            f.write(txt)
    a = H.analyze(txt)
    print(f"== {arch} {shape_name} {mesh_kind} ==")
    print(f"dot_flops/dev: {a['dot_flops']/1e12:.1f} TF   "
          f"collective: {a['collective_total']/1e12:.2f} TB   "
          f"result_bytes: {a['result_bytes']/1e12:.2f} TB")
    ma = compiled.memory_analysis()
    if ma:
        print(f"temp: {ma.temp_size_in_bytes/1e9:.1f} GB   "
              f"args: {ma.argument_size_in_bytes/1e9:.1f} GB")
    print("\ntop collectives (bytes x mult):")
    for row in H.top_collectives(txt, top):
        print("  " + row)
    print("\ntop dots:")
    comps = H.parse_computations(txt)
    entry = H._entry_name(comps, txt)
    mult = H.multiplicities(comps, entry)
    rows = []
    for cname, m in mult.items():
        for ins in comps[cname].instrs:
            if ins.op == "dot":
                rows.append((m * H._dot_flops(comps[cname], ins), m,
                             ins.name, cname))
    rows.sort(reverse=True)
    for fl, m, name, cname in rows[:top]:
        print(f"  {fl/1e12:8.2f}TF x{int(m):5d}  {name:20s} @{cname[:50]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod1", choices=("pod1", "pod2"))
    ap.add_argument("--save")
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--sp", action="store_true", help="Megatron seq-parallel")
    args = ap.parse_args()
    profile(args.arch, args.shape, args.mesh, args.save, args.top,
            seq_parallel=args.sp)


if __name__ == "__main__":
    main()
