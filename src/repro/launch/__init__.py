"""Subpackage."""
