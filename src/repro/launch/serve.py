"""Serving launcher: batched generation with location-aware routing.

``python -m repro.launch.serve --arch <id> --engines 2 --requests 12``

Runs smoke-scale engines on CPU; demonstrates the cross-layer serving path:
sessions pinned in the location service, follow-up requests routed to the
engine holding the KV cache (compute-on-data-path for inference).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import ARCH_NAMES, get_smoke
from repro.core.locstore import LocStore
from repro.models import init_params
from repro.serve.engine import Router, ServingEngine

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="granite-3-2b")
    ap.add_argument("--engines", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = LocStore(args.engines)
    engines = [ServingEngine(cfg, params, max_batch=args.max_batch,
                             max_seq=96, node=i, store=store)
               for i in range(args.engines)]
    router = Router(engines, store)
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    sessions = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=8).tolist()
        eng = router.engine_for()
        sid = eng.submit(prompt)
        sessions.append((eng, sid))
        print(f"req {i}: engine {eng.node} slot session {sid}")
    # decode everything to completion, round-robin across engines
    for _ in range(args.max_new):
        for eng in engines:
            eng.step()
    for eng, sid in sessions:
        toks = eng.finish(sid)
        print(f"engine {eng.node} session {sid}: {toks[:args.max_new]}")
    dt = time.perf_counter() - t0
    total_tokens = sum(len(e.finish(s)) for e, s in sessions)
    print(f"\n{args.requests} requests, {total_tokens} tokens, "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    print("router locality:", router.locality_hits, "hits /",
          router.locality_misses, "misses")


if __name__ == "__main__":
    main()
