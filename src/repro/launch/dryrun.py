import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. constructs ShapeDtypeStruct inputs (no allocation) and the sharding
     rules from repro.dist.sharding,
  3. ``jax.jit(step, in_shardings=…).lower(...).compile()`` — a failure here
     (sharding mismatch, OOM at compile, unsupported collective) is a bug,
  4. records ``compiled.memory_analysis()`` (proves it fits),
     ``cost_analysis()`` (FLOPs/bytes for §Roofline) and the collective
     traffic parsed from the optimized HLO, into a JSONL file consumed by
     benchmarks/bench_roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""

import argparse
import json
import time
import traceback
from typing import Any

import jax
import numpy as np

from repro.configs import ARCH_NAMES, SHAPES, get_config, shapes_for
from repro.dist import sharding as shd
from repro.dist.hints import sharding_rules
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import (make_prefill_step, make_serve_step,
                                    make_train_step)


def opt_config_for(cfg) -> OptConfig:
    from repro.models import param_count
    big = param_count(cfg) > 80e9
    return OptConfig(moment_dtype="bfloat16" if big else "float32")


def microbatches_for(cfg, shape) -> tuple[int, object]:
    """Gradient-accumulation depth per train cell (memory-term control):
    activations scale with tokens-per-pass. Giant models also accumulate in
    bf16 (an f32 accumulator alone would be 2.7 TB for deepseek-v3)."""
    import jax.numpy as jnp
    from repro.models import param_count
    n = param_count(cfg)
    if shape.kind != "train":
        return 1, None
    if n > 80e9:
        return 8, jnp.bfloat16
    if n > 20e9 or cfg.family == "hybrid":
        return 8, None
    if n > 8e9:
        return 4, None
    return 2, None


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             collect_hlo: bool = True) -> dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "kind": shape.kind, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
        specs = input_specs(cfg, shape)
        with mesh:
            if shape.kind == "train":
                mb, acc_dt = microbatches_for(cfg, shape)
                rec["microbatches"] = mb
                step = make_train_step(
                    cfg, opt_config_for(cfg), microbatches=mb,
                    accum_dtype=acc_dt,
                    grad_specs=shd.param_specs(cfg, specs["params"], mesh))
                p_specs = specs["params"]
                o_specs = jax.eval_shape(
                    lambda: init_opt_state(opt_config_for(cfg), p_specs))
                in_sh = (shd.named(mesh, shd.param_specs(cfg, p_specs, mesh)),
                         shd.named(mesh, {
                             "m": shd.param_specs(cfg, p_specs, mesh),
                             "v": shd.param_specs(cfg, p_specs, mesh),
                             "step": jax.sharding.PartitionSpec()}),
                         shd.named(mesh, shd.batch_specs(
                             cfg, specs["batch"], mesh)))
                args = (p_specs, o_specs, specs["batch"])
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg, shape.seq_len)
                p_specs = specs["params"]
                in_sh = (shd.named(mesh, shd.param_specs(cfg, p_specs, mesh)),
                         shd.named(mesh, shd.batch_specs(
                             cfg, specs["batch"], mesh)))
                args = (p_specs, specs["batch"])
            else:  # decode
                step = make_serve_step(cfg)
                p_specs = specs["params"]
                in_sh = (shd.named(mesh, shd.param_specs(cfg, p_specs, mesh)),
                         shd.named(mesh, shd.decode_state_specs(
                             cfg, specs["state"], mesh)),
                         shd.named(mesh, shd.batch_specs(
                             cfg, {"t": specs["tokens"]}, mesh))["t"])
                args = (p_specs, specs["state"], specs["tokens"])

            with sharding_rules(mesh):
                lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
            compiled = lowered.compile()

            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):   # jax<=0.4 returns [dict]
                ca = ca[0] if ca else {}
            ma = compiled.memory_analysis()
            rec["flops_per_device"] = float(ca.get("flops", 0.0))
            rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
            if ma is not None:
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes"):
                    v = getattr(ma, k, None)
                    if v is not None:
                        rec[k] = int(v)
            if collect_hlo:
                hlo = hlo_analysis.analyze(compiled.as_text())
                rec["collectives"] = hlo["collective_bytes"]
                rec["collective_total"] = hlo["collective_total"]
                rec["collective_count"] = hlo["collective_count"]
                rec["dot_flops_per_device"] = hlo["dot_flops"]
                rec["result_bytes_per_device"] = hlo["result_bytes"]
                rec["n_while"] = hlo["n_while"]
            rec["n_devices"] = int(np.prod(list(mesh.shape.values())))
            rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — recorded, cell marked failed
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["compile_seconds"] = round(time.time() - t0, 1)
    return rec


def cells(arch_filter=None, shape_filter=None, mesh_filter=None):
    for arch in ARCH_NAMES:
        if arch_filter and arch != arch_filter:
            continue
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if shape_filter and shape.name != shape_filter:
                continue
            for mesh_kind in ("pod1", "pod2"):
                if mesh_filter and mesh_kind != mesh_filter:
                    continue
                yield arch, shape.name, mesh_kind


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("pod1", "pod2"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present & ok in --out")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip collective parsing (faster)")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done: set[tuple] = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    todo = list(cells(args.arch, args.shape, args.mesh))
    print(f"dry-run: {len(todo)} cells -> {args.out}", flush=True)
    n_ok = n_fail = 0
    with open(args.out, "a") as f:
        for arch, shape, mesh_kind in todo:
            if (arch, shape, mesh_kind) in done:
                continue
            rec = run_cell(arch, shape, mesh_kind,
                           collect_hlo=not args.no_hlo)
            f.write(json.dumps(rec) + "\n")
            f.flush()
            status = "OK " if rec["ok"] else "FAIL"
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
            print(f"[{status}] {arch:22s} {shape:12s} {mesh_kind} "
                  f"({rec['compile_seconds']}s) "
                  f"{rec.get('error', '')}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed", flush=True)


if __name__ == "__main__":
    main()
