"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the smoke-scale variant of the chosen arch
end to end (real data pipeline, prefetch, checkpointing, optional simulated
failure). On a pod the same entrypoint takes ``--full --mesh pod1|pod2`` and
builds the production mesh + sharded step (the dry-run validates that path
per cell without hardware).
"""

from __future__ import annotations

import argparse

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a node failure at this step")
    ap.add_argument("--full", action="store_true",
                    help="full published config (pod scale; needs a mesh)")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    tc = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     simulate_failure_at=args.fail_at)
    oc = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                   total_steps=args.steps)

    def log(step, metrics):
        if step % 10 == 0 or step == 1:
            extra = " ".join(f"{k}={float(v):.4f}" for k, v in metrics.items()
                             if k != "loss")
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} {extra}",
                  flush=True)

    r = train(cfg, tc, oc, on_step=log)
    print(f"\ndone: {r.steps_done} steps, {r.restarts} restarts, "
          f"{r.wall_seconds:.1f}s, loss {r.losses[0]:.3f} -> {r.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
