"""While-aware analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE — useless for
scan-over-layers programs where ~L× the reported FLOPs actually execute. This
module parses ``compiled.as_text()`` into computations, recovers each loop's
trip count from its condition's comparison constant, propagates execution
multiplicities (ENTRY=1, while body ×trip, fusion bodies inherit the caller's
multiplicity), and reports:

  * ``dot_flops``          — Σ mult × 2 × numel(result) × K over every dot,
  * ``collective_bytes``   — Σ mult × result bytes, by collective type,
  * ``result_bytes``       — Σ mult × result bytes over top-level instructions
                             (a proxy for HBM traffic written; reads ≈ same
                             order), excluding fusion-internal instructions.

This is the dry-run "profile" the §Perf loop reads: redundant collectives,
layout copies and remat recompute all show up here.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((?:[^()]|\([^()]*\))*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[\w\[\],{}\s/*]+?))\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_WHILE_LINKS = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND = re.compile(r"%([\w.\-]+)")


def _shape_info(type_text: str) -> tuple[float, list[tuple[str, list[int]]]]:
    """(total bytes, [(dtype, dims), ...]) for a result type string."""
    total = 0.0
    shapes = []
    for dt, dims_s in _SHAPE.findall(type_text):
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dims))
    return total, shapes


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_bytes: float
    result_shapes: list
    rest: str            # text after the '(' of op(...)


@dataclasses.dataclass
class Comp:
    name: str
    instrs: list[Instr] = dataclasses.field(default_factory=list)
    shapes: dict[str, list] = dataclasses.field(default_factory=dict)
    is_fusion_body: bool = False


def parse_computations(text: str) -> dict[str, Comp]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):       # top-level: computation header
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Comp(m.group(1))
                comps[cur.name] = cur
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        rbytes, rshapes = _shape_info(rtype)
        cur.instrs.append(Instr(name, op, rbytes, rshapes, rest))
        cur.shapes[name] = rshapes
    return comps


def _trip_count(cond: Comp) -> int:
    consts = []
    for ins in cond.instrs:
        consts += [int(c) for c in _CONST.findall(ins.rest)]
        consts += [int(c) for c in _CONST.findall(ins.op)]
    # also catch "%constant.39 = s32[] constant(5)" lines where op=="constant"
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"^\s*(\d+)\)?", ins.rest)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def multiplicities(comps: dict[str, Comp], entry: str) -> dict[str, float]:
    """Execution count per computation, walking while/calls links."""
    mult: dict[str, float] = defaultdict(float)

    def visit(cname: str, m: float, depth: int = 0) -> None:
        if cname not in comps or m <= 0 or depth > 32:
            return
        mult[cname] += m
        comp = comps[cname]
        for ins in comp.instrs:
            if ins.op == "while":
                lm = _WHILE_LINKS.search(ins.rest)
                if lm:
                    cond_name, body_name = lm.group(1), lm.group(2)
                    tm = _TRIP.search(ins.rest)   # XLA's own annotation
                    trips = (int(tm.group(1)) if tm
                             else _trip_count(comps.get(cond_name, Comp(""))))
                    visit(body_name, m * trips, depth + 1)
                    visit(cond_name, m * (trips + 1), depth + 1)
            elif ins.op in ("fusion", "call", "custom-call", "conditional",
                            "reduce", "sort", "scatter", "map",
                            "async-start"):
                for sub in _CALLS.findall(ins.rest):
                    if sub != cname:
                        visit(sub, m, depth + 1)

    visit(entry, 1.0)
    return dict(mult)


def _entry_name(comps: dict[str, Comp], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m:
        return m.group(1)
    return next(iter(comps))


def _dot_flops(comp: Comp, ins: Instr) -> float:
    """2 × numel(result) × K, K from lhs contracting dims."""
    if not ins.result_shapes:
        return 0.0
    _, rdims = ins.result_shapes[0]
    numel = 1
    for d in rdims:
        numel *= d
    ops = _OPERAND.findall(ins.rest.split(")")[0])
    k = 1
    cm = _CONTRACT.search(ins.rest)
    if ops and cm and ops[0] in comp.shapes and comp.shapes[ops[0]]:
        _, ldims = comp.shapes[ops[0]][0]
        for ci in (int(x) for x in cm.group(1).split(",") if x):
            if ci < len(ldims):
                k *= ldims[ci]
    return 2.0 * numel * k


def analyze(text: str) -> dict:
    comps = parse_computations(text)
    entry = _entry_name(comps, text)
    mult = multiplicities(comps, entry)

    dot_flops = 0.0
    coll = {c: 0.0 for c in _COLLECTIVES}
    coll_count = 0.0
    result_bytes = 0.0
    fusion_bodies: set[str] = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                fusion_bodies.update(_CALLS.findall(ins.rest))

    for cname, m in mult.items():
        comp = comps[cname]
        in_fusion = cname in fusion_bodies
        for ins in comp.instrs:
            if ins.op == "dot":
                dot_flops += m * _dot_flops(comp, ins)
            base = None
            for c in _COLLECTIVES:
                if ins.op == c or ins.op.startswith(c + "-start"):
                    base = c
                    break
            if base is not None:
                coll[base] += m * ins.result_bytes
                coll_count += m
            if not in_fusion and ins.op not in ("parameter", "constant",
                                                "get-tuple-element", "tuple",
                                                "bitcast"):
                result_bytes += m * ins.result_bytes

    return {
        "dot_flops": dot_flops,
        "collective_bytes": coll,
        "collective_total": sum(coll.values()),
        "collective_count": coll_count,
        "result_bytes": result_bytes,
        "n_computations": len(comps),
        "n_while": sum(1 for c in comps.values()
                       for i in c.instrs if i.op == "while"),
    }


def top_collectives(text: str, n: int = 12) -> list[str]:
    """The n largest collectives (with multiplicity) — perf-loop helper."""
    comps = parse_computations(text)
    entry = _entry_name(comps, text)
    mult = multiplicities(comps, entry)
    rows = []
    for cname, m in mult.items():
        for ins in comps[cname].instrs:
            if any(ins.op == c or ins.op.startswith(c + "-start")
                   for c in _COLLECTIVES):
                rows.append((m * ins.result_bytes, m, ins.op, ins.name,
                             cname))
    rows.sort(reverse=True)
    return [f"{b/2**30:8.2f} GiB  x{int(m):4d}  {op:20s} {name} @{c}"
            for b, m, op, name, c in rows[:n]]
