"""Workflow/config linter — the "prove it before running" half of the paper's
compiler/runtime co-design (PR 9 tentpole, part a).

The compiler already sees the whole workflow (sizes, producers, consumers,
predicted placement); this module turns that visibility into pre-execution
proofs: races and broken happens-before edges, producerless inputs, dead
datasets, capacity infeasibility, durability hazards, unsafe ``mode="around"``
write pins, and cluster-config mistakes (zero-bandwidth links, zero-capacity
tiers, gapped membership schedules).

Usage::

    from repro.analysis import lint
    findings = lint.lint(wf, config=SimConfig(...), name="montage")
    for f in findings:
        print(f)

Every rule is registered in :data:`RULES` with an id and a default severity.
Findings can be *suppressed* with a reasoned allow-list entry (same discipline
as ``benchmarks/trend_allowlist.json``)::

    [{"rule": "dead-dataset", "target": "random_layered:d*",
      "reason": "random fan-in leaves unsampled layer outputs by design"}]

``target`` patterns are ``fnmatch``-style over ``"<workflow>:<target>"``; the
``reason`` field is mandatory — a suppression nobody can explain is a bug
magnet. ``python -m repro.analysis`` lints the built-in workloads and exits
non-zero on any unsuppressed WARNING-or-worse finding (the CI gate).

This module deliberately never imports the simulator or the serving stack —
the runtime imports *us* (``safe_write_modes`` gates the simulator's
``honor_write_modes="auto"`` default), so the dependency must stay one-way.
"""

from __future__ import annotations

import dataclasses
import enum
import fnmatch
import json
import os
from typing import Callable, Iterable, Iterator

from repro.core.config import SimConfig
from repro.core.dag import CycleError, TaskGraph
from repro.core.wfcompiler import CompiledWorkflow

__all__ = ["Severity", "Finding", "Rule", "RULES", "lint", "lint_graph",
           "safe_write_modes", "load_allowlist", "apply_allowlist",
           "default_allowlist_path"]


class Severity(enum.IntEnum):
    """Ordered so gates can compare: ``f.severity >= Severity.WARNING``."""

    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:  # "WARNING", not "Severity.WARNING"
        return self.name


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint result: which rule fired, on what, and why."""

    rule: str
    severity: Severity
    workflow: str
    target: str            # dataset / task / config element the rule fired on
    message: str
    suppressed: bool = False
    reason: str | None = None     # the allow-list entry's reason, if suppressed

    def __str__(self) -> str:
        sup = f" (suppressed: {self.reason})" if self.suppressed else ""
        return (f"[{self.rule}] {self.severity} {self.workflow}:{self.target}"
                f" — {self.message}{sup}")


@dataclasses.dataclass
class LintContext:
    """What a rule function gets to look at. ``wf``/``config`` are optional —
    structural rules work on a bare :class:`TaskGraph`; cost/placement rules
    return nothing when the context they need is missing."""

    graph: TaskGraph
    wf: CompiledWorkflow | None
    config: SimConfig | None
    name: str
    # per-run rule knobs (e.g. "oversub-factor"); rules read with .get()
    params: dict = dataclasses.field(default_factory=dict)
    _rule: "Rule | None" = None

    def finding(self, target: str, message: str,
                severity: Severity | None = None) -> Finding:
        assert self._rule is not None
        return Finding(rule=self._rule.id,
                       severity=self._rule.severity if severity is None
                       else severity,
                       workflow=self.name, target=target, message=message)

    def sizes(self) -> dict[str, float]:
        """Best-known dataset sizes: the compiler's propagated table when
        compiled, else whatever ``@size`` hints the graph carries."""
        if self.wf is not None:
            return self.wf.sizes
        return {d.name: float(d.size_bytes)
                for d in self.graph.data.values() if d.size_bytes is not None}


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    severity: Severity
    summary: str
    fn: Callable[[LintContext], Iterator[Finding]]


RULES: dict[str, Rule] = {}


def _rule(rid: str, severity: Severity, summary: str):
    def deco(fn: Callable[[LintContext], Iterator[Finding]]):
        RULES[rid] = Rule(rid, severity, summary, fn)
        return fn
    return deco


# --------------------------------------------------------------- structural
@_rule("waw-race", Severity.ERROR,
       "cycles, self-reads, duplicate writers, broken happens-before edges")
def _waw_race(ctx: LintContext) -> Iterator[Finding]:
    g = ctx.graph
    # self-referential tasks first: the most precise diagnosis of the
    # smallest cycle (a task that reads its own output races with itself)
    for tid, t in g.tasks.items():
        overlap = sorted(set(t.inputs) & set(t.outputs))
        if overlap:
            yield ctx.finding(tid, f"task reads its own output(s) "
                                   f"{overlap}: write-after-read on the same "
                                   f"dataset can never be ordered")
    # general cycles: run Kahn ourselves so we can NAME the stuck tasks
    # (topo_order raises without saying which). Edges naming phantom tasks —
    # the broken-edge findings below — are skipped so they cannot crash or
    # masquerade as cycles here.
    indeg = {tid: sum(1 for p in g.predecessors(tid) if p in g.tasks)
             for tid in g.tasks}
    queue = sorted(tid for tid, d in indeg.items() if d == 0)
    seen = 0
    while queue:
        tid = queue.pop()
        seen += 1
        for s in g.successors(tid):
            if s not in indeg:
                continue
            indeg[s] -= 1
            if indeg[s] == 0:
                queue.append(s)
    if seen != len(g.tasks):
        stuck = sorted(tid for tid, d in indeg.items() if d > 0)
        yield ctx.finding(stuck[0],
                          f"workflow graph contains a cycle through "
                          f"{len(stuck)} task(s): {stuck[:5]}")
    # duplicate writers / broken producer edges. TaskGraph.add_task rejects a
    # second producer, but a hand-mutated DataSpec (or a graph assembled from
    # parts) can still disagree — and a scheduler trusting d.producer would
    # then order a WAW race wrong.
    for tid, t in g.tasks.items():
        for out in t.outputs:
            d = g.data.get(out)
            if d is None:
                yield ctx.finding(out, f"task {tid!r} writes dataset "
                                       f"{out!r} that was never declared")
            elif d.producer != tid:
                yield ctx.finding(out,
                                  f"WAW race: dataset produced by both "
                                  f"{d.producer!r} and {tid!r} — no "
                                  f"happens-before edge orders the writes")
    for d in g.data.values():
        if d.producer is not None:
            p = g.tasks.get(d.producer)
            if p is None or d.name not in p.outputs:
                yield ctx.finding(d.name,
                                  f"missing happens-before edge: recorded "
                                  f"producer {d.producer!r} does not declare "
                                  f"{d.name!r} as an output")
        for c in d.consumers:
            t = g.tasks.get(c)
            if t is None or d.name not in t.inputs:
                yield ctx.finding(d.name,
                                  f"missing happens-before edge: consumer "
                                  f"{c!r} recorded on {d.name!r} does not "
                                  f"list it as an input")
    for tid, t in g.tasks.items():
        for name in t.inputs:
            d = g.data.get(name)
            if d is not None and tid not in d.consumers:
                yield ctx.finding(name,
                                  f"missing happens-before edge: task "
                                  f"{tid!r} reads {name!r} but is absent "
                                  f"from its consumer list — schedulers "
                                  f"walking consumers will miss the "
                                  f"dependency")


@_rule("missing-producer", Severity.WARNING,
       "consumed datasets with no producer and no @size hint")
def _missing_producer(ctx: LintContext) -> Iterator[Finding]:
    for d in ctx.graph.data.values():
        if d.is_external and d.consumers and d.size_bytes is None:
            yield ctx.finding(d.name,
                              f"consumed by {sorted(set(d.consumers))[:3]} "
                              f"but has no producer task and no @size hint "
                              f"— a missing producer or an empty external "
                              f"source (the compiler will guess 1 MiB)")


@_rule("dead-dataset", Severity.WARNING,
       "produced datasets nobody consumes and nobody marked as a sink")
def _dead_dataset(ctx: LintContext) -> Iterator[Finding]:
    for d in ctx.graph.data.values():
        if not d.is_external and not d.consumers and not d.xattr.get("sink"):
            yield ctx.finding(d.name,
                              f"produced by {d.producer!r} but never "
                              f"consumed and not marked as a workflow sink "
                              f"(graph.mark_sink) — wasted compute and tier "
                              f"occupancy")


# ------------------------------------------------------------- cost/capacity
def _finite_node_capacity(config: SimConfig | None) -> float | None:
    """Total per-node tier capacity when EVERY node tier is finite, else None
    (an unbounded tier means capacity can never be infeasible)."""
    if config is None or config.hierarchy is None:
        return None
    caps = [t.capacity_bytes for t in config.hierarchy.tiers]
    if not caps or any(c == float("inf") for c in caps):
        return None
    return float(sum(caps))


@_rule("capacity-infeasible", Severity.WARNING,
       "working sets that cannot fit the configured tier capacities")
def _capacity_infeasible(ctx: LintContext) -> Iterator[Finding]:
    wf, config = ctx.wf, ctx.config
    node_cap = _finite_node_capacity(config)
    if wf is None or node_cap is None:
        return
    gib = float(1 << 30)
    # per-task: a task whose inputs+outputs exceed one node's total finite
    # capacity is guaranteed to spill mid-task, whatever the scheduler does
    worst: list[tuple[float, str]] = []
    for tid in wf.topo:
        ws = wf.input_bytes(tid) + wf.output_bytes(tid)
        if ws > node_cap:
            worst.append((ws, tid))
    worst.sort(reverse=True)
    for ws, tid in worst[:5]:
        yield ctx.finding(tid,
                          f"working set {ws / gib:.2f} GiB exceeds one "
                          f"node's total tier capacity "
                          f"{node_cap / gib:.2f} GiB — forced PFS spill on "
                          f"every run")
    if len(worst) > 5:
        yield ctx.finding("…", f"{len(worst) - 5} more task(s) exceed the "
                               f"per-node capacity (showing the worst 5)")
    # cluster-level: sweep the compiled schedule (earliest_start + est
    # durations, unlimited workers) and find the peak bytes of live
    # intermediates; above the cluster's total finite capacity the store
    # MUST demote to the PFS no matter how placement shuffles replicas.
    assert config is not None
    finish = {tid: wf.earliest_start[tid] + wf.est_seconds[tid]
              for tid in wf.topo}
    events: list[tuple[float, float]] = []   # (time, +/- bytes)
    for d in wf.graph.data.values():
        if d.is_external or d.producer not in finish:
            continue
        born = finish[d.producer]
        ends = [finish[c] for c in d.consumers if c in finish]
        died = max(ends) if ends else max(finish.values())
        if died <= born:
            continue
        size = wf.sizes.get(d.name, 0.0)
        events.append((born, size))
        events.append((died, -size))
    live = peak = 0.0
    for _, delta in sorted(events):
        live += delta
        peak = max(peak, live)
    cluster_cap = node_cap * max(config.n_nodes, 1)
    if peak > cluster_cap:
        yield ctx.finding("cluster",
                          f"peak live intermediate bytes "
                          f"{peak / gib:.2f} GiB exceed the cluster's total "
                          f"tier capacity {cluster_cap / gib:.2f} GiB "
                          f"({config.n_nodes} nodes × "
                          f"{node_cap / gib:.2f} GiB) — capacity-pressure "
                          f"demotions to the PFS are unavoidable")


@_rule("durability-hazard", Severity.WARNING,
       "sole-copy intermediates exposed to injected failures")
def _durability_hazard(ctx: LintContext) -> Iterator[Finding]:
    wf, config = ctx.wf, ctx.config
    if wf is None or config is None or not config.failures:
        return
    if config.durability != "none":
        return
    at_risk = [d.name for d in wf.graph.data.values()
               if not d.is_external and d.consumers
               and wf.write_modes.get(d.name) != "around"]
    if not at_risk:
        return
    first_fail = min(t for t, _ in config.failures)
    yield ctx.finding("config",
                      f"durability='none' with {len(config.failures)} "
                      f"injected failure(s) (first at t={first_fail:g}s): "
                      f"{len(at_risk)} intermediate dataset(s) are "
                      f"sole-copy and non-durable — losing their node "
                      f"re-runs the producers (durability="
                      f"'fsync_on_barrier' bounds the exposure)")


# --------------------------------------------------------------- write modes
def _around_unsafe_reason(graph: TaskGraph, sizes: dict[str, float],
                          name: str) -> str | None:
    """None when honoring ``mode="around"`` for ``name`` is provably safe
    (the single consumer is predicted to be co-scheduled with the producer at
    put time — the LocalityScheduler binds a task to the node holding the
    strict majority of its input bytes); else a human-readable reason."""
    d = graph.data.get(name)
    if d is None:
        return "dataset not in the graph"
    if d.is_external:
        return "external datasets have no producing task to co-schedule with"
    if d.pinned_loc is not None:
        return ("an explicit placement pin overrides the write mode "
                "(the runtime ignores modes on pinned datasets)")
    if len(d.consumers) != 1:
        return (f"{len(d.consumers)} consumers — write-around keeps the only "
                f"copy on the PFS, so every non-co-scheduled reader pays a "
                f"remote fetch")
    consumer = graph.tasks.get(d.consumers[0])
    if consumer is None:
        return f"consumer {d.consumers[0]!r} is not a task in the graph"
    total = sum(sizes.get(n, 0.0) for n in consumer.inputs)
    from_producer = sum(sizes.get(n, 0.0) for n in consumer.inputs
                        if graph.data[n].producer == d.producer)
    if not (total > 0 and from_producer * 2 > total):
        return (f"producer {d.producer!r} supplies "
                f"{from_producer / total if total else 0.0:.0%} of consumer "
                f"{d.consumers[0]!r}'s input bytes — no strict majority, so "
                f"the consumer is not predicted to land on the producing "
                f"node at put time")
    return None


def safe_write_modes(wf: CompiledWorkflow) -> dict[str, str]:
    """The subset of ``wf.write_modes`` whose ``"around"`` pins the linter
    can prove safe to honor (consumer co-scheduled with producer at put
    time). This is the gate behind the simulator's
    ``honor_write_modes="auto"`` default — re-proving the compiler's pass-5
    condition here means a hand-edited or stale ``write_modes`` dict cannot
    smuggle an unsafe pin past the runtime."""
    out: dict[str, str] = {}
    for name, mode in wf.write_modes.items():
        if mode != "around":
            out[name] = mode
        elif _around_unsafe_reason(wf.graph, wf.sizes, name) is None:
            out[name] = mode
    return out


@_rule("unsafe-write-around", Severity.WARNING,
       "mode='around' pins whose consumer is not provably co-scheduled")
def _unsafe_write_around(ctx: LintContext) -> Iterator[Finding]:
    sizes = ctx.sizes()
    marked = {d.name for d in ctx.graph.data.values()
              if d.xattr.get("write_mode") == "around"}
    if ctx.wf is not None:
        marked.update(n for n, m in ctx.wf.write_modes.items()
                      if m == "around")
    for name in sorted(marked):
        reason = _around_unsafe_reason(ctx.graph, sizes, name)
        if reason is not None:
            yield ctx.finding(name, f"unsafe write-around pin: {reason}")


# ------------------------------------------------------------ cluster config
@_rule("unreachable-node", Severity.ERROR,
       "zero-bandwidth links or dead-weight nodes in the cluster config")
def _unreachable_node(ctx: LintContext) -> Iterator[Finding]:
    config = ctx.config
    if config is None:
        return
    hw, n = config.hw, config.n_nodes
    pods = (n + hw.nodes_per_pod - 1) // hw.nodes_per_pod if n else 0
    if n > 1 and hw.nodes_per_pod > 1 and hw.ici_gbps <= 0:
        yield ctx.finding("hw.ici_gbps",
                          "intra-pod link bandwidth is 0 — nodes in the same "
                          "pod cannot exchange data (and a fetch divides by "
                          "this bandwidth at runtime)")
    if pods > 1 and hw.dcn_gbps <= 0:
        yield ctx.finding("hw.dcn_gbps",
                          f"cross-pod bandwidth is 0 with {pods} pods — "
                          f"cross-pod placements are unreachable")
    has_external = any(d.is_external for d in ctx.graph.data.values())
    if hw.remote_tier_gbps <= 0 and has_external \
            and config.external_loc == "remote":
        yield ctx.finding("hw.remote_tier_gbps",
                          "remote/PFS bandwidth is 0 but external inputs "
                          "start on the remote tier — they can never be "
                          "staged in")
    for node, speed in sorted((config.speeds or {}).items()):
        if not 0 <= node < n:
            yield ctx.finding(f"node{node}",
                              f"speed override for node {node} is outside "
                              f"the cluster (n_nodes={n}) and silently "
                              f"ignored", severity=Severity.WARNING)
        elif speed <= 0:
            yield ctx.finding(f"node{node}",
                              f"node {node} has speed {speed:g} — any task "
                              f"placed there effectively never finishes",
                              severity=Severity.WARNING)
    # link-graph reachability: with an explicit topology a node is
    # unreachable when its NIC, its rack's uplink, or the PFS attachment it
    # depends on has zero bandwidth (link_gbps divides by these at runtime)
    topo = getattr(config, "topology", None)
    if topo is None:
        return
    if topo.n_nodes != n:
        yield ctx.finding("topology.n_nodes",
                          f"topology describes {topo.n_nodes} node(s) but "
                          f"the config runs {n} — the simulator refuses the "
                          f"mismatch")
    remote_externals = has_external and config.external_loc == "remote"
    for node in range(min(topo.n_nodes, n)):
        if topo.nic(node) <= 0:
            yield ctx.finding(f"node{node}",
                              f"node {node}'s NIC bandwidth is 0 — no path "
                              f"to any peer or to the PFS")
    for r in range(topo.n_racks):
        if topo.up(r) <= 0 and (topo.n_racks > 1 or remote_externals):
            yield ctx.finding(f"rack{r}",
                              f"rack {r}'s ToR uplink bandwidth is 0 — its "
                              f"nodes cannot reach other racks or the PFS")
    if topo.pfs_gbps <= 0 and remote_externals:
        yield ctx.finding("topology.pfs_gbps",
                          "PFS attachment bandwidth is 0 but external "
                          "inputs start on the remote tier — they can "
                          "never be staged in")


@_rule("oversubscribed-link", Severity.WARNING,
       "compiled transfer demand exceeding a shared link's capacity budget")
def _oversubscribed_link(ctx: LintContext) -> Iterator[Finding]:
    """Budget the compiled external stage-in plan against the shared links.

    Over the schedule's critical-path window, every byte staged in from the
    remote tier crosses the PFS attachment once and a ToR uplink once; when
    that demand exceeds ``capacity * critical_seconds * factor`` the link is
    the bottleneck no matter how the scheduler places tasks. ``factor``
    (default 1.0) comes from ``lint(..., params={"oversub-factor": ...})`` —
    raise it to only flag gross oversubscription."""
    wf, config = ctx.wf, ctx.config
    if wf is None or config is None:
        return
    topo = getattr(config, "topology", None)
    if topo is None or topo.flat:
        return
    crit = max((wf.earliest_start[t] + wf.est_seconds[t] for t in wf.topo),
               default=0.0)
    if crit <= 0.0:
        return
    ext_bytes = sum(wf.sizes.get(d.name, 0.0)
                    for d in ctx.graph.data.values() if d.is_external)
    if ext_bytes <= 0.0:
        return
    factor = float(ctx.params.get("oversub-factor", 1.0))
    gib = float(1 << 30)
    if config.external_loc == "remote" and topo.pfs_gbps > 0:
        budget = topo.pfs_gbps * crit * factor
        if ext_bytes > budget:
            yield ctx.finding(
                "pfs",
                f"remote stage-in plan moves {ext_bytes / gib:.2f} GiB "
                f"through the PFS link but its budget over the "
                f"{crit:.1f}s critical path is {budget / gib:.2f} GiB "
                f"(factor {factor:g}) — stage-in serializes behind the "
                f"PFS attachment")
    per_rack = ext_bytes / max(topo.n_racks, 1)
    for r in range(topo.n_racks):
        cap = topo.up_capacity_gbps[r]
        if cap <= 0 or cap == float("inf"):
            continue
        budget = cap * crit * factor
        if per_rack > budget:
            yield ctx.finding(
                f"rack{r}",
                f"stage-in plan pushes ~{per_rack / gib:.2f} GiB through "
                f"rack {r}'s uplink but its budget over the {crit:.1f}s "
                f"critical path is {budget / gib:.2f} GiB (capacity "
                f"{cap / 1e9:.2f} GB/s, factor {factor:g}) — the "
                f"oversubscribed uplink is the bottleneck")


@_rule("zero-capacity-tier", Severity.ERROR,
       "tiers that can hold nothing or have zero media bandwidth")
def _zero_capacity_tier(ctx: LintContext) -> Iterator[Finding]:
    config = ctx.config
    if config is None or config.hierarchy is None:
        return
    hier = config.hierarchy
    for spec in list(hier.tiers) + [hier.remote]:
        if spec.capacity_bytes <= 0:
            yield ctx.finding(spec.name,
                              f"tier {spec.name!r} has capacity "
                              f"{spec.capacity_bytes:g} bytes — nothing can "
                              f"be admitted; every put cascades straight "
                              f"past it")
        if spec.gbps <= 0:
            yield ctx.finding(spec.name,
                              f"tier {spec.name!r} has media bandwidth "
                              f"{spec.gbps:g} B/s — media_seconds divides "
                              f"by it at runtime")


@_rule("gapped-membership", Severity.WARNING,
       "join schedules that skip node ids, failures of never-members")
def _gapped_membership(ctx: LintContext) -> Iterator[Finding]:
    config = ctx.config
    if config is None:
        return
    cur_max = config.n_nodes
    for t, node in sorted(config.joins):
        if node > cur_max:
            yield ctx.finding(f"node{node}",
                              f"join of node {node} at t={t:g}s skips ids "
                              f"{cur_max}..{node - 1} — gapped growth marks "
                              f"the skipped ids failed (alive + failed must "
                              f"partition range(n_nodes)); renumber unless "
                              f"intentional")
        cur_max = max(cur_max, node + 1)
    for t, node in sorted(config.failures):
        admitted = node < config.n_nodes or any(
            tj <= t and nj >= node for tj, nj in config.joins)
        if not admitted:
            yield ctx.finding(f"node{node}",
                              f"failure of node {node} at t={t:g}s names a "
                              f"node never admitted to the cluster "
                              f"(n_nodes={config.n_nodes}, no earlier join "
                              f"covers it)", severity=Severity.ERROR)


# ------------------------------------------------------------------- driver
def lint(wf: CompiledWorkflow | TaskGraph, *,
         config: SimConfig | None = None, name: str = "workflow",
         rules: Iterable[str] | None = None,
         allowlist: "list[dict] | None" = None,
         params: dict | None = None) -> list[Finding]:
    """Run every registered rule (or the ``rules`` subset) over a workflow.

    ``wf`` may be a bare :class:`TaskGraph` (structural rules only) or a
    :class:`CompiledWorkflow` (adds the size/placement/cost rules).
    ``config`` unlocks the cluster/capacity/durability rules. Findings
    matching ``allowlist`` entries come back with ``suppressed=True``.
    ``params`` carries per-run rule knobs (e.g. ``{"oversub-factor": 2.0}``
    for the ``oversubscribed-link`` budget)."""
    if isinstance(wf, TaskGraph):
        graph, compiled = wf, None
    else:
        graph, compiled = wf.graph, wf
    ctx = LintContext(graph=graph, wf=compiled, config=config, name=name,
                      params=dict(params or {}))
    findings: list[Finding] = []
    for rid in (rules if rules is not None else RULES):
        r = RULES[rid]
        ctx._rule = r
        findings.extend(r.fn(ctx))
    order = {rid: i for i, rid in enumerate(RULES)}
    findings.sort(key=lambda f: (-int(f.severity), order.get(f.rule, 99),
                                 f.target))
    if allowlist:
        findings = apply_allowlist(findings, allowlist)
    return findings


def lint_graph(graph: TaskGraph, **kw) -> list[Finding]:
    """Structural lint of an uncompiled graph (alias of :func:`lint`)."""
    return lint(graph, **kw)


# -------------------------------------------------------------- suppressions
def default_allowlist_path() -> str:
    """``analysis_allowlist.json`` at the repo root (three levels above this
    package), where the benchmarks' trend allow-list convention lives too."""
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "analysis_allowlist.json")


def load_allowlist(path: str | None = None) -> list[dict]:
    """Reasoned suppressions: ``[{"rule", "target", "reason"}, ...]``.
    ``target`` is an fnmatch pattern over ``"<workflow>:<target>"``. A
    missing file is an empty list; an entry without a non-empty ``reason``
    is a :class:`ValueError` (same contract as the trend allow-list)."""
    path = path or default_allowlist_path()
    if not os.path.exists(path):
        return []
    with open(path) as f:
        entries = json.load(f)
    for e in entries:
        if not e.get("reason", "").strip():
            raise ValueError(f"analysis allow-list entry {e.get('rule')!r}:"
                             f"{e.get('target')!r} has no reason")
        if not e.get("rule") or not e.get("target"):
            raise ValueError(f"analysis allow-list entry needs rule and "
                             f"target: {e!r}")
    return entries


def apply_allowlist(findings: list[Finding],
                    entries: list[dict]) -> list[Finding]:
    """Mark findings matching an allow-list entry as suppressed (carrying the
    entry's reason). Unmatched findings pass through untouched."""
    out: list[Finding] = []
    for f in findings:
        key = f"{f.workflow}:{f.target}"
        hit = next((e for e in entries
                    if fnmatch.fnmatchcase(f.rule, e["rule"])
                    and fnmatch.fnmatchcase(key, e["target"])), None)
        if hit is not None:
            f = dataclasses.replace(f, suppressed=True, reason=hit["reason"])
        out.append(f)
    return out


def gate(findings: list[Finding],
         threshold: Severity = Severity.WARNING) -> list[Finding]:
    """The CI contract: findings that should fail a build — unsuppressed and
    at least ``threshold`` severe."""
    return [f for f in findings
            if not f.suppressed and f.severity >= threshold]
