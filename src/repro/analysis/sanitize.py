"""Runtime invariant sanitizer (PR 9 tentpole, part b).

PR 6/PR 8 turned the hot paths into event-driven incremental caches — the
scheduler's placement mirror and move-cost term cache, the simulator's
pending-candidate index, the cluster's free/alive views, the store's
per-(node,tier) usage and pin refcounts — that are only *test-pinned* equal
to from-scratch rebuilds. A drift introduced by any future PR would silently
corrupt scheduling decisions long before an equivalence test notices. This
module cross-checks every incremental structure against a from-scratch
rebuild of the same fact, raising a structured :class:`SanitizerError` that
names the first divergent entry.

Opt-in (the rebuilds are O(cluster) per checkpoint): set ``sanitize=True`` on
:class:`~repro.core.config.SimConfig` / ``ServingConfig``, or export
``REPRO_SANITIZE=1`` (``benchmarks/run.py --sanitize`` does exactly that).
Checkpoint frequency for the simulator is ``SimConfig.sanitize_every`` (every
N-th event) — the invariants hold at *every* event boundary, the knob only
trades coverage for speed.

Like the linter, this module never imports the simulator or the serving
stack; callers hand their structures in. Checks degrade to no-ops when the
structure they audit is absent (e.g. a scheduler with no attached store has
no mirror to drift).
"""

from __future__ import annotations

import math
import os
from collections import Counter
from typing import Any, Iterable, Mapping

from repro.core.locstore import REMOTE_TIER, LocStore

__all__ = ["SanitizerError", "env_enabled", "check_placement_mirror",
           "check_membership", "check_tier_usage", "check_pin_conservation",
           "check_candidate_index", "check_ledger", "check_term_cache",
           "check_proactive", "check_engine", "check_router",
           "check_link_rows", "check_link_paths"]


class SanitizerError(AssertionError):
    """An incremental structure diverged from its from-scratch rebuild.

    Carries the failing ``check``, the first divergent ``key`` (entries are
    visited in sorted order, so the report is deterministic), and the
    ``expected`` (rebuilt) vs ``actual`` (incremental) values."""

    def __init__(self, check: str, key: Any, expected: Any, actual: Any):
        self.check = check
        self.key = key
        self.expected = expected
        self.actual = actual
        super().__init__(
            f"sanitizer[{check}] divergent entry {key!r}: "
            f"rebuild says {expected!r}, incremental state says {actual!r}")


def env_enabled() -> bool:
    """``REPRO_SANITIZE`` truthiness — the process-wide opt-in used when a
    config object leaves ``sanitize`` unset."""
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() \
        not in ("", "0", "false", "no", "off")


def _fail(check: str, key: Any, expected: Any, actual: Any) -> None:
    raise SanitizerError(check, key, expected, actual)


def _close(a: float, b: float) -> bool:
    # float counters accumulate chronologically; rebuilds sum in ledger
    # order — allow for the differing association, nothing more
    return math.isclose(a, b, rel_tol=1e-6, abs_tol=1e-6)


# ------------------------------------------------------------------- storage
def check_membership(store: LocStore, cluster: Any = None) -> None:
    """``alive + failed == range(n_nodes)`` — in the store AND (when given)
    the SimCluster, including their materialized sorted caches."""
    alive = list(store._alive)
    failed = set(store._failed_nodes)
    if alive != sorted(alive):
        _fail("membership", "store._alive", sorted(alive), alive)
    if set(alive) & failed:
        _fail("membership", "alive∩failed", set(),
              sorted(set(alive) & failed))
    want = set(range(store.n_nodes))
    got = set(alive) | failed
    if got != want:
        missing = sorted(want - got) + sorted(got - want)
        _fail("membership", f"node{missing[0]}",
              "alive + failed == range(n_nodes)",
              f"n_nodes={store.n_nodes} alive={alive} failed={sorted(failed)}")
    if cluster is None:
        return
    if cluster.n_nodes != store.n_nodes:
        _fail("membership", "cluster.n_nodes", store.n_nodes,
              cluster.n_nodes)
    if set(cluster.failed) != failed:
        diff = sorted(set(cluster.failed) ^ failed)
        _fail("membership", f"node{diff[0]}",
              f"store failed {sorted(failed)}",
              f"cluster failed {sorted(cluster.failed)}")
    free_cache = getattr(cluster, "_free_cache", None)
    if free_cache is not None:
        want_free = sorted(cluster.free - cluster.failed)
        if free_cache != want_free:
            _fail("membership", "cluster._free_cache", want_free, free_cache)
    alive_cache = getattr(cluster, "_alive_cache", None)
    if alive_cache is not None:
        want_alive = [n for n in range(cluster.n_nodes)
                      if n not in cluster.failed]
        if alive_cache != want_alive:
            _fail("membership", "cluster._alive_cache", want_alive,
                  alive_cache)


def check_tier_usage(store: LocStore) -> None:
    """Per-(node, tier) byte usage vs a rebuild from the residency map —
    the O(1) ``tier_used`` fast path must agree with what is actually
    resident (``_drop_replica`` clamps at zero, so a leak shows up here as
    incremental > rebuilt)."""
    want: dict[tuple[int, str], float] = {}
    for name, res in store._residency.items():
        size = store._sizes.get(name, 0.0)
        for node, tier in res.items():
            if node == REMOTE_TIER:     # PFS copies are not tier-accounted
                continue
            key = (node, tier)
            want[key] = want.get(key, 0.0) + size
    for key in sorted(set(want) | set(store._usage)):
        w = want.get(key, 0.0)
        g = store._usage.get(key, 0.0)
        if abs(w - g) > max(1.0, 1e-9 * max(w, g)):
            _fail("tier-usage", key, w, g)


def check_pin_conservation(store: LocStore,
                           task_pins: Mapping[str, Iterable[tuple[str, int]]],
                           ) -> None:
    """Every positive pin refcount in the store is owed to exactly that many
    live prefetch holds in the simulator's ``_task_pins`` (and vice versa).
    A leak here means evict-protection outlives the task that asked for it —
    or a stale unpin released somebody else's pin."""
    got = Counter({k: v for k, v in store._pins.items() if v > 0})
    want: Counter = Counter()
    for pins in task_pins.values():
        want.update(tuple(p) for p in pins)
    if got != want:
        diffs = sorted(set(got) | set(want),
                       key=lambda k: (k[0], k[1]))
        for key in diffs:
            if got.get(key, 0) != want.get(key, 0):
                _fail("pin-conservation", key, want.get(key, 0),
                      got.get(key, 0))


def check_ledger(store: LocStore) -> None:
    """Scalar movement counters vs a full recomputation from the Transfer
    ledger (the PR 3 cross-check, now runnable at every checkpoint). Mirrors
    ``tests/test_sim_accounting.recompute_from_transfers`` exactly."""
    spill_kinds = ("demote", "spill", "writeback", "writearound")
    fetches = [t for t in store.transfers if t.kind == "fetch"]
    migrates = [t for t in store.transfers if t.kind == "migrate"]
    # every PFS-bound write (spills AND durability fsyncs) lands in
    # bytes_moved/remote_bytes via _record_pfs_write
    spills = [t for t in store.transfers
              if t.kind in spill_kinds + ("fsync",) and t.dst == REMOTE_TIER]
    demotes = [t for t in store.transfers if t.kind == "demote"]
    writebacks = [t for t in store.transfers if t.kind == "writeback"]
    fsyncs = [t for t in store.transfers if t.kind == "fsync"]
    want: dict[str, float] = {
        "bytes_local": sum(t.nbytes for t in fetches if t.local),
        "bytes_moved": (sum(t.nbytes for t in fetches if not t.local)
                        + sum(t.nbytes for t in migrates)
                        + sum(t.nbytes for t in spills)),
        "remote_bytes": (sum(t.nbytes for t in fetches if not t.local
                             and (t.src == REMOTE_TIER
                                  or t.dst == REMOTE_TIER))
                         + sum(t.nbytes for t in migrates
                               if t.src == REMOTE_TIER
                               or t.dst == REMOTE_TIER)
                         + sum(t.nbytes for t in spills)),
        "bytes_demoted": (sum(t.nbytes for t in demotes)
                          + sum(t.nbytes for t in writebacks)),
        "writeback_bytes": sum(t.nbytes for t in writebacks),
        "fsync_bytes": sum(t.nbytes for t in fsyncs),
    }
    rep = store.movement_report()
    for key in sorted(want):
        if not _close(rep[key], want[key]):
            _fail("ledger", key, want[key], rep[key])
    for key, value in (("demotions", len(demotes) + len(writebacks)),
                       ("writebacks", len(writebacks)),
                       ("fsyncs", len(fsyncs))):
        if int(rep[key]) != value:
            _fail("ledger", key, value, int(rep[key]))
    tier_reads: dict[str, float] = {}
    for t in fetches:
        tier_reads[t.src_tier] = tier_reads.get(t.src_tier, 0.0) + t.nbytes
    for tier in sorted(set(tier_reads) | set(store.tier_reads)):
        if not _close(tier_reads.get(tier, 0.0),
                      store.tier_reads.get(tier, 0.0)):
            _fail("ledger", f"tier_reads[{tier}]",
                  tier_reads.get(tier, 0.0), store.tier_reads.get(tier, 0.0))


# ----------------------------------------------------------------- scheduler
def check_placement_mirror(sched: Any, store: LocStore) -> None:
    """Scheduler's event-maintained placement mirror vs
    ``LocationService.lookup`` for every known dataset, both directions."""
    if not getattr(sched, "_indexed", False) or sched._store is None:
        return
    mirror = sched._placements
    truth_names = store.loc.names()
    for name in sorted(truth_names):
        truth = store.loc.lookup(name)
        got = mirror.get(name)
        want_key = (truth.nodes, truth.tier, truth.tiers)
        got_key = None if got is None else (got.nodes, got.tier, got.tiers)
        if got_key != want_key:
            _fail("placement-mirror", name, want_key, got_key)
    for name in sorted(set(mirror) - set(truth_names)):
        _fail("placement-mirror", name, None,
              (mirror[name].nodes, mirror[name].tier, mirror[name].tiers))


def check_term_cache(sched: Any, cluster: Any) -> None:
    """Every cached move-cost term vs the exact arithmetic ``move_seconds``
    would run today. Terms are only cached for *placed* inputs, so the
    comparison is == (identical code path), not approx."""
    if not getattr(sched, "_indexed", False) or sched._store is None:
        return
    dst_tier = getattr(cluster, "top_tier", lambda: "hbm")()
    for name in sorted(sched._term_cache):
        p = sched._placements.get(name)
        if p is None:
            _fail("term-cache", name, "no cached terms for unplaced input",
                  sorted(sched._term_cache[name]))
        size = sched.wf.sizes.get(name, 0.0)
        for node in sorted(sched._term_cache[name]):
            if p.resident_on(node):
                want = sched._tier_seconds(cluster, p.tier_on(node), size)
            else:
                src = p.real_loc
                want = sched._one_term(cluster, size,
                                       cluster.link_gbps(src, node),
                                       p.tier_on(src), dst_tier)
            got = sched._term_cache[name][node]
            if got != want:
                _fail("term-cache", (name, node), want, got)


def check_link_rows(cluster: Any) -> None:
    """Every cached link-bandwidth row (and its uniform-collapse marker) vs
    a from-scratch rebuild through ``hw.link_gbps`` — with a topology
    attached the rows carry real path bandwidths, and the elastic-growth
    in-place row extension (SimCluster.join) is exactly the kind of
    incremental update that can drift. The divergent key is ``(src, dst)``
    (or ``(src, "uniform")`` for the collapse marker)."""
    rows = getattr(cluster, "_link_rows", None)
    if not rows:
        return
    hw = cluster.hw
    for src in sorted(rows):
        row, uniform = rows[src]
        if len(row) != cluster.n_nodes:
            _fail("link-row", (src, "len"), cluster.n_nodes, len(row))
        for dst in range(cluster.n_nodes):
            want = hw.link_gbps(src, dst)
            if row[dst] != want:
                _fail("link-row", (src, dst), want, row[dst])
        vals = set(row[:src] + row[src + 1:]
                   if 0 <= src < cluster.n_nodes else row)
        want_uniform = vals.pop() if len(vals) == 1 else None
        if uniform != want_uniform:
            _fail("link-row", (src, "uniform"), want_uniform, uniform)


def check_link_paths(path_cache: Mapping | None, topo: Any) -> None:
    """Every memoized (src, dst) -> lane-key path vs a fresh
    ``topo.links()`` walk of the link graph — the path table feeds the
    per-link lane charging, so a stale entry would mischarge contention.
    No-op without a real topology (flat runs never populate the cache)."""
    if topo is None:
        if path_cache:
            _fail("link-path", sorted(path_cache)[0],
                  "empty path cache without a topology",
                  path_cache[sorted(path_cache)[0]])
        return
    for key in sorted(path_cache or {}):
        want = topo.links(*key)
        got = path_cache[key]
        if got != want:
            _fail("link-path", key, want, got)


def check_proactive(sched: Any, cluster: Any) -> None:
    """ProactiveScheduler extras: no preassignment to a dead/unknown node,
    the per-task placed-input counter vs a recount over the mirror, and no
    prefetch marker for a dataset the store no longer knows. Prefetch
    markers on nodes the dataset has not REACHED yet are legal (the marker
    is set when the transfer is emitted, not when it lands)."""
    preassignment = getattr(sched, "preassignment", None)
    if preassignment is None:
        return
    for tid in sorted(preassignment):
        node = preassignment[tid]
        if node in cluster.failed or not 0 <= node < cluster.n_nodes:
            _fail("proactive", tid, "preassignment to a live node",
                  f"node {node} (failed={node in cluster.failed})")
    if not getattr(sched, "_indexed", False) or sched._store is None:
        return
    mirror = sched._placements
    for tid in sorted(sched.wf.graph.tasks):
        t = sched.wf.graph.tasks[tid]
        want = sum(1 for n in t.inputs if n in mirror)
        got = sched._avail.get(tid, 0)
        if got != want:
            _fail("proactive", f"_avail[{tid}]", want, got)
    for name in sorted(sched._prefetched):
        if sched._prefetched[name] and name not in mirror:
            _fail("proactive", f"_prefetched[{name}]",
                  "markers only for datasets in the mirror",
                  sorted(sched._prefetched[name]))


# ----------------------------------------------------------------- simulator
def check_candidate_index(*, state: Mapping[str, str],
                          avail_count: Mapping[str, int],
                          cand_list: list, cand_set: set,
                          exists_mirror: set, order: Mapping[str, int],
                          store: LocStore, graph: Any) -> None:
    """The simulator's pending-candidate index (PR 6) vs a full rescan:
    the existence mirror, the per-task materialized-input counters, and the
    sorted candidate list/set must all match what the store actually holds."""
    truth = set(store.loc.names())
    for name in sorted(truth ^ exists_mirror):
        _fail("candidate-index", f"exists[{name}]",
              name in truth, name in exists_mirror)
    want_avail = {tid: sum(1 for n in t.inputs if n in truth)
                  for tid, t in graph.tasks.items()}
    for tid in sorted(want_avail):
        got = avail_count.get(tid, 0)
        if got != want_avail[tid]:
            _fail("candidate-index", f"avail[{tid}]", want_avail[tid], got)
    want_set = {tid for tid in graph.tasks
                if state.get(tid) == "pending" and want_avail[tid] > 0}
    for tid in sorted(want_set ^ cand_set):
        _fail("candidate-index", f"candidate[{tid}]",
              tid in want_set, tid in cand_set)
    want_list = sorted((order[tid], tid) for tid in want_set)
    if cand_list != want_list:
        i = next(i for i, (a, b) in enumerate(
            zip(cand_list + [None], want_list + [None])) if a != b)
        _fail("candidate-index", f"cand_list[{i}]",
              want_list[i] if i < len(want_list) else None,
              cand_list[i] if i < len(cand_list) else None)


# ------------------------------------------------------------------- serving
def check_engine(engine: Any) -> None:
    """Slot bookkeeping: ``_slotted`` is exactly the slot-holding sessions,
    used and free slots partition ``range(max_batch)``, and every slotted
    session still has its KV placeholder in the store."""
    want_slotted = {sid: s for sid, s in engine.sessions.items()
                    if s.slot is not None}
    for sid in sorted(set(want_slotted) ^ set(engine._slotted)):
        _fail("engine-slots", f"session{sid}",
              sid in want_slotted, sid in engine._slotted)
    used = [s.slot for s in engine._slotted.values()]
    free = list(engine._free_slots)
    if len(set(used)) != len(used):
        dup = sorted(s for s in used if used.count(s) > 1)
        _fail("engine-slots", f"slot{dup[0]}", "one session per slot",
              f"{used.count(dup[0])} sessions share it")
    overlap = set(used) & set(free)
    if overlap:
        _fail("engine-slots", f"slot{sorted(overlap)[0]}",
              "slot is used xor free", "both used and free")
    want_all = set(range(engine.max_batch))
    got_all = set(used) | set(free)
    if got_all != want_all or len(free) != len(set(free)):
        _fail("engine-slots", "partition", sorted(want_all),
              f"used={sorted(used)} free={sorted(free)}")
    if engine.store is not None:
        from repro.serve.engine import _cache_name
        for sid in sorted(engine._slotted):
            if not engine.store.exists(_cache_name(sid)):
                _fail("engine-slots", f"kv[{sid}]",
                      "placeholder replica for every slotted session",
                      "missing from store")


def check_router(router: Any) -> None:
    """Failover bookkeeping: a deferred (unhomed) session must not
    simultaneously be registered live on a surviving engine."""
    for sid in sorted(getattr(router, "_unhomed", {})):
        for node in sorted(router.engines):
            if sid in router.engines[node].sessions:
                _fail("router", f"session{sid}",
                      "unhomed sessions live nowhere",
                      f"registered on engine at node {node}")
    for node in sorted(router.engines):
        check_engine(router.engines[node])
