"""``python -m repro.analysis`` — lint every built-in workload.

Compiles each workload in ``repro.core.workloads`` against ``HPC_CLUSTER``
and lints it with a representative ``SimConfig``, printing every finding
(suppressed ones with their allow-list reason). Exits non-zero when any
unsuppressed finding at WARNING or above remains — the CI gate.
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.lint import (RULES, Severity, gate, lint,
                                 load_allowlist)
from repro.core.config import SimConfig
from repro.core.wfcompiler import HPC_CLUSTER, compile_workflow
from repro.core.workloads import (fig2_workflow, mapreduce_workflow,
                                  montage_workflow, pipeline_chain_workflow,
                                  random_layered_workflow,
                                  serving_session_workflow,
                                  training_epoch_workflow)

BUILTINS = {
    "fig2": lambda: fig2_workflow(),
    "mapreduce": lambda: mapreduce_workflow(),
    "montage": lambda: montage_workflow(),
    "random_layered": lambda: random_layered_workflow(seed=0),
    "serving_session": lambda: serving_session_workflow(),
    "pipeline_chain": lambda: pipeline_chain_workflow(),
    "training_epoch": lambda: training_epoch_workflow(),
}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__)
    ap.add_argument("--allowlist", default=None,
                    help="path to analysis_allowlist.json "
                         "(default: repo root)")
    ap.add_argument("--workload", action="append", choices=sorted(BUILTINS),
                    help="lint only these built-ins (default: all)")
    ap.add_argument("--fail-on", default="WARNING",
                    choices=[s.name for s in Severity],
                    help="minimum severity that fails the gate")
    args = ap.parse_args(argv)

    allowlist = load_allowlist(args.allowlist)
    threshold = Severity[args.fail_on]
    config = SimConfig(n_nodes=8, hw=HPC_CLUSTER)
    names = args.workload or sorted(BUILTINS)
    n_findings = 0
    failing = []
    for name in names:
        wf = compile_workflow(BUILTINS[name](), HPC_CLUSTER)
        findings = lint(wf, config=config, name=name, allowlist=allowlist)
        n_findings += len(findings)
        for f in findings:
            print(f)
        failing.extend(gate(findings, threshold))
    print(f"{len(names)} workload(s) linted, {len(RULES)} rule(s), "
          f"{n_findings} finding(s), "
          f"{len(failing)} unsuppressed >= {threshold}")
    return 1 if failing else 0


if __name__ == "__main__":
    sys.exit(main())
