"""Cross-layer static analysis + runtime invariant sanitizer (PR 9).

``repro.analysis.lint`` proves workflow/config properties before execution
(races, capacity infeasibility, durability hazards, unsafe write-around
pins, cluster-config mistakes); ``repro.analysis.sanitize`` cross-checks the
runtime's incremental caches against from-scratch rebuilds at checkpoints.
``python -m repro.analysis`` lints every built-in workload (the CI gate).
"""

from repro.analysis.lint import (Finding, Rule, RULES, Severity,
                                 apply_allowlist, gate, lint, lint_graph,
                                 load_allowlist, safe_write_modes)
from repro.analysis.sanitize import SanitizerError

__all__ = ["Finding", "Rule", "RULES", "Severity", "apply_allowlist",
           "gate", "lint", "lint_graph", "load_allowlist",
           "safe_write_modes", "SanitizerError"]
