"""Shims that present the jax>=0.6 API surface on the pinned jax 0.4.x.

The codebase (and its test suite) is written against the current public jax
API; two pieces of it moved after 0.4.37:

  * ``jax.shard_map`` — still lives at ``jax.experimental.shard_map.shard_map``
    and takes ``check_rep`` instead of ``check_vma``;
  * ``jax.sharding.AbstractMesh(axis_sizes, axis_names)`` — the 0.4.x
    constructor wants a single ``((name, size), ...)`` tuple.

Importing :mod:`repro` installs these adapters exactly once. Both adapters
return the *real* jax objects, so everything downstream (isinstance checks
inside jax, lowering, tree flattening) behaves identically.
"""

from __future__ import annotations

import functools

import jax
import jax.sharding as _jshard


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                  check_rep=None, **kwargs):
        if check_rep is None:
            # check_vma is the post-0.6 name for check_rep; default False —
            # the replication checker predates several collectives we use.
            check_rep = bool(check_vma) if check_vma is not None else False
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep, **kwargs)

    jax.shard_map = shard_map


def _install_abstract_mesh() -> None:
    _AbstractMesh = _jshard.AbstractMesh
    try:
        _AbstractMesh((1,), ("x",))
        return                      # modern signature already supported
    except (TypeError, ValueError):
        pass

    class AbstractMesh(_AbstractMesh):
        """0.4.x AbstractMesh accepting the modern (sizes, names) signature.

        A real subclass, so isinstance checks against either name hold."""

        def __init__(self, axis_sizes, axis_names=None, **kwargs):
            if axis_names is not None:
                axis_sizes = tuple(zip(axis_names, axis_sizes))
            super().__init__(axis_sizes, **kwargs)

    _jshard.AbstractMesh = AbstractMesh


def _install_pallas_compiler_params() -> None:
    try:
        import jax.experimental.pallas.tpu as pltpu
    except ImportError:                      # pallas not built on this platform
        return
    if not hasattr(pltpu, "CompilerParams") and \
            hasattr(pltpu, "TPUCompilerParams"):
        pltpu.CompilerParams = pltpu.TPUCompilerParams


def install() -> None:
    _install_shard_map()
    _install_abstract_mesh()
    _install_pallas_compiler_params()
