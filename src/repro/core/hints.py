"""Hint annotations — the paper's Swift/T ``@`` language extensions.

The paper (§B, "Hint-Assist Workflow Compiler") adds four annotations to the
Swift/T language so the compiler can attach "rich" metadata to the task DAG:

  ``@size``                 size of an existing (external-input) file
  ``@task``                 key task parameters (process count)
  ``@compute-complexity``   computation cost as a function of input size
                            (e.g. ``@compute-complexity=@input`` == linear)
  ``@input-output-ratio``   output size as a function of input size

We reproduce these as Python-level hints. ``@compute-complexity`` is expressed
as a :class:`Complexity` — either one of the named growth laws from the paper's
examples (``const``/``linear``/``nlogn``/``quadratic``) scaled by a
``flops_per_byte`` coefficient, or an arbitrary callable ``bytes -> flops``.

Nothing in this module touches JAX: hints are pure static metadata consumed by
:mod:`repro.core.wfcompiler`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Union

__all__ = [
    "Complexity",
    "TaskHints",
    "task",
    "size_hint",
    "CONST",
    "LINEAR",
    "NLOGN",
    "QUADRATIC",
]


@dataclasses.dataclass(frozen=True)
class Complexity:
    """``@compute-complexity`` — estimated FLOPs as a function of input bytes.

    ``law`` is one of ``const|linear|nlogn|quadratic`` or ``custom`` (then
    ``fn`` must be given). ``flops_per_byte`` scales the law: e.g. an FFT-ish
    task would be ``Complexity("nlogn", flops_per_byte=5.0)``.
    """

    law: str = "linear"
    flops_per_byte: float = 1.0
    fn: Callable[[float], float] | None = None

    def flops(self, input_bytes: float) -> float:
        b = max(float(input_bytes), 0.0)
        if self.fn is not None:
            return float(self.fn(b))
        if self.law == "const":
            return self.flops_per_byte
        if self.law == "linear":
            return self.flops_per_byte * b
        if self.law == "nlogn":
            return self.flops_per_byte * b * math.log2(b + 2.0)
        if self.law == "quadratic":
            return self.flops_per_byte * b * b
        raise ValueError(f"unknown complexity law {self.law!r}")


CONST = Complexity("const")
LINEAR = Complexity("linear")
NLOGN = Complexity("nlogn")
QUADRATIC = Complexity("quadratic")

ComplexityLike = Union[Complexity, str, float, Callable[[float], float]]


def _as_complexity(c: ComplexityLike) -> Complexity:
    if isinstance(c, Complexity):
        return c
    if isinstance(c, str):
        return Complexity(c)
    if callable(c):
        return Complexity("custom", fn=c)
    # a bare number means "linear with this flops/byte coefficient"
    return Complexity("linear", flops_per_byte=float(c))


@dataclasses.dataclass(frozen=True)
class TaskHints:
    """The paper's ``@task`` / ``@compute-complexity`` / ``@input-output-ratio``
    bundle attached to one task.

    ``io_ratio`` maps *output name -> output_bytes / total_input_bytes*; a
    single float applies to every output. ``procs`` is the paper's ``@task``
    process-count hint. ``est_seconds`` lets the runtime override the static
    estimate once a task has actually run (the compiler estimate is used until
    then — exactly the paper's compiler/runtime split).
    """

    procs: int = 1
    compute: Complexity = LINEAR
    io_ratio: Union[float, Mapping[str, float]] = 1.0
    est_seconds: float | None = None

    def ratio_for(self, output_name: str) -> float:
        if isinstance(self.io_ratio, Mapping):
            return float(self.io_ratio.get(output_name, 1.0))
        return float(self.io_ratio)


def task(
    *,
    procs: int = 1,
    compute: ComplexityLike = LINEAR,
    io_ratio: Union[float, Mapping[str, float]] = 1.0,
    est_seconds: float | None = None,
) -> TaskHints:
    """Build a :class:`TaskHints` — spelled like the paper's ``@task(...)``.

    Example (paper Fig. 2 style)::

        hints = task(procs=4, compute="linear", io_ratio=0.25)
    """
    return TaskHints(
        procs=int(procs),
        compute=_as_complexity(compute),
        io_ratio=io_ratio,
        est_seconds=est_seconds,
    )


def size_hint(num_bytes: float) -> float:
    """``@size`` — size of an existing external input, in bytes."""
    if num_bytes < 0:
        raise ValueError("@size must be non-negative")
    return float(num_bytes)
