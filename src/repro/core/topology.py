"""Cluster topology — heterogeneous nodes and an explicit per-link network.

The paper's cross-layer argument needs the hardware layer to expose *where*
data sits relative to compute. A flat :class:`~repro.core.wfcompiler.
HardwareModel` collapses that to a boolean (same pod / different pod); this
module replaces it with an explicit node -> ToR -> spine link graph plus
per-node profiles (mixed-generation compute speeds, per-node NIC bandwidth,
spot-class markers), mirroring the Helix cluster simulator's mixed-machine
model (SNIPPETS.md Snippet 2):

* every transfer has a *path* (source NIC, the racks' ToR uplinks when it
  crosses the spine, the PFS attachment for remote-tier traffic);
* ``link_gbps`` is the **max-utilized link on the path** — the minimum
  capacity along it, with each ToR uplink contributing its fair-share
  per-flow bandwidth (``nic / oversubscription``: what a flow can count on
  when the rack's offered load saturates the uplink);
* the simulator turns each link into a transfer *lane*, so concurrent
  transfers through a shared uplink genuinely contend (per-NIC lanes are the
  degenerate single-link case).

**Flat-equivalence guarantee.** ``ClusterTopology.one_switch(n)`` is the
degenerate topology: every node on one ToR, infinite-capacity links, and
``flat=True``. A flat topology contributes *structure only* — the
HardwareModel keeps its scalar ICI/DCN/remote link model and the simulator
keeps its per-NIC lanes, so a flat-topology run is bit-identical to a
scalar-HardwareModel run (pinned by tests/test_sched_equivalence.py).

This module is imported by ``wfcompiler`` (the HardwareModel carries an
optional topology) and must not import any other core module.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = ["NodeProfile", "ClusterTopology"]

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class NodeProfile:
    """Per-node hardware profile (mixed-generation / spot-class clusters).

    ``speed`` is the node's relative compute throughput (1.0 = nominal —
    feeds ``ClusterView.worker_speed`` and the speed-aware schedulers).
    ``nic_gbps`` overrides the topology's default NIC capacity for this node
    (an older generation's slower network). ``cls`` tags the node's class:
    ``"spot"`` nodes are preemption-prone — the predictive re-replication
    trigger treats their sole-copy data as at-risk.
    """

    speed: float = 1.0
    cls: str = "standard"            # "standard" | "spot" | generation tag
    nic_gbps: float | None = None


@dataclasses.dataclass(frozen=True)
class ClusterTopology:
    """Node -> ToR -> spine link graph with per-node profiles.

    ``rack_of[node]`` assigns each node to a ToR switch; each rack has one
    uplink to the spine, and the PFS hangs off the spine behind its own
    link. ``up_gbps[r]`` is the *effective per-flow* bandwidth through rack
    ``r``'s uplink (``nic / oversubscription`` for :meth:`two_tier`);
    ``up_capacity_gbps[r]`` is the uplink's nominal capacity (what the
    ``oversubscribed-link`` lint rule budgets against).

    Nodes that join beyond the configured size (elastic growth) fall back to
    round-robin rack assignment and the default NIC/profile, so a frozen
    topology keeps answering for a growing cluster.
    """

    n_nodes: int
    rack_of: tuple[int, ...]
    nic_gbps: tuple[float, ...]
    up_gbps: tuple[float, ...]              # per-flow share through each uplink
    up_capacity_gbps: tuple[float, ...]     # nominal uplink capacity
    oversub: tuple[float, ...]              # nominal oversubscription per rack
    pfs_gbps: float = 0.5e9
    default_nic_gbps: float = 1.25e9
    profiles: tuple[NodeProfile, ...] = ()
    flat: bool = False

    def __post_init__(self) -> None:
        if self.n_nodes <= 0:
            raise ValueError(f"n_nodes must be positive, got {self.n_nodes}")
        if len(self.rack_of) != self.n_nodes:
            raise ValueError(f"rack_of covers {len(self.rack_of)} nodes, "
                             f"n_nodes={self.n_nodes}")
        if len(self.nic_gbps) != self.n_nodes:
            raise ValueError(f"nic_gbps covers {len(self.nic_gbps)} nodes, "
                             f"n_nodes={self.n_nodes}")
        n_racks = len(self.up_gbps)
        if len(self.up_capacity_gbps) != n_racks \
                or len(self.oversub) != n_racks:
            raise ValueError("up_gbps / up_capacity_gbps / oversub must all "
                             "cover the same rack count")
        if self.profiles and len(self.profiles) != self.n_nodes:
            raise ValueError(f"profiles covers {len(self.profiles)} nodes, "
                             f"n_nodes={self.n_nodes}")
        bad = [r for r in self.rack_of if not 0 <= r < n_racks]
        if bad:
            raise ValueError(f"rack id {bad[0]} out of range for "
                             f"{n_racks} rack(s)")

    # ------------------------------------------------------------- builders
    @classmethod
    def one_switch(cls, n_nodes: int, *,
                   profiles: Sequence[NodeProfile] = ()) -> "ClusterTopology":
        """The degenerate flat topology: one ToR, infinite links.

        ``flat=True`` means the HardwareModel keeps its scalar link model and
        the simulator keeps its legacy per-NIC lanes — a run under this
        topology is bit-identical to a run without one (the equivalence
        suite pins it). Profiles still apply (per-node speeds)."""
        return cls(n_nodes=n_nodes, rack_of=(0,) * n_nodes,
                   nic_gbps=(_INF,) * n_nodes, up_gbps=(_INF,),
                   up_capacity_gbps=(_INF,), oversub=(1.0,),
                   pfs_gbps=_INF, default_nic_gbps=_INF,
                   profiles=tuple(profiles), flat=True)

    @classmethod
    def two_tier(cls, n_racks: int, nodes_per_rack: int, *,
                 nic_gbps: float = 1.25e9, oversubscription: float = 1.0,
                 pfs_gbps: float = 0.5e9,
                 profiles: Sequence[NodeProfile] = ()) -> "ClusterTopology":
        """A classic two-tier fabric: ``n_racks`` ToRs of ``nodes_per_rack``
        nodes each, every uplink oversubscribed ``oversubscription``:1.

        Per-node NIC overrides come from ``profiles`` (mixed generations);
        each uplink's effective per-flow bandwidth is
        ``nic_gbps / oversubscription`` and its nominal capacity is
        ``nodes_per_rack * nic_gbps / oversubscription``."""
        if oversubscription <= 0:
            raise ValueError("oversubscription must be positive")
        n = n_racks * nodes_per_rack
        profs = tuple(profiles)
        nics = tuple(
            (profs[i].nic_gbps if i < len(profs)
             and profs[i].nic_gbps is not None else nic_gbps)
            for i in range(n))
        share = nic_gbps / oversubscription
        cap = nodes_per_rack * nic_gbps / oversubscription
        return cls(n_nodes=n,
                   rack_of=tuple(i // nodes_per_rack for i in range(n)),
                   nic_gbps=nics, up_gbps=(share,) * n_racks,
                   up_capacity_gbps=(cap,) * n_racks,
                   oversub=(float(oversubscription),) * n_racks,
                   pfs_gbps=pfs_gbps, default_nic_gbps=nic_gbps,
                   profiles=profs)

    # ------------------------------------------------------------- accessors
    @property
    def n_racks(self) -> int:
        return len(self.up_gbps)

    def rack(self, node: int) -> int:
        """Rack of ``node`` — growth joins beyond the configured size get a
        deterministic round-robin assignment."""
        if 0 <= node < len(self.rack_of):
            return self.rack_of[node]
        return node % self.n_racks

    def nic(self, node: int) -> float:
        if 0 <= node < len(self.nic_gbps):
            return self.nic_gbps[node]
        return self.default_nic_gbps

    def speed(self, node: int) -> float:
        if 0 <= node < len(self.profiles):
            return self.profiles[node].speed
        return 1.0

    def node_class(self, node: int) -> str:
        if 0 <= node < len(self.profiles):
            return self.profiles[node].cls
        return "standard"

    def same_rack(self, a: int, b: int) -> bool:
        """True when ``a`` and ``b`` share a ToR (negative ids — the PFS —
        are in no rack)."""
        if a < 0 or b < 0:
            return False
        return self.rack(a) == self.rack(b)

    # ------------------------------------------------------------ path model
    def links(self, src: int, dst: int) -> tuple[object, ...]:
        """Lane keys of every link a ``src -> dst`` transfer occupies:
        node NICs are bare ints, ToR uplinks ``("up", rack)``, the PFS
        attachment ``("pfs",)``. Order: NICs, uplinks, PFS."""
        keys: list[object] = [n for n in (src, dst) if n >= 0]
        racks = sorted({self.rack(n) for n in (src, dst) if n >= 0})
        if src < 0 or dst < 0:                       # remote-tier endpoint
            keys.extend(("up", r) for r in racks)
            keys.append(("pfs",))
        elif len(racks) > 1:                         # crosses the spine
            keys.extend(("up", r) for r in racks)
        return tuple(keys)

    def up(self, rack: int) -> float:
        return self.up_gbps[rack] if 0 <= rack < len(self.up_gbps) else _INF

    def link_gbps(self, src: int, dst: int) -> float:
        """End-to-end bandwidth of one flow: the max-utilized (minimum
        effective capacity) link on the path."""
        if src == dst:
            return _INF
        bw = _INF
        racks = []
        for node in (src, dst):
            if node >= 0:
                bw = min(bw, self.nic(node))
                racks.append(self.rack(node))
        if src < 0 or dst < 0:
            for r in racks:
                bw = min(bw, self.up(r))
            bw = min(bw, self.pfs_gbps)
        elif len(racks) == 2 and racks[0] != racks[1]:
            for r in racks:
                bw = min(bw, self.up(r))
        return bw

    def speeds(self) -> dict[int, float]:
        """Per-node speed overrides derived from the profiles (only the
        non-nominal ones) — the simulator's default ``speeds`` mapping."""
        return {i: p.speed for i, p in enumerate(self.profiles)
                if p.speed != 1.0}
