"""Discrete-event cluster simulator — how we evaluate the paper's schedulers at
1000+ node scale inside a CPU-only container.

Models exactly the quantities the paper's argument rests on:

* workers (one task slot each, optional per-node speed factors = stragglers),
* the tiered LocStore (per-node HBM/DRAM/burst-buffer capacities + the remote
  parallel-FS tier; default: the paper's flat two-tier model), with every byte
  fetched across the network — and every capacity-pressure demotion —
  accounted,
* per-destination NIC serialization (transfers to one node queue up),
* per-task **I/O wait** (assignment -> inputs resident), the number the paper's
  proactive pipelining is designed to drive to ~zero,
* node failures (re-run lost producers, reschedule the running task) so the
  fault-tolerance story is testable.

The same :class:`~repro.core.scheduler.SchedulerBase` objects drive this
simulator and the real JAX executor — the simulator is not a re-implementation
of the policy, only of the cluster.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
from typing import Callable, Mapping, Sequence

from repro.core.config import SimConfig
from repro.core.locstore import (DropReport, JoinReport, LocStore, Placement,
                                 REMOTE_TIER, SimObject, _stable_hash)
from repro.core.scheduler import (Assignment, ClusterView, LocalityScheduler,
                                  ProactiveScheduler, SchedulerBase)
from repro.core.topology import ClusterTopology
from repro.core.wfcompiler import CompiledWorkflow, HardwareModel

__all__ = ["SimConfig", "SimResult", "SimCluster", "WorkflowSimulator",
           "simulate"]


@dataclasses.dataclass
class SimResult:
    makespan: float
    bytes_moved: float            # network bytes on the critical fetch path
    bytes_prefetched: float       # network bytes moved ahead of time
    bytes_local: float            # bytes served without the network
    io_wait_total: float          # sum of per-task input-stall seconds
    io_wait_max: float
    tasks_done: int
    reruns: int                   # failure-induced re-executions
    task_records: dict[str, dict] = dataclasses.field(default_factory=dict)
    remote_bytes: float = 0.0     # network bytes to/from the PFS tier
    bytes_demoted: float = 0.0    # capacity-pressure eviction traffic
    demotions: int = 0
    promotions: int = 0
    writebacks: int = 0           # async dirty flushes to the PFS
    writeback_bytes: float = 0.0
    clean_drops: int = 0          # free evictions (PFS already had the copy)
    coord_drops: int = 0          # free evictions (duplicate elsewhere)
    pin_protected_evictions: int = 0  # evictions a do-not-evict pin diverted
    # durability / failure accounting
    fsyncs: int = 0               # synchronous durability flushes
    fsync_bytes: float = 0.0
    dirty_lost: int = 0           # lost objects a tighter window would've kept
    phantom_durable: int = 0      # laundered drains (must stay 0)
    prefetch_aborts: int = 0      # in-flight transfers whose src node died
    # elastic membership accounting
    joins: int = 0                # nodes (re)admitted mid-run
    rereplications: int = 0       # sole-copy objects staged toward newcomers
    bytes_rereplicated: float = 0.0
    # topology accounting (stays 0/empty on flat configs)
    cross_spine_bytes: float = 0.0   # bytes that traversed any ToR uplink
    predictive_rereplications: int = 0  # sole copies drained off suspects
    bytes_predictively_rereplicated: float = 0.0
    drop_reports: list[DropReport] = dataclasses.field(default_factory=list)
    join_reports: list[JoinReport] = dataclasses.field(default_factory=list)
    # per-link cumulative bytes under a real topology: NIC lanes keyed by
    # node id, uplinks by ("up", rack), the PFS attachment by ("pfs",)
    link_bytes: dict = dataclasses.field(default_factory=dict)

    @property
    def locality_hit_rate(self) -> float:
        tot = self.bytes_local + self.bytes_moved
        return self.bytes_local / tot if tot else 1.0

    def summary(self) -> Mapping[str, float]:
        return {
            "makespan_s": self.makespan,
            "bytes_moved": self.bytes_moved,
            "bytes_prefetched": self.bytes_prefetched,
            "locality_hit_rate": self.locality_hit_rate,
            "io_wait_total_s": self.io_wait_total,
            "io_wait_max_s": self.io_wait_max,
            "tasks": float(self.tasks_done),
            "reruns": float(self.reruns),
            "remote_bytes": self.remote_bytes,
            "bytes_demoted": self.bytes_demoted,
            "demotions": float(self.demotions),
            "promotions": float(self.promotions),
            "writebacks": float(self.writebacks),
            "writeback_bytes": self.writeback_bytes,
            "clean_drops": float(self.clean_drops),
            "coord_drops": float(self.coord_drops),
            "fsyncs": float(self.fsyncs),
            "fsync_bytes": self.fsync_bytes,
            "dirty_lost": float(self.dirty_lost),
            "phantom_durable": float(self.phantom_durable),
            "prefetch_aborts": float(self.prefetch_aborts),
            "joins": float(self.joins),
            "rereplications": float(self.rereplications),
            "bytes_rereplicated": self.bytes_rereplicated,
            "cross_spine_bytes": self.cross_spine_bytes,
            "predictive_rereplications": float(self.predictive_rereplications),
            "bytes_predictively_rereplicated":
                self.bytes_predictively_rereplicated,
        }


class _LinkLanes:
    """One priority class of transfer lanes over the network.

    Flat model (``topo is None``): one lane per node NIC — exactly the
    legacy per-destination ``nic_free`` lists, bit-identical. Real topology:
    a transfer occupies **every link on its path** (endpoint NICs, the
    racks' ToR uplinks, the PFS attachment), so concurrent transfers
    through a shared uplink or the PFS link genuinely contend — the
    per-NIC lanes are the degenerate single-link special case.
    """

    __slots__ = ("topo", "node", "extra")

    def __init__(self, topo: ClusterTopology | None, n_nodes: int,
                 t0: float = 0.0) -> None:
        self.topo = topo                  # None => legacy per-NIC lanes
        self.node = [t0] * n_nodes        # NIC lane per node
        self.extra: dict = {}             # ("up", rack)/("pfs",) -> busy-until

    def __len__(self) -> int:
        return len(self.node)

    def avail(self, path) -> float:
        """Earliest instant every link on ``path`` is free."""
        t = 0.0
        for k in path:
            v = self.node[k] if isinstance(k, int) else self.extra.get(k, 0.0)
            if v > t:
                t = v
        return t

    def occupy(self, path, until: float) -> None:
        for k in path:
            if isinstance(k, int):
                self.node[k] = until
            else:
                self.extra[k] = until

    def reset_node(self, node: int, t0: float) -> None:
        """A dead/rejoining node's NIC serves nothing: its lane restarts at
        ``t0``. Shared uplink/PFS lanes keep their queued traffic — the
        fabric does not forget other tenants' transfers."""
        self.node[node] = t0

    def grow_to(self, n: int, t0: float) -> None:
        while len(self.node) < n:
            self.node.append(t0)


class SimCluster(ClusterView):
    """ClusterView over simulator state (free set, store, link model).

    ``free_workers()``/``alive_nodes()`` are cached between mutations — the
    schedulers call them every tick, and re-sorting a 4096-entry set per call
    was a measurable slice of per-decision cost. Mutate through
    :meth:`acquire`/:meth:`release`/:meth:`fail` so the caches invalidate.
    """

    def __init__(self, n_nodes: int, hw: HardwareModel, store: LocStore,
                 speeds: Mapping[int, float] | None = None) -> None:
        self.n_nodes = n_nodes
        self.hw = hw
        self.store = store
        self.speeds = dict(speeds or {})
        self.free: set[int] = set(range(n_nodes))
        self.failed: set[int] = set()
        self._free_cache: list[int] | None = None
        self._alive_cache: list[int] | None = None
        # per-source link-bandwidth rows for batched candidate scoring:
        # bandwidths are static per HardwareModel, so each row is built once
        self._link_rows: dict[int, tuple[list[float], float | None]] = {}
        # topology-aware runs attach the simulator's demand lanes + clock so
        # the schedulers can route around saturated links (node_queue_seconds)
        self.now = 0.0
        self._lanes: _LinkLanes | None = None

    def attach_lanes(self, lanes: _LinkLanes) -> None:
        self._lanes = lanes

    def node_queue_seconds(self, node: int) -> float:
        """Seconds of already-queued demand traffic a new transfer to/from
        ``node`` would wait behind — the max backlog over the node's NIC and
        its rack's ToR uplink. 0.0 on flat topologies (no lanes attached),
        which keeps flat scheduling decisions identical."""
        lanes = self._lanes
        if lanes is None:
            return 0.0
        q = lanes.node[node] - self.now if node < len(lanes.node) else 0.0
        topo = lanes.topo
        if topo is not None:
            up = lanes.extra.get(("up", topo.rack(node)), 0.0) - self.now
            if up > q:
                q = up
        return q if q > 0.0 else 0.0

    def acquire(self, node: int) -> None:
        """A task started on ``node`` — it is no longer free."""
        self.free.discard(node)
        self._free_cache = None

    def release(self, node: int) -> None:
        """A task finished on ``node`` — free again unless it failed."""
        if node not in self.failed:
            self.free.add(node)
            self._free_cache = None

    def fail(self, node: int) -> None:
        self.failed.add(node)
        self.free.discard(node)
        self._free_cache = None
        self._alive_cache = None

    def join(self, node: int) -> None:
        """Absorb a (re)joining node into the cached views incrementally —
        no rescan: the sorted free/alive caches get a bisect-insort, and on
        growth every cached link row is extended in place (bandwidths are
        static per HardwareModel, so appending the new destinations keeps
        each row exact)."""
        grew = node >= self.n_nodes
        if grew:
            old_n = self.n_nodes
            self.n_nodes = node + 1
            # skipped ids in a gapped growth join never joined: mark them
            # failed so an eventual cache rebuild agrees with the
            # incremental inserts below (alive + failed partitions
            # range(n_nodes), exactly as in LocStore.join_node)
            self.failed.update(range(old_n, node))
            for src, (row, _uniform) in list(self._link_rows.items()):
                row.extend(self.hw.link_gbps(src, dst)
                           for dst in range(old_n, self.n_nodes))
                vals = set(row[:src] + row[src + 1:]
                           if 0 <= src < self.n_nodes else row)
                uniform = vals.pop() if len(vals) == 1 else None
                self._link_rows[src] = (row, uniform)
        was_failed = node in self.failed
        self.failed.discard(node)
        if not (was_failed or grew):
            return          # already a live member (possibly busy): no-op
        self.free.add(node)
        for cache in (self._free_cache, self._alive_cache):
            if cache is not None:
                i = bisect.bisect_left(cache, node)
                if i == len(cache) or cache[i] != node:
                    cache.insert(i, node)

    def free_workers(self) -> Sequence[int]:
        if self._free_cache is None:
            self._free_cache = sorted(self.free - self.failed)
        return self._free_cache

    def alive_nodes(self) -> Sequence[int]:
        if self._alive_cache is None:
            self._alive_cache = [n for n in range(self.n_nodes)
                                 if n not in self.failed]
        return self._alive_cache

    def link_row(self, src: int) -> tuple[list[float], float | None]:
        info = self._link_rows.get(src)
        if info is None:
            row = [self.hw.link_gbps(src, dst) for dst in range(self.n_nodes)]
            # uniform = the single off-diagonal bandwidth, if there is one
            # (the src->src entry is inf and never consulted by the scorer)
            vals = set(row[:src] + row[src + 1:]
                       if 0 <= src < self.n_nodes else row)
            uniform = vals.pop() if len(vals) == 1 else None
            info = (row, uniform)
            self._link_rows[src] = info
        return info

    def locate(self, data_name: str) -> Placement | None:
        return self.store.loc.lookup(data_name)

    def is_durable(self, data_name: str) -> bool:
        return self.store.durable(data_name)

    def link_gbps(self, src: int, dst: int) -> float:
        return self.hw.link_gbps(src, dst)

    def tier_gbps(self, tier: str) -> float:
        return self.store.hierarchy.bw(tier)

    def top_tier(self) -> str:
        return self.store.hierarchy.top

    def bulk_tier(self) -> str:
        return self.store.hierarchy.bottom

    def worker_speed(self, node: int) -> float:
        return self.speeds.get(node, 1.0)


# event kinds, ordered so same-time finishes are processed before starts
_TASK_FINISH = 0
_XFER_DONE = 1
_FAIL = 2
_WB_FLUSH = 3
_JOIN = 4
_PREDICT = 5        # health monitor flags a node ahead of its failure


class WorkflowSimulator:
    def __init__(
        self,
        wf: CompiledWorkflow,
        scheduler: SchedulerBase,
        *,
        config: SimConfig | None = None,
        **legacy,
    ) -> None:
        # documented path: one frozen SimConfig. Legacy path: the original
        # flat keywords (n_nodes=, hierarchy=, write_policy=, ...), mapped
        # through SimConfig.from_kwargs — the pinned equivalence test proves
        # both spellings produce identical SimResults. Mixing them is a
        # config-aliasing bug waiting to happen, so it is rejected.
        if config is None:
            config = SimConfig.from_kwargs(**legacy)
        elif legacy:
            raise TypeError("WorkflowSimulator: pass config= OR the legacy "
                            f"keywords, not both: {sorted(legacy)}")
        self.config = config
        self.wf = wf
        self.sched = scheduler
        topo = config.topology
        if topo is not None and topo.n_nodes != config.n_nodes:
            raise ValueError(f"topology covers {topo.n_nodes} nodes, "
                             f"n_nodes={config.n_nodes}")
        # the *charging* model: with a topology attached, move_seconds prices
        # the max-utilized link on the node->ToR->spine path (flat topologies
        # keep the scalar arithmetic, so costs are bit-identical)
        self.hw = config.hw.with_topology(topo) if topo is not None \
            else config.hw
        # a real (non-flat) topology switches the NIC lanes to per-link lanes
        self._topo_real = topo if topo is not None and not topo.flat else None
        # the schedulers'/store's *view*: topology_aware=False is the blind
        # ablation — the simulator still charges real per-link costs, but
        # placement decisions see only the flat scalar model
        view_hw = self.hw if config.topology_aware else config.hw
        store_topo = topo if config.topology_aware else None
        # per-node speeds: topology profiles supply the defaults
        # (mixed-generation clusters); explicit config.speeds overrides win
        speeds: dict[int, float] = dict(topo.speeds()) if topo is not None \
            else {}
        if config.speeds:
            speeds.update(config.speeds)
        self.n_nodes = config.n_nodes
        self.store = LocStore(config.n_nodes, hierarchy=config.hierarchy,
                              write_policy=config.write_policy,
                              coordinated_eviction=config.coordinated_eviction,
                              durability=config.durability,
                              topology=store_topo)
        # fsync_on_barrier: a store barrier (flush everything dirty) fires
        # every `barrier_every` task finishes — the workflow's sync points
        self.barrier_every = max(int(config.barrier_every), 1)
        self.cluster = SimCluster(config.n_nodes, view_hw, self.store,
                                  speeds or None)
        self.failures = sorted(config.failures)
        self.joins = sorted(config.joins)
        self.join_rereplicate_bytes = config.join_rereplicate_bytes
        self.proactive = (isinstance(scheduler, ProactiveScheduler)
                          if config.proactive is None else config.proactive)
        # honor the compiler's per-dataset write-mode pins (pass 5): outputs
        # pinned "around" stream straight to the PFS instead of landing in
        # node tiers — trading the consumer's (remote) read for zero tier
        # occupancy, which only pays off under capacity pressure. False:
        # never; True: all pins, unconditionally (legacy opt-in); "auto"
        # (default): only the pins repro.analysis proves safe, and only in
        # configurations where the trade can win (finite node tier, a
        # locality-aware scheduler, stable membership).
        hwm = config.honor_write_modes
        if hwm not in (False, True, "auto"):
            raise ValueError(f"honor_write_modes must be True, False or "
                             f"'auto', got {hwm!r}")
        self.honor_write_modes = hwm
        # in auto mode the put path additionally diverts a pin whose consumer
        # is already bound to a DIFFERENT node at put time; explicit True
        # keeps the unguarded PR-4 semantics
        self._write_mode_guard = hwm == "auto"
        if hwm is True:
            self._write_modes: dict[str, str] = dict(wf.write_modes)
        elif hwm == "auto":
            self._write_modes = self._auto_write_modes(wf, config, scheduler)
        else:
            self._write_modes = {}
        # runtime invariant sanitizer (repro.analysis.sanitize): opt-in via
        # config or the REPRO_SANITIZE env var; checks every incremental
        # structure against a from-scratch rebuild every sanitize_every events
        if config.sanitize is None:
            from repro.analysis.sanitize import env_enabled
            self.sanitize = env_enabled()
        else:
            self.sanitize = bool(config.sanitize)
        self.sanitize_every = max(int(config.sanitize_every), 1)
        # prefetched replicas pinned do-not-evict until their consumer runs
        self._task_pins: dict[str, list[tuple[str, int]]] = {}
        # wire the scheduler to the store's metadata events. indexed=True
        # turns on the incremental decision path (placement mirror, term
        # cache, ready heap, pending-candidate index); indexed=False is the
        # decision-identical full-rescan reference the equivalence tests
        # compare against — the event wiring itself stays on in both modes
        # (the proactive pre-assignment/prefetch invalidation depends on it).
        self.indexed = config.indexed
        scheduler.attach_store(self.store, indexed=config.indexed)
        # place external inputs: remote tier (paper's parallel FS) or scattered
        for d in wf.graph.external_inputs():
            if config.external_loc == "remote":
                loc = Placement(nodes=(REMOTE_TIER,), tier="remote")
            else:
                # content-stable hash: scattered placement must not depend
                # on the process's string-hash salt (reproducible runs)
                loc = Placement(nodes=(_stable_hash(d.name)
                                       % config.n_nodes,))
            self.store.put(d.name, SimObject(wf.sizes[d.name]), loc=loc)

    # ------------------------------------------------------------------ run
    def run(self) -> SimResult:
        wf, sched = self.wf, self.sched
        now = 0.0
        seq = itertools.count()
        events: list[tuple[float, int, int, object]] = []
        for t, node in self.failures:
            heapq.heappush(events, (t, next(seq), _FAIL, node))
        # joins pushed after failures: a same-instant fail-then-join cycle
        # processes the failure first (seq breaks the time tie in push order)
        for t, node in self.joins:
            heapq.heappush(events, (t, next(seq), _JOIN, node))
        if self.config.predict_failures:
            # health-monitor model: each scheduled failure is flagged
            # predict_lead_s ahead, giving the predictive re-replication
            # trigger time to drain the suspect's sole copies
            lead = max(float(self.config.predict_lead_s), 0.0)
            for t, node in self.failures:
                heapq.heappush(events,
                               (max(t - lead, 0.0), next(seq), _PREDICT, node))

        unfinished_preds = {tid: sum(1 for _ in wf.graph.predecessors(tid))
                            for tid in wf.graph.tasks}
        state = {tid: "pending" for tid in wf.graph.tasks}  # pending|ready|running|done
        running_at: dict[str, int] = {}
        # per-task run generation: a failure requeues the task and a new
        # attempt may start before the OLD attempt's finish event pops — the
        # stale event must not complete the new run early
        run_gen: dict[str, int] = {}
        # Per-link transfer lanes, two priority classes: demand fetches queue
        # only behind demand; prefetch is preemptible background traffic that
        # fills idle network time (the paper pipelines "while predecessors
        # run"). Flat configs get one lane per destination NIC (the legacy
        # model, bit-identical); a real topology adds ToR-uplink and PFS
        # lanes, so transfers through a shared spine contend (_LinkLanes).
        topo = self._topo_real
        nic_free = _LinkLanes(topo, self.n_nodes)     # demand channel
        nic_bg_free = _LinkLanes(topo, self.n_nodes)  # background (prefetch)
        if topo is not None and self.config.topology_aware:
            self.cluster.attach_lanes(nic_free)
        # (src, dst) -> lane-key path, memoized (rebuilt-from-scratch by the
        # sanitizer's check_link_paths at checkpoints)
        self._path_cache: dict[tuple[int, int], tuple] = {}
        path_cache = self._path_cache
        link_bytes: dict = {}
        cross_spine_bytes = 0.0
        io_wait: dict[str, float] = {}
        bytes_prefetched = 0.0
        reruns = 0
        dirty_lost = 0
        prefetch_aborts = 0
        joins_done = 0
        rereplications = 0
        bytes_rereplicated = 0.0
        predictive_rereps = 0
        bytes_predictive = 0.0

        def lane_path(src: int, dst: int, endpoint: int) -> tuple:
            """Lane keys a src->dst transfer occupies. Flat model: just the
            charged endpoint's NIC (legacy semantics)."""
            if topo is None:
                return (endpoint,)
            key = (src, dst)
            p = path_cache.get(key)
            if p is None:
                p = topo.links(src, dst)
                path_cache[key] = p
            return p

        def note_bytes(path: tuple, nbytes: float) -> None:
            """Per-link byte accounting (real topologies only): every link on
            the path carries the payload; a transfer counts toward
            cross_spine_bytes once if it traversed any ToR uplink."""
            nonlocal cross_spine_bytes
            if topo is None:
                return
            crossed = False
            for k in path:
                link_bytes[k] = link_bytes.get(k, 0.0) + nbytes
                if k.__class__ is tuple and k[0] == "up":
                    crossed = True
            if crossed:
                cross_spine_bytes += nbytes
        drop_reports: list[DropReport] = []
        join_reports: list[JoinReport] = []
        records: dict[str, dict] = {}
        done = 0
        total = len(wf.graph.tasks)
        xfer_cursor = 0               # store.transfers scanned so far

        ready: set[str] = {tid for tid, n in unfinished_preds.items() if n == 0}
        for tid in ready:
            state[tid] = "ready"

        def data_available(name: str) -> bool:
            return self.store.exists(name)

        # -- pending-candidate index (indexed mode) -------------------------
        # preplace() wants every PENDING task with >= 1 materialized input.
        # The reference path rescans all tasks x inputs each tick; here we
        # keep a per-task materialized-input count, maintained from the
        # store's record/drop events via the dataset consumer lists, plus a
        # bisect-sorted (graph-order, tid) list of current members — the same
        # order ``state.items()`` yields, so preplace's stable rank sort
        # breaks ties identically. Membership changes on: a dataset
        # (dis)appearing (store event), a task leaving "pending" (the
        # finish-unlock loop below), or a failure rollback (rare; we rebuild).
        use_index = (self.indexed and self.proactive
                     and isinstance(sched, ProactiveScheduler))
        order = {tid: i for i, tid in enumerate(wf.graph.tasks)}
        exists_mirror: set[str] = set()
        avail_count: dict[str, int] = {}
        cand_list: list[tuple[int, str]] = []
        cand_set: set[str] = set()

        def cand_check(tid: str) -> None:
            should = state[tid] == "pending" and avail_count[tid] > 0
            if should and tid not in cand_set:
                cand_set.add(tid)
                bisect.insort(cand_list, (order[tid], tid))
            elif not should and tid in cand_set:
                cand_set.remove(tid)
                cand_list.remove((order[tid], tid))

        def cand_rebuild() -> None:
            """Recompute index membership from scratch (after a failure's
            state rollbacks). avail_count stays event-maintained — exact,
            since ``exists()`` is lookup()-is-not-None and every lookup
            change funnels through a record/drop event."""
            cand_list.clear()
            cand_set.clear()
            for tid in wf.graph.tasks:
                if state[tid] == "pending" and avail_count[tid] > 0:
                    cand_set.add(tid)
                    cand_list.append((order[tid], tid))

        def on_store_event(event: str, key: str, placement: object) -> None:
            if event == "record":
                if key not in exists_mirror:
                    exists_mirror.add(key)
                    d = wf.graph.data.get(key)
                    if d is not None:
                        for c in d.consumers:
                            avail_count[c] += 1
                            cand_check(c)
            elif event == "drop":
                if key in exists_mirror:
                    exists_mirror.discard(key)
                    d = wf.graph.data.get(key)
                    if d is not None:
                        for c in d.consumers:
                            avail_count[c] -= 1
                            cand_check(c)

        if use_index:
            exists_mirror.update(self.store.loc.names())
            for tid, t in wf.graph.tasks.items():
                avail_count[tid] = sum(1 for n in t.inputs
                                       if n in exists_mirror)
            cand_rebuild()
            self.store.loc.subscribe(on_store_event)

        def on_pin_event(event: str, key: object, placement: object) -> None:
            # keep _task_pins mirroring the store's pin table: delete() and
            # drop_node() release pins INSIDE the store, so the task-finish
            # unpin for a stale mirror entry would decrement a fresh pin
            # someone re-acquired for the same (name, node) later
            if event == "drop":
                for pins in self._task_pins.values():
                    if pins:
                        pins[:] = [p for p in pins if p[0] != key]
            elif event == "drop_node":
                for pins in self._task_pins.values():
                    if pins:
                        pins[:] = [p for p in pins if p[1] != key]

        self.store.loc.subscribe(on_pin_event)

        n_events = 0
        if self.sanitize:
            from repro.analysis import sanitize as _san

        def sanitize_check() -> None:
            _san.check_membership(self.store, self.cluster)
            _san.check_tier_usage(self.store)
            _san.check_ledger(self.store)
            _san.check_pin_conservation(self.store, self._task_pins)
            _san.check_placement_mirror(sched, self.store)
            _san.check_term_cache(sched, self.cluster)
            _san.check_proactive(sched, self.cluster)
            _san.check_link_rows(self.cluster)
            _san.check_link_paths(path_cache, topo)
            if use_index:
                _san.check_candidate_index(
                    state=state, avail_count=avail_count,
                    cand_list=cand_list, cand_set=cand_set,
                    exists_mirror=exists_mirror, order=order,
                    store=self.store, graph=wf.graph)

        def fetch_time(name: str, dst: int, t0: float) -> float:
            """Queue one input fetch on dst's NIC; returns completion time.

            A local hit still costs its resident tier's media time (reading a
            burst-buffer replica is not free, just cheaper than the PFS); a
            network fetch pays link + per-tier-hop media time.
            """
            value, tr = self.store.get(name, at=dst)
            if tr is None:
                return t0
            if tr.local:
                return t0 + tr.est_seconds
            dur = self.hw.move_seconds(tr.nbytes, tr.src, dst) + tr.est_seconds
            path = lane_path(tr.src, dst, dst)
            start = max(nic_free.avail(path), t0)
            nic_free.occupy(path, start + dur)
            note_bytes(path, tr.nbytes)
            return start + dur

        def drain_eviction_traffic(t0: float) -> None:
            """Charge PFS-bound eviction traffic to the evicting node's NIC.

            Write-through spills (kind demote/spill) are synchronous — they
            occupy the DEMAND lane, so the fetches tasks are waiting on queue
            behind them: that is the critical-path cost async write-back
            exists to remove. Write-back flushes and write-around streams
            (kind writeback/writearound) overlap compute on the background
            lane, competing only with prefetch for idle network time."""
            nonlocal xfer_cursor
            new = self.store.transfers[xfer_cursor:]
            xfer_cursor = len(self.store.transfers)
            for tr in new:
                if tr.dst != REMOTE_TIER or not (0 <= tr.src < self.n_nodes):
                    continue
                dur = (self.hw.move_seconds(tr.nbytes, tr.src, REMOTE_TIER)
                       + tr.est_seconds)
                path = lane_path(tr.src, REMOTE_TIER, tr.src)
                if tr.kind in ("demote", "spill", "fsync"):
                    # fsync is ack/barrier-blocking by design: it rides the
                    # demand lane, so the durability window's cost is real —
                    # fetches queue behind the eager flush
                    end = max(nic_free.avail(path), t0) + dur
                    nic_free.occupy(path, end)
                    note_bytes(path, tr.nbytes)
                elif tr.kind == "writearound":
                    end = max(nic_bg_free.avail(path), t0) + dur
                    nic_bg_free.occupy(path, end)
                    note_bytes(path, tr.nbytes)
                elif tr.kind == "writeback":
                    # the flush becomes durable when the background lane
                    # finishes it, not at enqueue — the queue is FIFO and
                    # transfers are scanned in enqueue order, so one
                    # flush-done event per transfer drains the right entry
                    end = max(nic_bg_free.avail(path), t0) + dur
                    nic_bg_free.occupy(path, end)
                    note_bytes(path, tr.nbytes)
                    heapq.heappush(events, (end, next(seq), _WB_FLUSH, None))

        def start_assignment(a: Assignment, t0: float) -> None:
            nonlocal done
            tid = a.tid
            state[tid] = "running"
            running_at[tid] = a.node
            self.cluster.acquire(a.node)
            t_inputs = t0
            for name in wf.graph.tasks[tid].inputs:
                t_inputs = max(t_inputs, fetch_time(name, a.node, t0))
            io_wait[tid] = t_inputs - t0
            dur = wf.est_seconds[tid] / max(self.cluster.worker_speed(a.node), 1e-6)
            finish = t_inputs + dur
            records[tid] = {"node": a.node, "assigned": t0, "start": t_inputs,
                            "finish": finish, "io_wait": t_inputs - t0,
                            "move_est": a.move_seconds}
            run_gen[tid] = run_gen.get(tid, 0) + 1
            heapq.heappush(events, (finish, next(seq), _TASK_FINISH,
                                    (tid, run_gen[tid])))

        def schedule_pass(t0: float) -> None:
            nonlocal bytes_prefetched
            drain_eviction_traffic(t0)
            self.cluster.now = t0   # node_queue_seconds measures backlog
            if ready and self.cluster.free_workers():
                for a in sched.select(sorted(ready), self.cluster):
                    ready.discard(a.tid)
                    start_assignment(a, t0)
            if self.proactive and isinstance(sched, ProactiveScheduler):
                if use_index:
                    candidates = [tid for _, tid in cand_list]
                else:
                    candidates = [tid for tid, st in state.items()
                                  if st == "pending"
                                  and any(data_available(n)
                                          for n in wf.graph.tasks[tid].inputs)]
                for req in sched.preplace(candidates, self.cluster, running_at):
                    p = self.store.loc.lookup(req.data_name)
                    if p is None or p.resident_on(req.dst):
                        continue
                    src = p.real_loc
                    hier = self.store.hierarchy
                    dst_tier = hier.normalize(req.tier)
                    dur = (self.hw.move_seconds(req.est_bytes, src, req.dst)
                           + hier.media_seconds(req.est_bytes, p.tier_on(src))
                           + hier.media_seconds(req.est_bytes, dst_tier))
                    path = lane_path(src, req.dst, req.dst)
                    start = max(nic_bg_free.avail(path),
                                nic_free.avail(path), t0)
                    nic_bg_free.occupy(path, start + dur)
                    note_bytes(path, req.est_bytes)
                    bytes_prefetched += req.est_bytes
                    heapq.heappush(events, (start + dur, next(seq), _XFER_DONE,
                                            (req.data_name, src, req.dst,
                                             dst_tier, req.for_task)))

        def fail_node(node: int, t0: float) -> None:
            nonlocal reruns, dirty_lost
            # charge transfers issued before the failure to the NIC model
            # first, so the lane reset below cannot erase pre-failure traffic
            drain_eviction_traffic(t0)
            self.cluster.fail(node)
            # the dead node's NIC lanes serve nothing anymore: reset them so
            # later accounting cannot queue behind (or charge) a dead queue
            # (shared uplink/PFS lanes keep other tenants' queued traffic)
            nic_free.reset_node(node, t0)
            nic_bg_free.reset_node(node, t0)
            # requeue the running task and release its prefetch pins — the
            # task-finish unpin will never fire for a failure-cancelled task
            for tid, n in list(running_at.items()):
                if n == node:
                    running_at.pop(tid)
                    state[tid] = "ready"
                    ready.add(tid)
                    reruns += 1
                    for pname, pdst in self._task_pins.pop(tid, []):
                        self.store.unpin(pname, pdst)
            # one atomic storage-layer drop: forget the node's replicas,
            # cancel in-flight write-back flushes sourced on it (a later
            # drain must not mark a lost object durable), clear its pins
            report = self.store.drop_node(node)
            drop_reports.append(report)
            dirty_lost += len(report.dirty_lost)
            nonlocal done
            for name in report.lost:   # data gone: re-run the producers
                prod = wf.graph.data[name].producer
                if prod is None:       # external input: remote tier still has it
                    self.store.put(name, SimObject(wf.sizes[name]),
                                   loc=Placement((REMOTE_TIER,), tier="remote"))
                    continue
                if state[prod] == "done":
                    reruns += 1
                    done -= self._invalidate(prod, state, unfinished_preds,
                                             ready, running_at)
            if use_index:
                # the requeue/rollback above moved tasks between pending and
                # ready in bulk — failures are rare, recompute membership
                cand_rebuild()

        def join_node(node: int, t0: float) -> None:
            nonlocal joins_done, rereplications, bytes_rereplicated
            # charge pre-join traffic before touching the newcomer's lanes
            drain_eviction_traffic(t0)
            grew = node >= len(nic_free)
            was_failed = node in self.cluster.failed
            nic_free.grow_to(node + 1, t0)
            nic_bg_free.grow_to(node + 1, t0)
            if was_failed:
                # a rejoining node's NIC starts idle at the join instant
                # (an already-alive node keeps its queued traffic)
                nic_free.reset_node(node, t0)
                nic_bg_free.reset_node(node, t0)
            # storage layer first: clears the failed mark, reopens default
            # placement, and fires ("join_node", node, None) so the indexed
            # scheduler and preplace eligibility absorb the newcomer
            report = self.store.join_node(node)
            join_reports.append(report)
            self.cluster.join(node)
            if report.grew:
                self.n_nodes = self.store.n_nodes
            joins_done += 1
            # re-replicate toward the newcomer: sole-copy objects, dirty
            # first (the write side of risk_aware) — staged as background
            # transfers so the copies pay real network/media time and only
            # materialize when the lane delivers them (_XFER_DONE with no
            # consuming task: replicate without a pin)
            bulk = self.store.hierarchy.bottom
            for name, src, src_tier, nbytes in \
                    self.store.rereplication_candidates(
                        node, max_bytes=self.join_rereplicate_bytes):
                dur = (self.hw.move_seconds(nbytes, src, node)
                       + self.store.hierarchy.media_seconds(nbytes, src_tier)
                       + self.store.hierarchy.media_seconds(nbytes, bulk))
                path = lane_path(src, node, node)
                start = max(nic_bg_free.avail(path), t0)
                nic_bg_free.occupy(path, start + dur)
                note_bytes(path, nbytes)
                rereplications += 1
                bytes_rereplicated += nbytes
                heapq.heappush(events, (start + dur, next(seq), _XFER_DONE,
                                        (name, src, node, bulk, None)))

        def predict_node(suspect: int, t0: float) -> None:
            """The health monitor flagged ``suspect``: drain its sole-copy
            data (dirty first) to a target in a *different rack* before the
            failure lands — the predictive trigger the reactive join-time
            re-replication (join_node above) cannot match, because it only
            runs after the data is already gone. The copies ride the
            background lanes; ones still in flight when the failure hits are
            aborted by the _XFER_DONE dead-source guard."""
            nonlocal predictive_rereps, bytes_predictive
            if suspect in self.cluster.failed or suspect >= self.n_nodes:
                return
            target = self._predict_target(suspect)
            if target is None:
                return
            drain_eviction_traffic(t0)
            bulk = self.store.hierarchy.bottom
            for name, src, src_tier, nbytes in \
                    self.store.rereplication_candidates(
                        target,
                        max_bytes=self.config.predict_rereplicate_bytes,
                        only_src=suspect):
                dur = (self.hw.move_seconds(nbytes, src, target)
                       + self.store.hierarchy.media_seconds(nbytes, src_tier)
                       + self.store.hierarchy.media_seconds(nbytes, bulk))
                path = lane_path(src, target, target)
                start = max(nic_bg_free.avail(path), t0)
                nic_bg_free.occupy(path, start + dur)
                note_bytes(path, nbytes)
                predictive_rereps += 1
                bytes_predictive += nbytes
                heapq.heappush(events, (start + dur, next(seq), _XFER_DONE,
                                        (name, src, target, bulk, None)))

        schedule_pass(0.0)
        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == _TASK_FINISH:
                tid, gen = payload  # type: ignore[misc]
                if state.get(tid) != "running" or gen != run_gen.get(tid):
                    continue    # cancelled by a failure / stale prior attempt
                node = running_at.pop(tid)
                state[tid] = "done"
                done += 1
                for pname, pdst in self._task_pins.pop(tid, []):
                    self.store.unpin(pname, pdst)
                self.cluster.release(node)
                for out in wf.graph.tasks[tid].outputs:
                    pin = wf.graph.data[out].pinned_loc
                    loc = pin if pin is not None else node
                    mode = (self._write_modes.get(out)
                            if pin is None else None)
                    if mode == "around" and self._write_mode_guard:
                        # auto mode's runtime guard: the analyzer proved the
                        # consumer SHOULD land on the producing node, but if
                        # the scheduler has already bound it elsewhere (a
                        # running attempt or a proactive preassignment), the
                        # prediction is void for this put — fall back to the
                        # normal write path rather than strand the consumer
                        # behind a guaranteed remote read
                        cs = wf.graph.data[out].consumers
                        ctid = cs[0] if len(cs) == 1 else None
                        cnode = running_at.get(ctid) if ctid else None
                        if cnode is None and ctid is not None \
                                and isinstance(sched, ProactiveScheduler):
                            cnode = sched.preassignment.get(ctid)
                        if cnode is not None and cnode != node:
                            mode = None
                    if not self.store.exists(out):
                        self.store.put(out, SimObject(self.wf.sizes[out]),
                                       loc=loc, mode=mode)
                for s in wf.graph.successors(tid):
                    unfinished_preds[s] -= 1
                    if unfinished_preds[s] == 0 and state[s] == "pending":
                        state[s] = "ready"
                        ready.add(s)
                        if use_index and s in cand_set:
                            cand_check(s)   # left "pending": out of the index
                if (self.store.durability == "fsync_on_barrier"
                        and done % self.barrier_every == 0):
                    # workflow sync point: close the durability window. The
                    # fsync transfers ride the demand NIC lane (see
                    # drain_eviction_traffic) — that contention is the cost
                    # this policy pays for bounding the rerun exposure.
                    self.store.barrier()
            elif kind == _XFER_DONE:
                name, src, dst, dst_tier, for_task = payload  # type: ignore[misc]
                if src in self.cluster.failed:
                    # the source died mid-flight: the bytes never finished
                    # crossing — without this guard a transfer could "arrive"
                    # from a dead node and materialize a replica of data that
                    # may no longer exist anywhere
                    prefetch_aborts += 1
                elif self.store.exists(name) and dst not in self.cluster.failed:
                    self.store.replicate(name, [dst], tier=dst_tier)
                    # shield the fresh replica from (coordinated) eviction
                    # until its consumer has run — prefetch work must not be
                    # undone by capacity pressure at comfortable occupancy
                    if state.get(for_task) not in ("done", None):
                        self.store.pin(name, dst)
                        self._task_pins.setdefault(for_task, []).append(
                            (name, dst))
            elif kind == _WB_FLUSH:
                self.store.drain_writebacks(max_entries=1)
            elif kind == _FAIL:
                fail_node(payload, now)  # type: ignore[arg-type]
            elif kind == _JOIN:
                join_node(payload, now)  # type: ignore[arg-type]
            elif kind == _PREDICT:
                predict_node(payload, now)  # type: ignore[arg-type]
            schedule_pass(now)
            if self.sanitize:
                n_events += 1
                if n_events % self.sanitize_every == 0:
                    sanitize_check()
            if done == total and not any(st == "running" for st in state.values()):
                # drain queued failures/transfers without extending makespan
                break
        if self.sanitize:
            sanitize_check()   # final checkpoint at quiescence
        if use_index:
            self.store.loc.unsubscribe(on_store_event)
        self.store.loc.unsubscribe(on_pin_event)

        if done != total:
            missing = [t for t, st in state.items() if st != "done"]
            raise RuntimeError(f"simulation deadlock: {len(missing)} tasks "
                               f"unfinished, e.g. {missing[:5]}")
        self.store.drain_writebacks()   # flush stragglers (already charged)
        rep = self.store.movement_report()
        return SimResult(
            makespan=now,
            bytes_moved=rep["bytes_moved"],
            bytes_prefetched=bytes_prefetched,
            bytes_local=rep["bytes_local"],
            io_wait_total=sum(io_wait.values()),
            io_wait_max=max(io_wait.values(), default=0.0),
            tasks_done=done,
            reruns=reruns,
            task_records=records,
            remote_bytes=rep["remote_bytes"],
            bytes_demoted=rep["bytes_demoted"],
            demotions=int(rep["demotions"]),
            promotions=int(rep["promotions"]),
            writebacks=int(rep["writebacks"]),
            writeback_bytes=rep["writeback_bytes"],
            clean_drops=int(rep["clean_drops"]),
            coord_drops=int(rep["coord_drops"]),
            pin_protected_evictions=int(rep["pin_protected_evictions"]),
            fsyncs=int(rep["fsyncs"]),
            fsync_bytes=rep["fsync_bytes"],
            dirty_lost=dirty_lost,
            phantom_durable=int(rep["phantom_durable"]),
            prefetch_aborts=prefetch_aborts,
            joins=joins_done,
            rereplications=rereplications,
            bytes_rereplicated=bytes_rereplicated,
            cross_spine_bytes=cross_spine_bytes,
            predictive_rereplications=predictive_rereps,
            bytes_predictively_rereplicated=bytes_predictive,
            drop_reports=drop_reports,
            join_reports=join_reports,
            link_bytes=link_bytes,
        )

    def _predict_target(self, suspect: int) -> int | None:
        """Where to drain a suspect node's sole copies: the lowest-id alive
        node in a *different rack* (failure-domain diversity); any other
        alive node when the topology is flat or single-rack."""
        topo = self.config.topology
        best: int | None = None
        best_key: tuple[int, int] | None = None
        for n in self.cluster.alive_nodes():
            if n == suspect:
                continue
            same = 1
            if topo is not None and not topo.flat:
                same = 1 if topo.same_rack(n, suspect) else 0
            key = (same, n)
            if best_key is None or key < best_key:
                best_key, best = key, n
        return best

    @staticmethod
    def _auto_write_modes(wf: CompiledWorkflow, config: SimConfig,
                          scheduler: SchedulerBase) -> dict[str, str]:
        """The analyzer-gated default (PR 9): honor exactly the write-mode
        pins ``repro.analysis.lint.safe_write_modes`` proves safe, and only
        when the configuration lets write-around pay off — at least one
        finite node tier (otherwise there is no occupancy to save), a
        locality-aware scheduler (the co-scheduling proof assumes one), and
        stable membership (failures/joins void the static prediction).
        Everything else behaves exactly like ``honor_write_modes=False``."""
        if not wf.write_modes or config.failures or config.joins:
            return {}
        if not isinstance(scheduler, LocalityScheduler):
            return {}
        hier = config.hierarchy
        if hier is None or not any(t.capacity_bytes != float("inf")
                                   for t in hier.tiers):
            return {}
        from repro.analysis.lint import safe_write_modes
        return safe_write_modes(wf)

    def _invalidate(self, tid: str, state: dict, unfinished_preds: dict,
                    ready: set, running_at: dict) -> int:
        """Roll a completed task (and stale successors) back to pending/ready.
        Returns how many previously-done tasks were rolled back (the caller
        must subtract from its completion counter)."""
        rolled = 0
        if state[tid] == "running":
            running_at.pop(tid, None)
        if state[tid] == "done":
            rolled = 1
            for s in self.wf.graph.successors(tid):
                unfinished_preds[s] += 1
                if state[s] == "ready":
                    state[s] = "pending"
                    ready.discard(s)
        npred = sum(1 for p in self.wf.graph.predecessors(tid)
                    if state[p] != "done")
        unfinished_preds[tid] = npred
        if npred == 0:
            state[tid] = "ready"
            ready.add(tid)
        else:
            state[tid] = "pending"
        return rolled


def simulate(wf: CompiledWorkflow,
             scheduler_factory: Callable[[CompiledWorkflow], SchedulerBase],
             *, config: SimConfig | None = None, **kw) -> SimResult:
    """One-call helper: build scheduler, run, return the result.

    ``config=SimConfig(...)`` is the documented spelling; the legacy flat
    keywords are still accepted (but not both at once)."""
    return WorkflowSimulator(wf, scheduler_factory(wf), config=config,
                             **kw).run()
