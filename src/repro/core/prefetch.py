"""Asynchronous data-pipelining engine — the mechanism behind the paper's
"tell the file system to start pipelining the data to the target server".

Two modes, one interface:

* **host objects** (numpy arrays, bytes, pytrees): a background thread copies
  the object and registers the replica with the LocStore, so by the time the
  consumer task starts, ``store.get(name, at=node)`` is a local hit.
* **JAX arrays**: ``jax.device_put`` is dispatched asynchronously (JAX's async
  dispatch IS the pipeline); the engine keeps the in-flight handle and
  ``wait()`` blocks on readiness only if the consumer arrives early.

Every prefetch targets a storage **tier** on the destination node: ``"hbm"``
means device prefetch (the replica is promoted into device memory and, when a
``device_of`` map is present, actually ``device_put``); lower tiers stage into
host DRAM or the burst buffer without occupying device memory. A flat store
clamps unknown tiers to its top tier, so the engine works unchanged against
the original two-tier model.

The engine is deliberately small: policy lives in the ProactiveScheduler; this
is only the data plane.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable

from repro.core.locstore import LocStore

__all__ = ["PrefetchEngine"]


class PrefetchEngine:
    def __init__(self, store: LocStore, *, max_workers: int = 4,
                 device_of: Callable[[int], Any] | None = None) -> None:
        """``device_of(node) -> jax.Device`` enables device-level prefetch;
        without it the engine replicates at host level only."""
        self.store = store
        self.device_of = device_of
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="xflow-prefetch")
        self._inflight: dict[tuple[str, int], Future] = {}
        self._device_copies: dict[tuple[str, int], Any] = {}
        # consumer task -> replicas pinned do-not-evict on its behalf
        self._pins_for: dict[str, list[tuple[str, int]]] = {}
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.skipped_read_once = 0
        self.bytes_prefetched = 0.0
        # failure hygiene: a dead node's in-flight handles, device copies and
        # pin records describe replicas that no longer exist — purge them on
        # the store's drop events so a later submit() re-stages instead of
        # returning a handle to vanished data, and release() does not unpin
        # replicas the store already forgot.
        store.loc.subscribe(self._on_store_event)

    def _on_store_event(self, event: str, key: Any, placement: Any) -> None:
        if event == "drop_node":
            with self._lock:
                for k in [k for k in self._inflight if k[1] == key]:
                    del self._inflight[k]
                for k in [k for k in self._device_copies if k[1] == key]:
                    del self._device_copies[k]
                for pins in self._pins_for.values():
                    pins[:] = [p for p in pins if p[1] != key]
        elif event == "drop":
            with self._lock:
                for k in [k for k in self._inflight if k[0] == key]:
                    del self._inflight[k]
                for k in [k for k in self._device_copies if k[0] == key]:
                    del self._device_copies[k]
                for pins in self._pins_for.values():
                    pins[:] = [p for p in pins if p[0] != key]

    # ------------------------------------------------------------------ api
    def submit(self, name: str, dst: int, *, tier: str = "hbm",
               pin_for: str | None = None) -> Future:
        """Start pipelining ``name`` to node ``dst``'s ``tier``.

        Idempotent per (name, dst) while a stage is in flight — but once the
        previous stage has landed, a request for a tier *faster* than where
        the replica sits NOW re-submits (a session cache parked back into
        the burst buffer must still be promotable to HBM by every later
        warm-up; the store may also have demoted or overwritten the replica
        since the last stage, so the decision reads live placement, not a
        recorded snapshot). ``pin_for`` names the consuming task: the
        replica is pinned do-not-evict in the store until :meth:`release` is
        called for that task, so capacity pressure cannot undo the prefetch
        before its consumer runs."""
        key = (name, dst)
        with self._lock:
            fut = self._inflight.get(key)
            if fut is not None and not self._should_restage(fut, name, dst,
                                                            tier):
                if pin_for is not None:
                    self._pin(name, dst, pin_for)
                return fut
            fut = self._pool.submit(self._stage, name, dst, tier)
            self._inflight[key] = fut
            self.submitted += 1
            if pin_for is not None:
                self._pin(name, dst, pin_for)
            return fut

    def _should_restage(self, fut: Future, name: str, dst: int,
                        tier: str) -> bool:
        """A completed stage is stale when the replica is gone from ``dst``
        or parked below the requested tier (read-once objects never
        re-stage — their mode exists to avoid exactly that)."""
        if not fut.done():
            return False
        mode_of = getattr(self.store, "write_mode", None)
        if mode_of is not None and mode_of(name) == "around":
            return False
        hier = self.store.hierarchy
        p = self.store.loc.lookup(name)
        if p is None:
            return False                       # object deleted: nothing to do
        if not p.resident_on(dst):
            return True                        # evicted off the node entirely
        return hier.rank(hier.normalize(tier)) < hier.rank(p.tier_on(dst))

    def _pin(self, name: str, dst: int, for_task: str) -> None:
        """Caller holds the lock. Pin once per (task, name, dst)."""
        if (name, dst) in self._pins_for.setdefault(for_task, []):
            return
        self.store.pin(name, dst)
        self._pins_for[for_task].append((name, dst))

    def release(self, for_task: str) -> int:
        """Unpin every replica pinned on behalf of ``for_task`` (the consumer
        finished — the prefetched copies are fair eviction game again).
        Returns how many pins were released."""
        with self._lock:
            pinned = self._pins_for.pop(for_task, [])
        for name, dst in pinned:
            self.store.unpin(name, dst)
        return len(pinned)

    def _stage(self, name: str, dst: int, tier: str) -> Any:
        value, tr = self.store.get(name)  # metadata read, no accounting
        mode_of = getattr(self.store, "write_mode", None)
        if mode_of is not None and mode_of(name) == "around":
            # write-around objects are read exactly once: caching a replica
            # ahead of time would waste the tier the mode exists to protect
            with self._lock:
                self.completed += 1
                self.skipped_read_once += 1
            return value
        if tier == "hbm" and self.device_of is not None:
            try:
                import jax
                dev = self.device_of(dst)
                if dev is not None:
                    value = jax.device_put(value, dev)  # async dispatch
                    with self._lock:
                        self._device_copies[(name, dst)] = value
            except Exception:
                pass  # host-level replication still proceeds
        placement = self.store.replicate(name, [dst], tier=tier)
        with self._lock:
            self.completed += 1
            self.bytes_prefetched += float(placement.xattr.get("size", 0.0))
        return value

    def wait(self, name: str, dst: int, timeout: float | None = None) -> bool:
        """Block until a previously-submitted prefetch lands; False if none."""
        key = (name, dst)
        with self._lock:
            fut = self._inflight.get(key)
        if fut is None:
            return False
        fut.result(timeout=timeout)
        return True

    def device_copy(self, name: str, dst: int) -> Any | None:
        """The device-resident replica, if device-level prefetch ran."""
        with self._lock:
            return self._device_copies.get((name, dst))

    def drain(self) -> None:
        with self._lock:
            futs = list(self._inflight.values())
        for f in futs:
            f.result()

    def shutdown(self) -> None:
        self.drain()
        self._pool.shutdown(wait=True)

    # ------------------------------------------------------------ reporting
    def report(self) -> dict[str, float]:
        with self._lock:
            pins = sum(len(v) for v in self._pins_for.values())
        return {"submitted": float(self.submitted),
                "completed": float(self.completed),
                "skipped_read_once": float(self.skipped_read_once),
                "pins_held": float(pins),
                "bytes_prefetched": self.bytes_prefetched}
