"""Frozen configuration objects for the two big entry points (PR 7).

:class:`SimConfig` consolidates :class:`~repro.core.simulator.WorkflowSimulator`'s
dozen keyword knobs; :class:`ServingConfig` does the same for the serving
stack's :class:`~repro.serve.engine.ServingEngine` / ``Router`` constructor
sprawl. Both entry points now take ``config=`` as the documented path while
still accepting the legacy keywords, which are mapped through
``from_kwargs`` — an equivalence test pins that the two spellings produce
identical results.

The dataclasses are frozen so a config can be shared across engines, stored
on the object that consumed it, and compared/hashed in tests without
aliasing surprises.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.core.locstore import StorageHierarchy
from repro.core.topology import ClusterTopology
from repro.core.wfcompiler import HardwareModel, TPU_V5E


def _check_known(cls: type, kw: dict) -> None:
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(kw) - known)
    if unknown:
        raise TypeError(f"{cls.__name__}: unknown knob(s) {unknown}; "
                        f"known: {sorted(known)}")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Every :class:`WorkflowSimulator` knob in one frozen object.

    ``WorkflowSimulator(wf, sched, config=SimConfig(...))`` and
    ``simulate(wf, factory, config=...)`` are the documented spelling; the
    legacy flat keywords still work and are routed through
    :meth:`from_kwargs` (passing both is a ``TypeError``).
    """

    n_nodes: int = 64
    hw: HardwareModel = TPU_V5E
    # Explicit link graph + per-node profiles (repro.core.topology). When
    # set, the simulator charges transfers per traversed link and the
    # schedulers/store see topology-backed costs; a *flat* topology
    # (ClusterTopology.one_switch) is bit-identical to topology=None.
    topology: ClusterTopology | None = None
    # False: the *simulator* still charges real per-link costs but the
    # scheduler/store keep the flat scalar view — the topology-blind
    # ablation bench_topology compares against.
    topology_aware: bool = True
    # Predictive re-replication (health-monitor model): when True, each
    # scheduled failure is flagged ``predict_lead_s`` seconds ahead and the
    # suspect node's sole-copy data is re-replicated to a different rack
    # (any other node when flat) before the failure lands, under a
    # ``predict_rereplicate_bytes`` budget per warning.
    predict_failures: bool = False
    predict_lead_s: float = 3.0
    predict_rereplicate_bytes: float = float("inf")
    speeds: Mapping[int, float] | None = None
    failures: tuple[tuple[float, int], ...] = ()
    joins: tuple[tuple[float, int], ...] = ()
    join_rereplicate_bytes: float = float("inf")
    external_loc: str = "remote"            # "remote" | "scattered"
    proactive: bool | None = None
    hierarchy: StorageHierarchy | None = None
    write_policy: str = "through"
    coordinated_eviction: bool = False
    # False: never honor compiler write-mode pins; True: honor them all
    # (legacy PR-4 behaviour); "auto" (default): honor exactly the pins the
    # analyzer proves safe (repro.analysis.lint.safe_write_modes), and only
    # in configurations where write-around can pay off (a finite node tier,
    # a locality-aware scheduler, stable membership).
    honor_write_modes: bool | str = "auto"
    durability: str = "none"
    barrier_every: int = 1
    indexed: bool = True
    # None: follow the REPRO_SANITIZE env var; True/False: force. When on,
    # every incremental structure is cross-checked against a from-scratch
    # rebuild every ``sanitize_every`` events (repro.analysis.sanitize).
    sanitize: bool | None = None
    sanitize_every: int = 64

    @classmethod
    def from_kwargs(cls, **kw) -> "SimConfig":
        """Map the legacy keyword spelling onto a config (TypeError on an
        unknown knob — same failure mode the old signature had)."""
        _check_known(cls, kw)
        failures: Sequence[tuple[float, int]] | None = kw.get("failures")
        if failures is not None:
            kw["failures"] = tuple((float(t), int(n)) for t, n in failures)
        joins: Sequence[tuple[float, int]] | None = kw.get("joins")
        if joins is not None:
            kw["joins"] = tuple((float(t), int(n)) for t, n in joins)
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Shared knobs of the serving stack: engine geometry plus the router's
    park/pricing policy. One object configures both ``ServingEngine`` (which
    reads the geometry fields) and ``Router`` (which reads the policy
    fields), so the two layers can never disagree about the workload shape.

    ``resume_bias`` scales the priced resume cost against the measured
    migrate-and-re-prefill cost: > 1 makes the router migrate earlier,
    < 1 makes it cling to locality harder. 1.0 reproduces the PR-4 pricing
    exactly.
    """

    max_batch: int = 4
    max_seq: int = 128
    eos_id: int = -1
    idle_tier: str = "bb"
    allow_park: bool = True
    resume_bias: float = 1.0
    # None: follow REPRO_SANITIZE; True/False: force slot/placeholder
    # invariant checks at every engine/router transition (PR 9 sanitizer)
    sanitize: bool | None = None

    @classmethod
    def from_kwargs(cls, **kw) -> "ServingConfig":
        _check_known(cls, kw)
        return cls(**kw)
