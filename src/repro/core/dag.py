"""Task DAG — the structure the workflow compiler annotates and the scheduler walks.

Mirrors the Swift/T compiler output in the paper (Fig. 2): a directed acyclic
graph whose nodes are *tasks* and whose edges pass through named *datasets*
(task -> dataset -> task), because the paper's whole point is that datasets are
first-class: they have sizes, locations, and movement costs.

Pure Python; no JAX. The graph is deliberately O(V+E) for every analysis pass
so it stays usable at 10^5-task scale (1000+-node clusters).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Iterable, Iterator, Mapping

from repro.core.hints import TaskHints

__all__ = ["DataSpec", "TaskSpec", "TaskGraph", "CycleError"]


class CycleError(ValueError):
    """Raised when the workflow graph is not acyclic."""


@dataclasses.dataclass
class DataSpec:
    """A named dataset flowing through the workflow (the paper's "file").

    ``size_bytes`` is None until known — either from a ``@size`` hint (external
    inputs) or propagated by the workflow compiler via ``@input-output-ratio``.
    ``pinned_loc`` mirrors the paper's ``S_LOC`` explicit-placement request.
    """

    name: str
    size_bytes: float | None = None
    producer: str | None = None           # task id, None for external inputs
    consumers: list[str] = dataclasses.field(default_factory=list)
    pinned_loc: Any | None = None
    xattr: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def is_external(self) -> bool:
        return self.producer is None


@dataclasses.dataclass
class TaskSpec:
    """One workflow task.

    ``fn`` is the executable body (``fn(**inputs) -> dict[output_name, value]``)
    for real execution; the simulator and compiler only need the metadata.
    """

    tid: str
    inputs: tuple[str, ...]
    outputs: tuple[str, ...]
    hints: TaskHints = dataclasses.field(default_factory=TaskHints)
    fn: Callable[..., Mapping[str, Any]] | None = None
    # filled by the workflow compiler:
    est_flops: float | None = None
    est_seconds: float | None = None
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)


class TaskGraph:
    """Mutable task/dataset DAG with the analyses the paper's compiler needs.

    Construction::

        g = TaskGraph()
        g.add_data("raw", size_bytes=size_hint(1 << 30))     # @size
        g.add_task("split", inputs=("raw",), outputs=("a", "b"),
                   hints=task(io_ratio=0.5))
    """

    def __init__(self) -> None:
        self.tasks: dict[str, TaskSpec] = {}
        self.data: dict[str, DataSpec] = {}

    # ------------------------------------------------------------- building
    def add_data(
        self,
        name: str,
        *,
        size_bytes: float | None = None,
        pinned_loc: Any | None = None,
        **xattr: Any,
    ) -> DataSpec:
        if name in self.data:
            raise ValueError(f"dataset {name!r} already declared")
        d = DataSpec(name=name, size_bytes=size_bytes, pinned_loc=pinned_loc,
                     xattr=dict(xattr))
        self.data[name] = d
        return d

    def add_task(
        self,
        tid: str,
        *,
        inputs: Iterable[str] = (),
        outputs: Iterable[str] = (),
        hints: TaskHints | None = None,
        fn: Callable[..., Mapping[str, Any]] | None = None,
        **attrs: Any,
    ) -> TaskSpec:
        if tid in self.tasks:
            raise ValueError(f"task {tid!r} already declared")
        inputs = tuple(inputs)
        outputs = tuple(outputs)
        t = TaskSpec(tid=tid, inputs=inputs, outputs=outputs,
                     hints=hints or TaskHints(), fn=fn, attrs=dict(attrs))
        for name in inputs:
            if name not in self.data:
                self.add_data(name)
            self.data[name].consumers.append(tid)
        for name in outputs:
            if name not in self.data:
                self.add_data(name)
            d = self.data[name]
            if d.producer is not None:
                raise ValueError(
                    f"dataset {name!r} already produced by {d.producer!r}")
            d.producer = tid
        self.tasks[tid] = t
        return t

    # ------------------------------------------------------------ structure
    def predecessors(self, tid: str) -> Iterator[str]:
        """Tasks whose outputs this task consumes."""
        seen: set[str] = set()
        for name in self.tasks[tid].inputs:
            p = self.data[name].producer
            if p is not None and p not in seen:
                seen.add(p)
                yield p

    def successors(self, tid: str) -> Iterator[str]:
        """Tasks consuming this task's outputs."""
        seen: set[str] = set()
        for name in self.tasks[tid].outputs:
            for c in self.data[name].consumers:
                if c not in seen:
                    seen.add(c)
                    yield c

    def external_inputs(self) -> list[DataSpec]:
        return [d for d in self.data.values() if d.is_external]

    def sinks(self) -> list[str]:
        return [tid for tid in self.tasks
                if not any(True for _ in self.successors(tid))]

    def sources(self) -> list[str]:
        return [tid for tid in self.tasks
                if not any(True for _ in self.predecessors(tid))]

    # ------------------------------------------------------------- analyses
    def topo_order(self) -> list[str]:
        """Kahn topological order; raises :class:`CycleError` on cycles."""
        indeg = {tid: sum(1 for _ in self.predecessors(tid)) for tid in self.tasks}
        q = deque(sorted(tid for tid, d in indeg.items() if d == 0))
        order: list[str] = []
        while q:
            tid = q.popleft()
            order.append(tid)
            for s in self.successors(tid):
                indeg[s] -= 1
                if indeg[s] == 0:
                    q.append(s)
        if len(order) != len(self.tasks):
            raise CycleError("workflow graph contains a cycle")
        return order

    def upward_rank(self, cost: Callable[[str], float] | None = None) -> dict[str, float]:
        """Length of the longest path from each task to a sink (inclusive).

        The paper: "it first calculates the length of the longest path from the
        final task to current task. Longer distance usually indicates a higher
        priority". ``cost(tid)`` weights each node (default: est_seconds if the
        compiler filled it, else 1.0 == pure hop count).
        """
        if cost is None:
            def cost(tid: str) -> float:  # noqa: ANN001
                est = self.tasks[tid].est_seconds
                return est if est is not None else 1.0
        rank: dict[str, float] = {}
        for tid in reversed(self.topo_order()):
            succ = [rank[s] for s in self.successors(tid)]
            rank[tid] = cost(tid) + (max(succ) if succ else 0.0)
        return rank

    def earliest_start(self, cost: Callable[[str], float] | None = None) -> dict[str, float]:
        """Earliest start time per task with unlimited workers (compiler pass)."""
        if cost is None:
            def cost(tid: str) -> float:  # noqa: ANN001
                est = self.tasks[tid].est_seconds
                return est if est is not None else 1.0
        est: dict[str, float] = {}
        for tid in self.topo_order():
            preds = [est[p] + cost(p) for p in self.predecessors(tid)]
            est[tid] = max(preds) if preds else 0.0
        return est

    def critical_path(self) -> tuple[list[str], float]:
        """(task chain, total weight) of the longest path through the DAG."""
        rank = self.upward_rank()
        if not rank:
            return [], 0.0
        cur = max(rank, key=lambda t: rank[t])
        total = rank[cur]
        path = [cur]
        while True:
            succ = list(self.successors(cur))
            if not succ:
                break
            cur = max(succ, key=lambda t: rank[t])
            path.append(cur)
        return path, total

    # ------------------------------------------------------------ utilities
    def validate(self, *, strict: bool = False) -> None:
        """Raise on cycles; with ``strict=True``, also raise on consumed
        external inputs that carry no ``@size`` hint (the compiler would
        silently guess a 1 MiB default, which poisons every size-derived
        estimate downstream)."""
        self.topo_order()  # raises on cycles
        for d in self.data.values():
            if d.is_external and d.size_bytes is None and d.consumers:
                if strict:
                    raise ValueError(
                        f"external input {d.name!r} is consumed by "
                        f"{sorted(set(d.consumers))} but has no size_bytes "
                        f"hint (strict validation; add @size or pass "
                        f"strict=False to accept the compiler's default)")

    def mark_sink(self, *names: str) -> None:
        """Declare datasets as intended workflow outputs. The dead-dataset
        lint flags produced-but-never-consumed datasets unless they carry
        this mark."""
        for name in names:
            if name not in self.data:
                raise KeyError(f"dataset {name!r} not declared")
            self.data[name].xattr["sink"] = True

    def __len__(self) -> int:
        return len(self.tasks)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"TaskGraph(tasks={len(self.tasks)}, data={len(self.data)}, "
                f"sinks={len(self.sinks())})")
