"""Location-aware store — the paper's file-system layer (§B, first component).

Reproduces, on top of JAX/host memory instead of Memcached, the three file
system extensions the paper proposes for Hercules:

1. **Placement control at create** — ``LocStore.put(name, value, loc=...)`` is
   ``OPEN(..., O_CREAT | S_LOC)``: the caller pins where the object lives. With
   no ``loc``, the store falls back to its default policy (consistent hash over
   nodes — what Hercules/Memcached would do).
2. **Location in extended attributes** — every object carries a
   :class:`Placement` with an ``xattr`` dict; ``stat``/``getxattr`` expose it.
3. **Distributed location service** — :class:`LocationService` shards the
   name -> real-loc mapping by consistent hash into ``n_shards`` independent
   metadata shards (one per metadata server in a real deployment), so lookups
   scale with the cluster instead of bottlenecking on one server. The runtime
   may re-pin ("real-loc") any object at any time via ``migrate`` — this is the
   channel the scheduler uses for its feedback (paper challenge #3).

Beyond the flat "compute node vs Lustre" split, each node exposes an ordered
**storage hierarchy** (:class:`StorageHierarchy`): device HBM over host DRAM
over burst buffer, with the shared parallel-FS ``remote`` tier at the bottom.
Every node-local tier has a per-node capacity and a sustained bandwidth; when
a tier fills, the store *demotes* the eviction victim one tier down (never
dropping data — the bottom of the cascade is the infinite remote tier), and
``get(name, at=node)`` *promotes* what it touches back to the top tier. The
default hierarchy is :data:`FLAT_HIERARCHY` (one infinite host tier), which
reproduces the paper's original two-tier behaviour exactly; pass
``tiered_hierarchy()`` to turn capacity pressure on.

Values can be anything sized: JAX arrays (``.nbytes``), numpy arrays, bytes, or
:class:`SimObject` stand-ins for the simulator. ``get(name, at=node)`` returns
the value AND a :class:`Transfer` record of the bytes that had to move — with
per-tier-hop accounting (:class:`TierHop`) — the numbers every benchmark in
this repo is built on.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Iterable, Mapping, Sequence

__all__ = ["Placement", "SimObject", "Transfer", "TierHop", "TierSpec",
           "StorageHierarchy", "FLAT_HIERARCHY", "tiered_hierarchy",
           "LocationService", "LocStore", "REMOTE_TIER"]

REMOTE_TIER = -1  # node id of the remote parallel-FS tier (Lustre analogue)

GiB = float(1 << 30)


def _stable_hash(name: str) -> int:
    return int.from_bytes(hashlib.blake2b(name.encode(), digest_size=8).digest(),
                          "big")


# --------------------------------------------------------------------- tiers
@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One level of the per-node storage hierarchy.

    ``capacity_bytes`` is PER NODE (``inf`` = unbounded); ``gbps`` is the
    sustained read/write bandwidth of the medium in bytes/s (``inf`` = free,
    which is how the flat hierarchy keeps the original two-tier cost model).
    """

    name: str
    capacity_bytes: float = float("inf")
    gbps: float = float("inf")


class StorageHierarchy:
    """Ordered node-local tiers (fastest first) + the shared remote PFS tier.

    The hierarchy answers three questions for the store: where does a fresh
    object land (``top``), where does an eviction victim go (``next_down`` —
    ``None`` past the last node tier, meaning "spill to remote"), and how fast
    is a tier's medium (``bw``).
    """

    def __init__(self, tiers: Sequence[TierSpec],
                 remote: TierSpec | None = None) -> None:
        if not tiers:
            raise ValueError("need at least one node-local tier")
        self.tiers = tuple(tiers)
        self.remote = remote or TierSpec("remote")
        names = [t.name for t in self.tiers] + [self.remote.name]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self._spec = {t.name: t for t in self.tiers}
        self._spec[self.remote.name] = self.remote
        self._order = {t.name: i for i, t in enumerate(self.tiers)}
        self._rank = dict(self._order)
        self._rank[self.remote.name] = len(self.tiers)

    @property
    def top(self) -> str:
        return self.tiers[0].name

    def names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.tiers) + (self.remote.name,)

    def is_node_tier(self, tier: str) -> bool:
        return tier in self._order

    def normalize(self, tier: str | None) -> str:
        """Map legacy/foreign tier names onto this hierarchy's node tiers."""
        if tier is None or tier == "node" or tier == self.remote.name:
            return self.top
        if tier in self._order:
            return tier
        # e.g. a scheduler asking for "hbm" against the flat hierarchy
        return self.top

    def spec(self, tier: str) -> TierSpec:
        return self._spec[tier]

    def capacity(self, tier: str) -> float:
        return self._spec[tier].capacity_bytes

    def bw(self, tier: str) -> float:
        spec = self._spec.get(tier)
        return spec.gbps if spec is not None else float("inf")

    def rank(self, tier: str) -> int:
        """Position in the hierarchy (0 = fastest; unknown sorts below all)."""
        return self._rank.get(tier, len(self._rank))

    def next_down(self, tier: str) -> str | None:
        """The demotion target below ``tier`` (None = spill to remote)."""
        i = self._order[tier]
        if i + 1 < len(self.tiers):
            return self.tiers[i + 1].name
        return None

    def media_seconds(self, nbytes: float, tier: str) -> float:
        bw = self.bw(tier)
        return 0.0 if bw == float("inf") else nbytes / bw


#: The original two-tier model: one unbounded, free host tier per node plus
#: the remote PFS. All existing cost accounting reduces to link bandwidths.
FLAT_HIERARCHY = StorageHierarchy([TierSpec("host")])


def tiered_hierarchy(*, hbm_bytes: float = 16 * GiB,
                     host_bytes: float = 64 * GiB,
                     bb_bytes: float = 256 * GiB,
                     hbm_gbps: float = 819e9, host_gbps: float = 100e9,
                     bb_gbps: float = 8e9, remote_gbps: float = 2e9,
                     ) -> StorageHierarchy:
    """Device-HBM / host-DRAM / burst-buffer / PFS — the HPC storage gradient."""
    return StorageHierarchy(
        [TierSpec("hbm", hbm_bytes, hbm_gbps),
         TierSpec("host", host_bytes, host_gbps),
         TierSpec("bb", bb_bytes, bb_gbps)],
        remote=TierSpec("remote", float("inf"), remote_gbps))


@dataclasses.dataclass
class Placement:
    """Where an object lives: one or more node ids (+ the remote tier).

    ``nodes`` is a tuple because the store supports replication; the paper's
    ``real-loc`` is ``nodes[0]``. ``xattr`` is the extended-attribute dict the
    paper stores location metadata in. ``tiers``, when set by a tiered store,
    is aligned with ``nodes`` and names the storage tier of each replica;
    ``tier`` alone describes the primary replica (kept for the two-tier API).
    """

    nodes: tuple[int, ...]
    tier: str = "host"                      # tier of nodes[0]
    xattr: dict[str, Any] = dataclasses.field(default_factory=dict)
    tiers: tuple[str, ...] | None = None    # per-replica tiers (tiered store)

    @property
    def real_loc(self) -> int:
        return self.nodes[0]

    def resident_on(self, node: int) -> bool:
        return node in self.nodes

    def tier_on(self, node: int) -> str:
        """Tier of the replica on ``node`` (falls back to ``tier``/remote)."""
        if self.tiers is not None:
            for n, t in zip(self.nodes, self.tiers):
                if n == node:
                    return t
        if node == REMOTE_TIER:
            return "remote"
        return self.tier


@dataclasses.dataclass(frozen=True)
class SimObject:
    """A sized placeholder used by the simulator (no actual payload)."""

    nbytes: float


@dataclasses.dataclass(frozen=True)
class TierHop:
    """One hop of a movement through the storage hierarchy."""

    src_node: int
    src_tier: str
    dst_node: int
    dst_tier: str
    nbytes: float
    est_seconds: float


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One data movement the store performed (fetch, demotion, promotion).

    ``hops`` itemizes the path through the hierarchy; ``est_seconds`` is the
    storage-layer media time (tier read + write) — the network link time on
    top of it is the hardware model's business (simulator/compiler add it).
    """

    name: str
    nbytes: float
    src: int
    dst: int
    src_tier: str = "host"
    dst_tier: str = "host"
    est_seconds: float = 0.0
    kind: str = "fetch"                 # fetch | demote | promote
    hops: tuple[TierHop, ...] = ()

    @property
    def local(self) -> bool:
        return self.src == self.dst

    @property
    def remote(self) -> bool:
        return self.src == REMOTE_TIER or self.dst == REMOTE_TIER


def sizeof(value: Any) -> float:
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return float(nb)
    if isinstance(value, (bytes, bytearray, memoryview)):
        return float(len(value))
    return float(64)  # opaque python object — metadata-sized


class LocationService:
    """Distributed location-metadata service (consistent-hash sharded).

    Each shard is an independent dict + lock — the in-process model of one
    metadata server. ``shard_of`` is deterministic so any client can route a
    lookup without coordination. Counters let the benchmarks report per-shard
    load balance (the scalability argument for "distributed" in the paper).
    """

    def __init__(self, n_shards: int = 16) -> None:
        if n_shards < 1:
            raise ValueError("need at least one metadata shard")
        self.n_shards = n_shards
        self._shards: list[dict[str, Placement]] = [{} for _ in range(n_shards)]
        self._locks = [threading.Lock() for _ in range(n_shards)]
        self.lookups = [0] * n_shards
        self.records = [0] * n_shards

    def shard_of(self, name: str) -> int:
        return _stable_hash(name) % self.n_shards

    def record(self, name: str, placement: Placement) -> None:
        s = self.shard_of(name)
        with self._locks[s]:
            self._shards[s][name] = placement
            self.records[s] += 1

    def lookup(self, name: str) -> Placement | None:
        s = self.shard_of(name)
        with self._locks[s]:
            self.lookups[s] += 1
            return self._shards[s].get(name)

    def drop(self, name: str) -> None:
        s = self.shard_of(name)
        with self._locks[s]:
            self._shards[s].pop(name, None)

    def names(self) -> list[str]:
        out: list[str] = []
        for s, lock in zip(self._shards, self._locks):
            with lock:
                out.extend(s.keys())
        return out

    def load_balance(self) -> Mapping[str, Any]:
        sizes = [len(s) for s in self._shards]
        return {"shards": self.n_shards, "entries": sum(sizes),
                "max_shard": max(sizes, default=0),
                "min_shard": min(sizes, default=0),
                "lookups": sum(self.lookups)}


class LocStore:
    """The location-aware compute-node-side store.

    ``nodes`` are integer ids 0..N-1 (plus :data:`REMOTE_TIER`). Thread-safe:
    the executor's worker threads and the prefetch engine hit it concurrently.

    With a capacity-bounded ``hierarchy``, each replica lives in one tier of
    its node; admitting past a tier's capacity demotes the eviction victim
    (``eviction_policy``: "lru", or "cost" = largest-coldest-first) down-tier,
    spilling to the remote PFS only below the last node tier. Reads promote
    the touched object back to the top tier (``promote_on_access``).
    """

    def __init__(self, n_nodes: int, *, n_meta_shards: int = 16,
                 default_policy: str = "hash",
                 hierarchy: StorageHierarchy | None = None,
                 eviction_policy: str = "lru",
                 promote_on_access: bool = True) -> None:
        if n_nodes < 1:
            raise ValueError("need at least one node")
        if eviction_policy not in ("lru", "cost"):
            raise ValueError(f"unknown eviction policy {eviction_policy!r}")
        self.n_nodes = n_nodes
        self.loc = LocationService(n_meta_shards)
        self.default_policy = default_policy
        self.hierarchy = hierarchy or FLAT_HIERARCHY
        self.eviction_policy = eviction_policy
        self.promote_on_access = promote_on_access
        self._values: dict[str, Any] = {}
        self._sizes: dict[str, float] = {}
        # replica map: name -> {node: tier} (insertion order = primary first)
        self._residency: dict[str, dict[int, str]] = {}
        self._usage: dict[tuple[int, str], float] = {}
        self._last_access: dict[tuple[int, str], dict[str, int]] = {}
        self._clock = 0
        self._lock = threading.RLock()
        self._rr = 0
        # accounting
        self.transfers: list[Transfer] = []
        self.bytes_moved = 0.0
        self.bytes_local = 0.0
        self.remote_bytes = 0.0        # network bytes touching the PFS tier
        self.bytes_demoted = 0.0
        self.demotions = 0
        self.promotions = 0
        self.migrations = 0
        self.tier_reads: dict[str, float] = {}

    # ------------------------------------------------------------ placement
    def _default_placement(self, name: str) -> Placement:
        if self.default_policy == "hash":       # Hercules/Memcached behaviour
            node = _stable_hash(name) % self.n_nodes
        elif self.default_policy == "rr":
            with self._lock:
                node = self._rr % self.n_nodes
                self._rr += 1
        else:
            raise ValueError(f"unknown default policy {self.default_policy!r}")
        return Placement(nodes=(node,), tier=self.hierarchy.top)

    def _norm_loc(self, loc: Any) -> Placement:
        if isinstance(loc, Placement):
            return loc
        if isinstance(loc, int):
            return Placement(nodes=(loc,), tier=self.hierarchy.top)
        if isinstance(loc, (tuple, list)):
            return Placement(nodes=tuple(int(n) for n in loc),
                             tier=self.hierarchy.top)
        raise TypeError(f"cannot interpret location {loc!r}")

    # ------------------------------------------------- tier admission (LRU)
    def _touch(self, name: str, node: int, tier: str) -> None:
        self._clock += 1
        self._last_access.setdefault((node, tier), {})[name] = self._clock

    def _victim(self, node: int, tier: str, protect: str) -> str | None:
        recency = self._last_access.get((node, tier), {})
        candidates = [n for n in recency if n != protect]
        if not candidates:
            return None
        if self.eviction_policy == "cost":
            # cost-aware: large, stale objects go first — freeing the most
            # capacity for the least loss of hot data (GreedyDual-Size-ish;
            # with equal sizes it degrades to plain LRU).
            return max(candidates,
                       key=lambda n: self._sizes.get(n, 0.0)
                       * (self._clock - recency[n] + 1))
        return min(candidates, key=lambda n: recency[n])

    def _drop_replica(self, name: str, node: int, tier: str) -> None:
        res = self._residency.get(name)
        if res is None or res.get(node) != tier:
            return
        del res[node]
        key = (node, tier)
        self._usage[key] = max(self._usage.get(key, 0.0)
                               - self._sizes.get(name, 0.0), 0.0)
        self._last_access.get(key, {}).pop(name, None)

    def _admit(self, name: str, node: int, tier: str,
               hops: list[TierHop] | None = None, *,
               spill: bool = False) -> str:
        """Place ``name``'s replica at (node, tier), demoting victims to fit.

        Returns the tier the object actually landed in (an object larger than
        every node tier cascades straight down to the remote PFS). Caller
        holds the lock. Demotion hops are appended to ``hops`` and recorded as
        ``kind="demote"`` transfers. ``spill=True`` means landing on the
        remote tier is capacity-forced data movement (counted in
        ``bytes_moved``/``remote_bytes``), not a caller-pinned PFS placement.
        """
        nbytes = self._sizes.get(name, 0.0)
        if node == REMOTE_TIER or not self.hierarchy.is_node_tier(tier):
            res = self._residency.setdefault(name, {})
            if spill and REMOTE_TIER not in res:
                self.bytes_moved += nbytes
                self.remote_bytes += nbytes
            res[REMOTE_TIER] = "remote"
            return "remote"
        cap = self.hierarchy.capacity(tier)
        if nbytes > cap:                       # cannot ever fit: skip down
            down = self.hierarchy.next_down(tier)
            return self._admit(name, node,
                               down if down is not None else "remote", hops,
                               spill=spill)
        res = self._residency.setdefault(name, {})
        old = res.get(node)
        if old == tier:
            self._touch(name, node, tier)
            return tier
        if old is not None:                    # moving between tiers on-node
            self._drop_replica(name, node, old)
        key = (node, tier)
        self._usage[key] = self._usage.get(key, 0.0) + nbytes
        res[node] = tier
        self._touch(name, node, tier)
        # cascade-demote until this tier fits again
        while self._usage.get(key, 0.0) > cap:
            victim = self._victim(node, tier, protect=name)
            if victim is None:
                break
            self._demote(victim, node, tier, hops)
            self._sync_placement(victim)
        return tier

    def _demote(self, name: str, node: int, tier: str,
                hops: list[TierHop] | None = None) -> None:
        """Move one replica a tier down (to the remote PFS past the bottom)."""
        nbytes = self._sizes.get(name, 0.0)
        down = self.hierarchy.next_down(tier)
        self._drop_replica(name, node, tier)
        landed = self._admit(name, node,
                             down if down is not None else "remote", hops,
                             spill=True)
        if landed == "remote":
            dst_node, dst_tier = REMOTE_TIER, "remote"
        else:
            dst_node, dst_tier = node, landed
        est = (self.hierarchy.media_seconds(nbytes, tier)
               + self.hierarchy.media_seconds(nbytes, dst_tier))
        hop = TierHop(node, tier, dst_node, dst_tier, nbytes, est)
        if hops is not None:
            hops.append(hop)
        self.bytes_demoted += nbytes
        self.demotions += 1
        self.transfers.append(Transfer(
            name, nbytes, node, dst_node, src_tier=tier, dst_tier=dst_tier,
            est_seconds=est, kind="demote", hops=(hop,)))

    def _sync_placement(self, name: str) -> None:
        """Re-record the LocationService entry from the residency map."""
        res = self._residency.get(name)
        if not res:
            return
        prev = self.loc.lookup(name)
        nodes = tuple(res.keys())
        tiers = tuple(res.values())
        self.loc.record(name, Placement(
            nodes=nodes, tier=tiers[0], tiers=tiers,
            xattr=prev.xattr if prev is not None else {}))

    # ------------------------------------------------------------------ api
    def put(self, name: str, value: Any, *, loc: Any | None = None,
            tier: str | None = None,
            xattr: Mapping[str, Any] | None = None) -> Placement:
        """Create an object; ``loc`` is the paper's ``S_LOC`` pinned placement.

        ``tier`` pins the starting tier on every node of the placement
        (default: the hierarchy's top tier — fresh output lands in the fastest
        memory and capacity pressure demotes it from there).
        """
        placement = (self._norm_loc(loc) if loc is not None
                     else self._default_placement(name))
        for n in placement.nodes:
            if n != REMOTE_TIER and not (0 <= n < self.n_nodes):
                raise ValueError(f"node {n} out of range for {self.n_nodes} nodes")
        placement.xattr.update(xattr or {})
        placement.xattr.setdefault("ctime", time.time())
        placement.xattr.setdefault("size", sizeof(value))
        want = self.hierarchy.normalize(tier if tier is not None
                                        else placement.tier)
        with self._lock:
            if name in self._residency:      # overwrite: clear old replicas
                for n, t in list(self._residency[name].items()):
                    self._drop_replica(name, n, t)
                self._residency.pop(name, None)
            self._values[name] = value
            self._sizes[name] = sizeof(value)
            for n in placement.nodes:
                # an explicit PFS placement is where the data starts, not a
                # movement; a node placement that cascades to the PFS is
                self._admit(name, n, "remote" if n == REMOTE_TIER else want,
                            spill=n != REMOTE_TIER)
            nodes = tuple(self._residency[name].keys())
            tiers = tuple(self._residency[name].values())
        final = Placement(nodes=nodes, tier=tiers[0], tiers=tiers,
                          xattr=placement.xattr)
        self.loc.record(name, final)
        return final

    def exists(self, name: str) -> bool:
        return self.loc.lookup(name) is not None

    def stat(self, name: str) -> Placement:
        p = self.loc.lookup(name)
        if p is None:
            raise KeyError(name)
        return p

    def getxattr(self, name: str, key: str) -> Any:
        """POSIX ``getxattr`` equivalent, incl. the location metadata."""
        p = self.stat(name)
        if key == "real_loc":
            return p.real_loc
        if key == "nodes":
            return p.nodes
        if key == "tier":
            return p.tier
        return p.xattr[key]

    def get(self, name: str, *, at: int | None = None) -> tuple[Any, Transfer | None]:
        """Read an object from node ``at``; returns (value, movement record).

        If the object is resident on ``at`` the movement record is a local hit
        (``Transfer.local``) whose ``est_seconds`` is the resident tier's media
        time, and the replica is promoted back to the top tier; otherwise the
        nearest (highest-tier, then closest) replica is the source and the
        store notes a network transfer. ``at=None`` skips accounting
        (metadata read).
        """
        self.stat(name)                       # raises KeyError if unknown
        with self._lock:
            value = self._values[name]
            if at is None:
                return value, None
            nbytes = self._sizes.get(name, sizeof(value))
            res = self._residency.get(name, {})
            if at in res:
                src_tier = res[at]
                hops: list[TierHop] = [TierHop(at, src_tier, at, src_tier,
                                               nbytes,
                                               self.hierarchy.media_seconds(
                                                   nbytes, src_tier))]
                self._touch(name, at, src_tier)
                dst_tier = src_tier
                if (self.promote_on_access
                        and self.hierarchy.is_node_tier(src_tier)
                        and src_tier != self.hierarchy.top):
                    # victim demotions this admit causes are recorded as
                    # their own kind="demote" transfers, not in our hops
                    landed = self._admit(name, at, self.hierarchy.top)
                    if landed != src_tier:
                        self.promotions += 1
                        hops.append(TierHop(
                            at, src_tier, at, landed, nbytes,
                            self.hierarchy.media_seconds(nbytes, landed)))
                        dst_tier = landed
                    self._sync_placement(name)
                t = Transfer(name, nbytes, at, at, src_tier=src_tier,
                             dst_tier=dst_tier,
                             est_seconds=hops[0].est_seconds,
                             kind="fetch", hops=tuple(hops))
                self.bytes_local += nbytes
                self.tier_reads[src_tier] = (self.tier_reads.get(src_tier, 0.0)
                                             + nbytes)
                self.transfers.append(t)
                return value, t
            # remote replica: prefer non-PFS, then the fastest tier, then near
            src = min(res, key=lambda n: (n == REMOTE_TIER,
                                          self.hierarchy.rank(res[n]),
                                          abs(n - at)))
            src_tier = res[src]
            dst_tier = self.hierarchy.top
            est = (self.hierarchy.media_seconds(nbytes, src_tier)
                   + self.hierarchy.media_seconds(nbytes, dst_tier))
            hop = TierHop(src, src_tier, at, dst_tier, nbytes, est)
            t = Transfer(name, nbytes, src, at, src_tier=src_tier,
                         dst_tier=dst_tier, est_seconds=est, kind="fetch",
                         hops=(hop,))
            self._touch(name, src, src_tier)
            self.bytes_moved += nbytes
            if src == REMOTE_TIER:
                self.remote_bytes += nbytes
            self.tier_reads[src_tier] = (self.tier_reads.get(src_tier, 0.0)
                                         + nbytes)
            self.transfers.append(t)
        return value, t

    def promote(self, name: str, node: int, tier: str | None = None) -> Placement:
        """Explicitly move a replica already resident on ``node`` to ``tier``
        (default: top) — the storage half of a device-targeted prefetch. Use
        :meth:`replicate` to create a replica on a new node."""
        want = self.hierarchy.normalize(tier)
        with self._lock:
            res = self._residency.get(name)
            if res is None or node not in res:
                raise KeyError(f"{name!r} has no replica on node {node}")
            have = res[node]
            if have != want:
                if self.hierarchy.rank(want) < self.hierarchy.rank(have):
                    self.promotions += 1       # moved up-tier; down is a pin
                self._admit(name, node, want)
            self._sync_placement(name)
        return self.stat(name)

    def migrate(self, name: str, loc: Any) -> Transfer:
        """Re-pin an object (the runtime->FS feedback channel).

        Returns the transfer that re-pinning implies. The value itself stays in
        the in-process dict (host RAM) — on a real deployment this issues the
        copy; device-resident arrays are re-placed by the executor.
        """
        p = self.stat(name)
        new = self._norm_loc(loc)
        new.xattr.update(p.xattr)
        new.xattr["migrated_from"] = p.nodes
        with self._lock:
            nbytes = self._sizes.get(name, 0.0)
            src = p.real_loc
            self.migrations += 1
            if not set(new.nodes) & set(p.nodes):
                self.bytes_moved += nbytes
                if src == REMOTE_TIER or REMOTE_TIER in new.nodes:
                    self.remote_bytes += nbytes
            for n, t in list(self._residency.get(name, {}).items()):
                self._drop_replica(name, n, t)
            self._residency.pop(name, None)
            self._residency[name] = {}
            want = self.hierarchy.normalize(new.tier)
            for n in new.nodes:
                self._admit(name, n, "remote" if n == REMOTE_TIER else want,
                            spill=n != REMOTE_TIER)
            nodes = tuple(self._residency[name].keys())
            tiers = tuple(self._residency[name].values())
        final = Placement(nodes=nodes, tier=tiers[0], tiers=tiers,
                          xattr=new.xattr)
        self.loc.record(name, final)
        return Transfer(name, nbytes, src, final.real_loc,
                        src_tier=p.tier, dst_tier=final.tier, kind="fetch")

    def replicate(self, name: str, extra_nodes: Iterable[int],
                  tier: str | None = None) -> Placement:
        """Add replicas (used by the prefetch engine: the original stays).

        ``tier`` targets a tier on the new nodes (default: top — a prefetch
        is supposed to land the data in the fastest memory).
        """
        self.stat(name)                       # raises KeyError if unknown
        want = self.hierarchy.normalize(tier)
        with self._lock:
            for n in extra_nodes:
                self._admit(name, int(n),
                            "remote" if int(n) == REMOTE_TIER else want,
                            spill=int(n) != REMOTE_TIER)
            self._sync_placement(name)
        return self.stat(name)

    def delete(self, name: str) -> None:
        with self._lock:
            self._values.pop(name, None)
            for n, t in list(self._residency.get(name, {}).items()):
                self._drop_replica(name, n, t)
            self._residency.pop(name, None)
            self._sizes.pop(name, None)
        self.loc.drop(name)

    def forget_replica(self, name: str, node: int) -> None:
        """Drop one node's replica from the residency map (failure handling).

        Dropping the LAST replica deletes the object entirely — the data is
        lost and ``exists()`` turns False so the caller can re-run the
        producer (what the simulator's failure path does)."""
        with self._lock:
            res = self._residency.get(name)
            if res is None or node not in res:
                return
            self._drop_replica(name, node, res[node])
            if res:
                self._sync_placement(name)
            else:
                self.delete(name)

    # ------------------------------------------------------------ reporting
    def movement_report(self) -> Mapping[str, float]:
        total = self.bytes_moved + self.bytes_local
        return {
            "bytes_moved": self.bytes_moved,
            "bytes_local": self.bytes_local,
            "locality_hit_rate": (self.bytes_local / total) if total else 1.0,
            "remote_bytes": self.remote_bytes,
            "bytes_demoted": self.bytes_demoted,
            "demotions": float(self.demotions),
            "promotions": float(self.promotions),
            "migrations": float(self.migrations),
            "transfers": float(len(self.transfers)),
        }

    def tier_report(self) -> Mapping[str, Mapping[str, float]]:
        """Per-tier residency and read traffic across all nodes."""
        out: dict[str, dict[str, float]] = {
            t: {"resident_bytes": 0.0, "bytes_read": 0.0, "replicas": 0.0}
            for t in self.hierarchy.names()}
        with self._lock:
            for (_, tier), used in self._usage.items():
                out.setdefault(tier, {"resident_bytes": 0.0, "bytes_read": 0.0,
                                      "replicas": 0.0})
                out[tier]["resident_bytes"] += used
            for res in self._residency.values():
                for _, tier in res.items():
                    out[tier]["replicas"] += 1
            for tier, nb in self.tier_reads.items():
                out[tier]["bytes_read"] += nb
        return out

    def reset_accounting(self) -> None:
        with self._lock:
            self.transfers.clear()
            self.bytes_moved = 0.0
            self.bytes_local = 0.0
            self.remote_bytes = 0.0
            self.bytes_demoted = 0.0
            self.demotions = 0
            self.promotions = 0
            self.migrations = 0
            self.tier_reads.clear()
