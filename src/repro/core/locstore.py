"""Location-aware store — the paper's file-system layer (§B, first component).

Reproduces, on top of JAX/host memory instead of Memcached, the three file
system extensions the paper proposes for Hercules:

1. **Placement control at create** — ``LocStore.put(name, value, loc=...)`` is
   ``OPEN(..., O_CREAT | S_LOC)``: the caller pins where the object lives. With
   no ``loc``, the store falls back to its default policy (consistent hash over
   nodes — what Hercules/Memcached would do).
2. **Location in extended attributes** — every object carries a
   :class:`Placement` with an ``xattr`` dict; ``stat``/``getxattr`` expose it.
3. **Distributed location service** — :class:`LocationService` shards the
   name -> real-loc mapping by consistent hash into ``n_shards`` independent
   metadata shards (one per metadata server in a real deployment), so lookups
   scale with the cluster instead of bottlenecking on one server. The runtime
   may re-pin ("real-loc") any object at any time via ``migrate`` — this is the
   channel the scheduler uses for its feedback (paper challenge #3).

Beyond the flat "compute node vs Lustre" split, each node exposes an ordered
**storage hierarchy** (:class:`StorageHierarchy`): device HBM over host DRAM
over burst buffer, with the shared parallel-FS ``remote`` tier at the bottom.
Every node-local tier has a per-node capacity and a sustained bandwidth; when
a tier fills, the store *demotes* the eviction victim one tier down (never
dropping data — the bottom of the cascade is the infinite remote tier), and
``get(name, at=node)`` *promotes* what it touches back to the top tier. The
default hierarchy is :data:`FLAT_HIERARCHY` (one infinite host tier), which
reproduces the paper's original two-tier behaviour exactly; pass
``tiered_hierarchy()`` to turn capacity pressure on.

**Write policies.** Demotion off the bottom node tier — the spill to the
parallel FS — supports three modes (``write_policy=`` / ``put(..., mode=)``):

* ``"through"`` (default, the original behaviour): the spill is a synchronous
  PFS write on the eviction path — the simulator charges it to the demand NIC
  lane, so it contends with the fetches tasks are waiting on.
* ``"back"``: per-replica **dirty bits** track whether the PFS already holds
  the current version. A *clean* victim is simply dropped (the durable copy
  exists — zero traffic); a *dirty* victim is enqueued on the
  :class:`WriteBackQueue` and flushed asynchronously (simulator: background
  NIC lane; executor: drainer thread) so the spill overlaps compute.
* ``"around"``: run-once streaming outputs are written straight to the PFS,
  never occupying node tiers, and reads are **read-once** — no replica is
  cached and ``replicate`` is a no-op for them.

**Coordinated eviction** (``coordinated_eviction=True``): ``_victim`` consults
the :class:`LocationService` so replicated objects are evicted before sole
copies, and a replica that is duplicated anywhere else in the cluster is
*dropped* (free) instead of demoted — node A never writes the last fast-tier
copy to the PFS while node B holds a cold duplicate. Sole copies are always
demoted down-tier, never dropped.

**Do-not-evict pins** (``pin``/``unpin``): the scheduler marks a prefetched
replica do-not-evict for its consumer's lifetime, so coordinated eviction at
comfortable capacity cannot undo prefetch work by dropping the duplicate it
just paid to create. Pins are per (name, node) and counted (two consumers may
pin the same replica); a fully-pinned tier stops evicting and runs overfull
rather than dropping pinned data.

**Durability windows** (``durability=``): compute-on-data-path keeps fresh
output on the node that produced it, which means a node failure can take the
*only* copy of a dataset down with it. The store models where in that window
each object sits — ``durable(name)`` is True exactly when the PFS holds the
current version — and offers three policies for closing it:

* ``"none"`` (default): dirty data reaches the PFS only when capacity
  pressure evicts it (and, under write-back, the queue drains). The window is
  unbounded: a failure re-runs the producer.
* ``"flush_before_ack"``: ``put`` is not acknowledged until the PFS write
  completes (``kind="fsync"`` transfer on the producer's demand NIC lane).
  Window = zero; cost = every byte eagerly crosses the network.
* ``"fsync_on_barrier"``: the runtime calls :meth:`barrier` at workflow sync
  points (task finishes, every ``barrier_every`` in the simulator); the
  barrier fsyncs everything still dirty. Window = one barrier interval.

**Failure handling** (``drop_node``): one atomic operation forgets every
replica on the dead node, *cancels pending write-back flushes sourced on it*
(the flush will never happen — without the cancel a later drain would mark
the lost object durable on the strength of a phantom PFS copy), revokes the
logical remote residency those flushes pre-recorded, and clears the node's
pin refcounts. Objects whose last copy died are deleted so ``exists()``
turns False and the caller can re-run the producer.

**Elastic membership** (``join_node``/``revive_node``): the arrival half of
the lifecycle, modeled on the saxml join protocol (the node announces
itself; the admin side updates membership). ``join_node`` clears the node
from the failed set (or grows ``n_nodes`` for a brand-new id), reopens
default placement to it, and publishes a ``("join_node", node, None)``
event so event-driven subscribers (indexed schedulers, the simulator's
candidate index, cached cluster views) absorb the newcomer without a
rescan. ``rereplication_candidates``/``rereplicate_to`` then close the
at-risk window the write side of ``risk_aware`` worries about: objects
whose ONLY node-local copy sits on one surviving node — dirty (no durable
PFS version: losing that node loses the data) first — are copied toward
the newcomer.

Values can be anything sized: JAX arrays (``.nbytes``), numpy arrays, bytes, or
:class:`SimObject` stand-ins for the simulator. ``get(name, at=node)`` returns
the value AND a :class:`Transfer` record of the bytes that had to move — with
per-tier-hop accounting (:class:`TierHop`) — the numbers every benchmark in
this repo is built on.
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
import hashlib
import threading
import time
from typing import Any, Iterable, Mapping, Sequence

__all__ = ["Placement", "SimObject", "Transfer", "TierHop", "TierSpec",
           "StorageHierarchy", "FLAT_HIERARCHY", "tiered_hierarchy",
           "LocationService", "LocStore", "REMOTE_TIER",
           "WriteBackEntry", "WriteBackQueue", "WRITE_POLICIES",
           "DURABILITY_POLICIES", "DropReport", "JoinReport"]

WRITE_POLICIES = ("through", "back", "around")
DURABILITY_POLICIES = ("none", "flush_before_ack", "fsync_on_barrier")

REMOTE_TIER = -1  # node id of the remote parallel-FS tier (Lustre analogue)

GiB = float(1 << 30)


def _stable_hash(name: str) -> int:
    return int.from_bytes(hashlib.blake2b(name.encode(), digest_size=8).digest(),
                          "big")


# --------------------------------------------------------------------- tiers
@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One level of the per-node storage hierarchy.

    ``capacity_bytes`` is PER NODE (``inf`` = unbounded); ``gbps`` is the
    sustained read/write bandwidth of the medium in bytes/s (``inf`` = free,
    which is how the flat hierarchy keeps the original two-tier cost model).
    """

    name: str
    capacity_bytes: float = float("inf")
    gbps: float = float("inf")


class StorageHierarchy:
    """Ordered node-local tiers (fastest first) + the shared remote PFS tier.

    The hierarchy answers three questions for the store: where does a fresh
    object land (``top``), where does an eviction victim go (``next_down`` —
    ``None`` past the last node tier, meaning "spill to remote"), and how fast
    is a tier's medium (``bw``).
    """

    def __init__(self, tiers: Sequence[TierSpec],
                 remote: TierSpec | None = None) -> None:
        if not tiers:
            raise ValueError("need at least one node-local tier")
        self.tiers = tuple(tiers)
        self.remote = remote or TierSpec("remote")
        names = [t.name for t in self.tiers] + [self.remote.name]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self._spec = {t.name: t for t in self.tiers}
        self._spec[self.remote.name] = self.remote
        self._order = {t.name: i for i, t in enumerate(self.tiers)}
        self._rank = dict(self._order)
        self._rank[self.remote.name] = len(self.tiers)

    @property
    def top(self) -> str:
        return self.tiers[0].name

    @property
    def bottom(self) -> str:
        """The slowest (largest) node-local tier — bulk staging target."""
        return self.tiers[-1].name

    def names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.tiers) + (self.remote.name,)

    def is_node_tier(self, tier: str) -> bool:
        return tier in self._order

    def normalize(self, tier: str | None) -> str:
        """Map legacy/foreign tier names onto this hierarchy's node tiers."""
        if tier is None or tier == "node" or tier == self.remote.name:
            return self.top
        if tier in self._order:
            return tier
        # e.g. a scheduler asking for "hbm" against the flat hierarchy
        return self.top

    def spec(self, tier: str) -> TierSpec:
        return self._spec[tier]

    def capacity(self, tier: str) -> float:
        return self._spec[tier].capacity_bytes

    def bw(self, tier: str) -> float:
        spec = self._spec.get(tier)
        return spec.gbps if spec is not None else float("inf")

    def rank(self, tier: str) -> int:
        """Position in the hierarchy (0 = fastest; unknown sorts below all)."""
        return self._rank.get(tier, len(self._rank))

    def next_down(self, tier: str) -> str | None:
        """The demotion target below ``tier`` (None = spill to remote)."""
        i = self._order[tier]
        if i + 1 < len(self.tiers):
            return self.tiers[i + 1].name
        return None

    def media_seconds(self, nbytes: float, tier: str) -> float:
        bw = self.bw(tier)
        return 0.0 if bw == float("inf") else nbytes / bw


#: The original two-tier model: one unbounded, free host tier per node plus
#: the remote PFS. All existing cost accounting reduces to link bandwidths.
FLAT_HIERARCHY = StorageHierarchy([TierSpec("host")])


def tiered_hierarchy(*, hbm_bytes: float = 16 * GiB,
                     host_bytes: float = 64 * GiB,
                     bb_bytes: float = 256 * GiB,
                     hbm_gbps: float = 819e9, host_gbps: float = 100e9,
                     bb_gbps: float = 8e9, remote_gbps: float = 2e9,
                     ) -> StorageHierarchy:
    """Device-HBM / host-DRAM / burst-buffer / PFS — the HPC storage gradient."""
    return StorageHierarchy(
        [TierSpec("hbm", hbm_bytes, hbm_gbps),
         TierSpec("host", host_bytes, host_gbps),
         TierSpec("bb", bb_bytes, bb_gbps)],
        remote=TierSpec("remote", float("inf"), remote_gbps))


@dataclasses.dataclass
class Placement:
    """Where an object lives: one or more node ids (+ the remote tier).

    ``nodes`` is a tuple because the store supports replication; the paper's
    ``real-loc`` is ``nodes[0]``. ``xattr`` is the extended-attribute dict the
    paper stores location metadata in. ``tiers``, when set by a tiered store,
    is aligned with ``nodes`` and names the storage tier of each replica;
    ``tier`` alone describes the primary replica (kept for the two-tier API).
    """

    nodes: tuple[int, ...]
    tier: str = "host"                      # tier of nodes[0]
    xattr: dict[str, Any] = dataclasses.field(default_factory=dict)
    tiers: tuple[str, ...] | None = None    # per-replica tiers (tiered store)

    @property
    def real_loc(self) -> int:
        return self.nodes[0]

    def resident_on(self, node: int) -> bool:
        return node in self.nodes

    def tier_on(self, node: int) -> str:
        """Tier of the replica on ``node`` (falls back to ``tier``/remote)."""
        if self.tiers is not None:
            for n, t in zip(self.nodes, self.tiers):
                if n == node:
                    return t
        if node == REMOTE_TIER:
            return "remote"
        return self.tier


@dataclasses.dataclass(frozen=True)
class SimObject:
    """A sized placeholder used by the simulator (no actual payload)."""

    nbytes: float


@dataclasses.dataclass(frozen=True)
class TierHop:
    """One hop of a movement through the storage hierarchy."""

    src_node: int
    src_tier: str
    dst_node: int
    dst_tier: str
    nbytes: float
    est_seconds: float


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One data movement the store performed (fetch, demotion, promotion).

    ``hops`` itemizes the path through the hierarchy; ``est_seconds`` is the
    storage-layer media time (tier read + write) — the network link time on
    top of it is the hardware model's business (simulator/compiler add it).
    """

    name: str
    nbytes: float
    src: int
    dst: int
    src_tier: str = "host"
    dst_tier: str = "host"
    est_seconds: float = 0.0
    # fetch | demote | promote | migrate (runtime re-pin) |
    # spill (put overflow straight to the PFS) |
    # writeback (async dirty flush) | writearound (streaming PFS write) |
    # fsync (durability-policy flush: synchronous, ack- or barrier-blocking)
    kind: str = "fetch"
    hops: tuple[TierHop, ...] = ()

    @property
    def local(self) -> bool:
        return self.src == self.dst

    @property
    def remote(self) -> bool:
        return self.src == REMOTE_TIER or self.dst == REMOTE_TIER


def sizeof(value: Any) -> float:
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return float(nb)
    if isinstance(value, (bytes, bytearray, memoryview)):
        return float(len(value))
    return float(64)  # opaque python object — metadata-sized


# ---------------------------------------------------------------- write-back
@dataclasses.dataclass(frozen=True)
class WriteBackEntry:
    """One dirty replica spilled off the node tiers, awaiting its PFS flush."""

    name: str
    node: int                 # node the replica was evicted from
    src_tier: str             # tier it was evicted out of
    nbytes: float
    est_seconds: float        # media time of the flush (tier read + PFS write)
    seq: int                  # enqueue order (drain is FIFO)


class WriteBackQueue:
    """FIFO of pending asynchronous PFS writes.

    The store *enqueues* when a dirty victim falls off the bottom node tier;
    the runtime *drains* off the critical path (simulator: background NIC
    lane, executor: drainer thread). Draining an entry is what makes the PFS
    copy durable — :meth:`LocStore.drain_writebacks` clears the dirty bits.
    Entries for overwritten/deleted objects are cancelled, not flushed.
    """

    def __init__(self) -> None:
        self._q: collections.deque[WriteBackEntry] = collections.deque()
        self._lock = threading.Lock()
        self._seq = 0
        # cancelled entries stay queued as tombstones so every queue slot is
        # consumed by exactly one pop — the simulator pairs one flush-done
        # event with one slot, and removal would shift later flushes onto
        # earlier events' completion times
        self._cancelled: set[int] = set()
        self.enqueued = 0
        self.drained = 0
        self.cancelled = 0
        self.bytes_enqueued = 0.0
        self.bytes_drained = 0.0

    def push(self, name: str, node: int, src_tier: str, nbytes: float,
             est_seconds: float) -> WriteBackEntry:
        with self._lock:
            entry = WriteBackEntry(name, node, src_tier, nbytes, est_seconds,
                                   self._seq)
            self._seq += 1
            self._q.append(entry)
            self.enqueued += 1
            self.bytes_enqueued += nbytes
            return entry

    def pop(self) -> tuple[WriteBackEntry, bool] | None:
        """Consume one queue slot: (entry, live). ``live=False`` means the
        entry was cancelled — the caller must not flush it, but the slot
        still pairs with its scheduled completion."""
        with self._lock:
            if not self._q:
                return None
            entry = self._q.popleft()
            if entry.seq in self._cancelled:
                self._cancelled.discard(entry.seq)
                return entry, False
            self.drained += 1
            self.bytes_drained += entry.nbytes
            return entry, True

    def cancel(self, name: str) -> int:
        """Tombstone pending flushes of ``name`` (its version is gone).
        Returns how many entries were cancelled."""
        with self._lock:
            n = 0
            for e in self._q:
                if e.name == name and e.seq not in self._cancelled:
                    self._cancelled.add(e.seq)
                    n += 1
            self.cancelled += n
            return n

    def cancel_node(self, node: int) -> list[WriteBackEntry]:
        """Tombstone every pending flush *sourced* on ``node`` (the node
        died: the bytes will never cross the network). Returns the cancelled
        entries so the caller can revoke the logical PFS residency each one
        pre-recorded."""
        with self._lock:
            out: list[WriteBackEntry] = []
            for e in self._q:
                if e.node == node and e.seq not in self._cancelled:
                    self._cancelled.add(e.seq)
                    out.append(e)
            self.cancelled += len(out)
            return out

    def pending_for(self, name: str) -> list[WriteBackEntry]:
        with self._lock:
            return [e for e in self._live() if e.name == name]

    def _live(self) -> list[WriteBackEntry]:
        return [e for e in self._q if e.seq not in self._cancelled]

    def has(self, name: str) -> bool:
        with self._lock:
            return any(e.name == name for e in self._live())

    def pending_bytes(self) -> float:
        with self._lock:
            return sum(e.nbytes for e in self._live())

    def __len__(self) -> int:
        with self._lock:
            return len(self._live())

    def report(self) -> Mapping[str, float]:
        with self._lock:
            return {"enqueued": float(self.enqueued),
                    "drained": float(self.drained),
                    "cancelled": float(self.cancelled),
                    "pending": float(len(self._live())),
                    "bytes_enqueued": self.bytes_enqueued,
                    "bytes_drained": self.bytes_drained}


@dataclasses.dataclass(frozen=True)
class DropReport:
    """What :meth:`LocStore.drop_node` did when a node failed.

    ``lost`` names lost their last copy (the caller must re-run producers);
    ``dirty_lost`` is the subset that was dirty — the rerun cost a tighter
    durability window would have avoided. ``survived`` kept a replica
    elsewhere (another node or a *real* — drained — PFS copy).
    ``cancelled_flushes`` counts pending write-backs sourced on the dead node
    that were tombstoned, and ``phantom_remote_revoked`` the logical PFS
    residencies those flushes had pre-recorded but never delivered."""

    node: int
    lost: tuple[str, ...]
    survived: tuple[str, ...]
    dirty_lost: tuple[str, ...]
    cancelled_flushes: int
    phantom_remote_revoked: int
    released_pins: int


@dataclasses.dataclass(frozen=True)
class JoinReport:
    """What :meth:`LocStore.join_node` did when a node (re)joined.

    ``rejoined`` means the id was in the failed set (a revival — its tiers
    start empty, its pin refcounts were already released by ``drop_node``);
    ``grew`` means the id was beyond ``n_nodes`` and the cluster was
    extended to absorb it (scale-out)."""

    node: int
    rejoined: bool
    grew: bool


class LocationService:
    """Distributed location-metadata service (consistent-hash sharded).

    Each shard is an independent dict + lock — the in-process model of one
    metadata server. ``shard_of`` is deterministic so any client can route a
    lookup without coordination. Counters let the benchmarks report per-shard
    load balance (the scalability argument for "distributed" in the paper).

    **Change events.** ``subscribe(fn)`` registers a listener called as
    ``fn(event, key, placement)`` on every metadata change:

    * ``("record", name, placement)`` — ``name`` now resolves to ``placement``
      (creation, replication, demotion, promotion, migration, drain, ...);
    * ``("drop", name, None)`` — ``name`` no longer exists;
    * ``("drop_node", node, None)`` — a whole node failed (relayed by
      :meth:`LocStore.drop_node` after the per-name events).

    This is the scheduler's cache-invalidation channel: an indexed scheduler
    mirrors the name -> Placement map from these events instead of paying a
    hash + shard lock per ``lookup``. Listeners run on the mutating thread
    and may hold the store lock — they must only touch their own state and
    never call back into the store.
    """

    def __init__(self, n_shards: int = 16) -> None:
        if n_shards < 1:
            raise ValueError("need at least one metadata shard")
        self.n_shards = n_shards
        self._shards: list[dict[str, Placement]] = [{} for _ in range(n_shards)]
        self._locks = [threading.Lock() for _ in range(n_shards)]
        self._listeners: list[Any] = []
        self.lookups = [0] * n_shards
        self.records = [0] * n_shards

    def subscribe(self, fn: Any) -> None:
        """Register ``fn(event, key, placement)`` for metadata-change events."""
        self._listeners.append(fn)

    def unsubscribe(self, fn: Any) -> None:
        try:
            self._listeners.remove(fn)
        except ValueError:
            pass

    def notify(self, event: str, key: Any, placement: "Placement | None") -> None:
        for fn in self._listeners:
            fn(event, key, placement)

    def shard_of(self, name: str) -> int:
        return _stable_hash(name) % self.n_shards

    def record(self, name: str, placement: Placement) -> None:
        s = self.shard_of(name)
        with self._locks[s]:
            self._shards[s][name] = placement
            self.records[s] += 1
        self.notify("record", name, placement)

    def lookup(self, name: str) -> Placement | None:
        s = self.shard_of(name)
        with self._locks[s]:
            self.lookups[s] += 1
            return self._shards[s].get(name)

    def drop(self, name: str) -> None:
        s = self.shard_of(name)
        with self._locks[s]:
            self._shards[s].pop(name, None)
        self.notify("drop", name, None)

    def names(self) -> list[str]:
        out: list[str] = []
        for s, lock in zip(self._shards, self._locks):
            with lock:
                out.extend(s.keys())
        return out

    def load_balance(self) -> Mapping[str, Any]:
        sizes = [len(s) for s in self._shards]
        return {"shards": self.n_shards, "entries": sum(sizes),
                "max_shard": max(sizes, default=0),
                "min_shard": min(sizes, default=0),
                "lookups": sum(self.lookups)}


class LocStore:
    """The location-aware compute-node-side store.

    ``nodes`` are integer ids 0..N-1 (plus :data:`REMOTE_TIER`). Thread-safe:
    the executor's worker threads and the prefetch engine hit it concurrently.

    With a capacity-bounded ``hierarchy``, each replica lives in one tier of
    its node; admitting past a tier's capacity demotes the eviction victim
    (``eviction_policy``: "lru", or "cost" = largest-coldest-first) down-tier,
    spilling to the remote PFS only below the last node tier. Reads promote
    the touched object back to the top tier (``promote_on_access``).

    ``write_policy`` sets how that spill happens ("through" = synchronous,
    "back" = dirty-tracked async write-back via :attr:`writeback`); a per-put
    ``mode=`` overrides it ("around" = stream straight to the PFS, read-once).
    ``coordinated_eviction`` makes ``_victim`` consult the LocationService:
    replicas duplicated elsewhere in the cluster are evicted (dropped, free)
    before sole copies, which are demoted down-tier and never dropped.
    """

    def __init__(self, n_nodes: int, *, n_meta_shards: int = 16,
                 default_policy: str = "hash",
                 hierarchy: StorageHierarchy | None = None,
                 eviction_policy: str = "lru",
                 promote_on_access: bool = True,
                 write_policy: str = "through",
                 coordinated_eviction: bool = False,
                 durability: str = "none",
                 topology: Any | None = None) -> None:
        if n_nodes < 1:
            raise ValueError("need at least one node")
        if eviction_policy not in ("lru", "cost"):
            raise ValueError(f"unknown eviction policy {eviction_policy!r}")
        if write_policy not in ("through", "back"):
            raise ValueError(f"store-wide write policy must be 'through' or "
                             f"'back', not {write_policy!r} — 'around' is "
                             f"per-object (put(..., mode='around'))")
        if durability not in DURABILITY_POLICIES:
            raise ValueError(f"unknown durability policy {durability!r} "
                             f"(want one of {DURABILITY_POLICIES})")
        self.n_nodes = n_nodes
        self.durability = durability
        # optional repro.core.topology.ClusterTopology: placement spreads
        # across racks (failure domains), reads prefer rack-local replicas,
        # and re-replication favors rack diversity. None or a *flat*
        # topology keeps every decision identical to the flat model.
        self.topology = topology
        self._topo_real = (topology if topology is not None
                           and not topology.flat else None)
        self.loc = LocationService(n_meta_shards)
        self.default_policy = default_policy
        self.hierarchy = hierarchy or FLAT_HIERARCHY
        self.eviction_policy = eviction_policy
        self.promote_on_access = promote_on_access
        self.write_policy = write_policy
        self.coordinated_eviction = coordinated_eviction
        self.writeback = WriteBackQueue()
        self._values: dict[str, Any] = {}
        self._sizes: dict[str, float] = {}
        # replica map: name -> {node: tier} (insertion order = primary first)
        self._residency: dict[str, dict[int, str]] = {}
        self._usage: dict[tuple[int, str], float] = {}
        self._last_access: dict[tuple[int, str], dict[str, int]] = {}
        # dirty objects: the current version has no durable PFS backing yet.
        # Replicas never diverge (a put replaces every copy), so the object
        # bit + the residency map IS the per-replica dirty state —
        # ``is_dirty(name, node)`` reads it per replica.
        self._dirty: set[str] = set()
        self._mode: dict[str, str] = {}       # per-object write mode
        # do-not-evict pin counts per (name, node) — the scheduler's shield
        # around prefetched replicas until their consumer has run
        self._pins: dict[tuple[str, int], int] = {}
        self._clock = 0
        self._lock = threading.RLock()
        self._rr = 0
        # accounting
        self.transfers: list[Transfer] = []
        self.bytes_moved = 0.0
        self.bytes_local = 0.0
        self.remote_bytes = 0.0        # network bytes touching the PFS tier
        self.bytes_demoted = 0.0
        self.demotions = 0
        self.promotions = 0
        self.bytes_promoted = 0.0      # bytes moved up-tier (warm/prefetch wins)
        self.migrations = 0
        self.tier_reads: dict[str, float] = {}
        # write-back / coordinated-eviction accounting
        self.writebacks = 0
        self.writeback_bytes = 0.0     # dirty bytes queued for async flush
        self.clean_drops = 0           # clean victims dropped (PFS had them)
        self.bytes_clean_dropped = 0.0
        self.coord_drops = 0           # replicated victims dropped, not moved
        self.bytes_coord_dropped = 0.0
        self.coordination_violations = 0   # a drop would have lost data (never)
        self.pin_protected_evictions = 0   # evictions a pin actually diverted
        # durability / failure accounting
        self.fsyncs = 0                # synchronous durability flushes
        self.fsync_bytes = 0.0
        self.phantom_durable = 0       # drains that would have laundered a
        # dead node's un-flushed bytes into a "durable" PFS copy (always 0
        # when failures go through drop_node — this is defense in depth)
        # membership / re-replication accounting
        self.rereplications = 0
        self.bytes_rereplicated = 0.0
        self._failed_nodes: set[int] = set()
        # sorted alive-node ids — default placement maps over this list so
        # hash/rr mass redistributes uniformly when nodes fail (no linear
        # probing, which would dump a dead run's mass on its first survivor)
        self._alive: list[int] = list(range(n_nodes))

    # ------------------------------------------------------------ placement
    def _default_placement(self, name: str) -> Placement:
        """Map over the *alive* list, not the full id range: indexing
        ``alive[h % len(alive)]`` keeps placement near-uniform across
        survivors no matter which nodes are down. (The old linear probe
        ``(node + 1) % n_nodes`` handed a dead run's entire hash/rr mass to
        its first surviving successor.) With nothing failed the alive list
        is ``range(n_nodes)`` and the mapping is identical to the original.

        Under a real topology the alive list is re-ordered rack-interleaved
        (:meth:`_spread_order`), so consecutive hash/rr indices land in
        different racks — default placement spreads across failure domains.
        With one rack (flat/one-switch) the interleave is the identity, so
        flat placement stays bit-identical."""
        with self._lock:
            alive = self._alive
            if not alive:
                raise RuntimeError("every node has failed")
            if self._topo_real is not None:
                alive = self._spread_order()
            if self.default_policy == "hash":   # Hercules/Memcached behaviour
                node = alive[_stable_hash(name) % len(alive)]
            elif self.default_policy == "rr":
                node = alive[self._rr % len(alive)]
                self._rr += 1
            else:
                raise ValueError(
                    f"unknown default policy {self.default_policy!r}")
        return Placement(nodes=(node,), tier=self.hierarchy.top)

    def _spread_order(self) -> list[int]:
        """The alive nodes re-ordered rack-interleaved: position-within-rack
        major, rack minor — walking the list round-robins the racks, so any
        consecutive window of default placements spans as many failure
        domains as possible. Cached per alive-list generation (membership
        changes are rare next to placements)."""
        alive = self._alive
        key = (len(alive), alive[0] if alive else -1, alive[-1] if alive else -1)
        cached = getattr(self, "_spread_cache", None)
        if cached is not None and cached[0] == key and cached[1] == alive:
            return cached[2]
        topo = self._topo_real
        seen: dict[int, int] = {}
        keyed: list[tuple[int, int, int]] = []
        for n in alive:
            r = topo.rack(n)
            k = seen.get(r, 0)
            seen[r] = k + 1
            keyed.append((k, r, n))
        keyed.sort()
        order = [n for _, _, n in keyed]
        self._spread_cache = (key, list(alive), order)
        return order

    def _norm_loc(self, loc: Any) -> Placement:
        if isinstance(loc, Placement):
            return loc
        if isinstance(loc, int):
            return Placement(nodes=(loc,), tier=self.hierarchy.top)
        if isinstance(loc, (tuple, list)):
            return Placement(nodes=tuple(int(n) for n in loc),
                             tier=self.hierarchy.top)
        raise TypeError(f"cannot interpret location {loc!r}")

    # ------------------------------------------------- tier admission (LRU)
    def _touch(self, name: str, node: int, tier: str) -> None:
        self._clock += 1
        self._last_access.setdefault((node, tier), {})[name] = self._clock

    # ------------------------------------------------------- dirty tracking
    def is_dirty(self, name: str, node: int | None = None) -> bool:
        """True if ``name`` (or specifically its replica on ``node``) lacks a
        durable PFS copy of the current version."""
        with self._lock:
            if name not in self._dirty:
                return False
            if node is None:
                return True
            return node in self._residency.get(name, {})

    def write_mode(self, name: str) -> str:
        """Effective write policy of one object ("through"/"back"/"around")."""
        return self._mode.get(name, self.write_policy)

    def durable(self, name: str) -> bool:
        """True when the PFS holds the *current* version of ``name`` — the
        object would survive losing every node-local replica. A pending
        (undrained) write-back does NOT make an object durable: the bytes
        have not crossed the network yet."""
        with self._lock:
            return name in self._values and name not in self._dirty

    @property
    def failed_nodes(self) -> frozenset[int]:
        with self._lock:
            return frozenset(self._failed_nodes)

    # -------------------------------------------------- do-not-evict pinning
    def pin(self, name: str, node: int) -> None:
        """Mark ``name``'s replica on ``node`` do-not-evict (refcounted).

        The ProactiveScheduler pins a replica it prefetched until the
        consuming task finishes, so capacity pressure elsewhere on the node
        cannot drop the duplicate it just created (the "prefetch undone by
        coordinated eviction at comfortable capacity" ROADMAP bug)."""
        with self._lock:
            key = (name, node)
            self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, name: str, node: int) -> None:
        """Release one pin; unknown pins are ignored (the replica may have
        been deleted or its node failed while pinned)."""
        with self._lock:
            key = (name, node)
            n = self._pins.get(key, 0) - 1
            if n > 0:
                self._pins[key] = n
            else:
                self._pins.pop(key, None)

    def is_pinned(self, name: str, node: int) -> bool:
        with self._lock:
            return self._pins.get((name, node), 0) > 0

    # --------------------------------------------------------------- victims
    def _replicas_elsewhere(self, name: str,
                            node: int, tier: str) -> list[tuple[int, str]]:
        """Other replicas of ``name`` beyond the one at (node, tier), per the
        LocationService — the cluster-wide view coordinated eviction ranks
        victims by. Falls back to the residency map if the service has no
        record (mid-update)."""
        p = self.loc.lookup(name)
        if p is not None and p.tiers is not None:
            pairs = list(zip(p.nodes, p.tiers))
        else:
            pairs = list(self._residency.get(name, {}).items())
        return [(n, t) for n, t in pairs if not (n == node and t == tier)]

    def _victim(self, node: int, tier: str, protect: str) -> str | None:
        recency = self._last_access.get((node, tier), {})
        everyone = [n for n in recency if n != protect]
        candidates = [n for n in everyone if not self._pins.get((n, node))]
        if self.eviction_policy == "cost":
            # cost-aware: large, stale objects go first — freeing the most
            # capacity for the least loss of hot data (GreedyDual-Size-ish;
            # with equal sizes it degrades to plain LRU).
            base = lambda n: -(self._sizes.get(n, 0.0)          # noqa: E731
                               * (self._clock - recency[n] + 1))
        else:
            base = lambda n: recency[n]                         # noqa: E731
        if self.coordinated_eviction:
            # Cluster-coordinated: consult the LocationService and evict
            # replicated objects before sole copies. Class 0: another
            # replica in an equal-or-faster tier exists somewhere (this copy
            # is fully redundant). Class 1: only colder duplicates elsewhere
            # (this is the last fast-tier copy — evicting it is still free,
            # but the dataset goes cold). Class 2: sole copy — demoting it
            # moves real bytes.
            my_rank = self.hierarchy.rank(tier)

            def klass(n: str) -> int:
                others = self._replicas_elsewhere(n, node, tier)
                if not others:
                    return 2
                if any(self.hierarchy.rank(t) <= my_rank for _, t in others):
                    return 0
                return 1

            key = lambda n: (klass(n), base(n))                 # noqa: E731
        else:
            key = base
        if not candidates:
            if everyone:        # only pinned choices: the pins blocked this
                self.pin_protected_evictions += 1
            return None
        choice = min(candidates, key=key)
        if len(candidates) != len(everyone):
            # count a protection only when a pin CHANGED the outcome — the
            # unpinned ranking would have evicted a pinned replica instead
            if min(everyone, key=key) != choice:
                self.pin_protected_evictions += 1
        return choice

    def _evict(self, victim: str, node: int, tier: str,
               hops: list[TierHop] | None) -> None:
        """Evict one replica: coordinated mode drops replicas that are
        duplicated elsewhere (free — a copy survives), everything else is
        demoted down-tier. Sole copies are NEVER dropped."""
        if self.coordinated_eviction:
            others = self._replicas_elsewhere(victim, node, tier)
            # belt and braces: only drop when the residency map agrees a
            # duplicate survives — the LocationService can lag mid-update
            live = [n for n, t in self._residency.get(victim, {}).items()
                    if not (n == node and t == tier)]
            if others and live:
                self._drop_replica(victim, node, tier)
                self.coord_drops += 1
                self.bytes_coord_dropped += self._sizes.get(victim, 0.0)
                return
            if others and not live:
                self.coordination_violations += 1   # lagging metadata — demote
        self._demote(victim, node, tier, hops)

    def _drop_replica(self, name: str, node: int, tier: str) -> None:
        res = self._residency.get(name)
        if res is None or res.get(node) != tier:
            return
        del res[node]
        key = (node, tier)
        self._usage[key] = max(self._usage.get(key, 0.0)
                               - self._sizes.get(name, 0.0), 0.0)
        self._last_access.get(key, {}).pop(name, None)

    def _record_pfs_write(self, name: str, node: int, src_tier: str,
                          nbytes: float, kind: str,
                          hops: list[TierHop] | None, *,
                          read_src_tier: bool = False) -> None:
        """The one place PFS-bound writes hit the ledger AND the scalars —
        a hand-copied variant of this block is how the PR 2 spill-accounting
        mismatch happened. ``read_src_tier`` adds the media time of reading
        the evicted tier (a spill of data that never resided there, e.g. a
        put overflow, pays only the PFS write). Caller holds the lock."""
        est = self.hierarchy.media_seconds(nbytes, "remote")
        if read_src_tier:
            est += self.hierarchy.media_seconds(nbytes, src_tier)
        hop = TierHop(node, src_tier, REMOTE_TIER, "remote", nbytes, est)
        if hops is not None:
            hops.append(hop)
        self.bytes_moved += nbytes
        self.remote_bytes += nbytes
        self.transfers.append(Transfer(
            name, nbytes, node, REMOTE_TIER, src_tier=src_tier,
            dst_tier="remote", est_seconds=est, kind=kind, hops=(hop,)))

    def _admit(self, name: str, node: int, tier: str,
               hops: list[TierHop] | None = None, *,
               spill: bool = False, record_spill: bool = False,
               origin_tier: str | None = None) -> str:
        """Place ``name``'s replica at (node, tier), evicting victims to fit.

        Returns the tier the object actually landed in (an object larger than
        every node tier cascades straight down to the remote PFS). Caller
        holds the lock. Demotion hops are appended to ``hops`` and recorded as
        ``kind="demote"`` transfers. ``spill=True`` means landing on the
        remote tier is capacity-forced data movement (counted in
        ``bytes_moved``/``remote_bytes``), not a caller-pinned PFS placement;
        ``record_spill=True`` additionally logs that crossing as a
        ``kind="spill"`` Transfer (``_demote`` records its own transfer, so it
        passes False). A synchronous landing on the PFS makes the durable
        copy current, clearing the object's dirty bit.
        """
        nbytes = self._sizes.get(name, 0.0)
        if node == REMOTE_TIER or not self.hierarchy.is_node_tier(tier):
            res = self._residency.setdefault(name, {})
            if spill and REMOTE_TIER not in res:
                if record_spill and node != REMOTE_TIER:
                    self._record_pfs_write(
                        name, node, origin_tier or self.hierarchy.top,
                        nbytes, "spill", hops)
                else:       # _demote records its own transfer for this spill
                    self.bytes_moved += nbytes
                    self.remote_bytes += nbytes
            res[REMOTE_TIER] = "remote"
            self._dirty.discard(name)          # PFS now holds this version
            return "remote"
        cap = self.hierarchy.capacity(tier)
        if nbytes > cap:                       # cannot ever fit: skip down
            down = self.hierarchy.next_down(tier)
            return self._admit(name, node,
                               down if down is not None else "remote", hops,
                               spill=spill, record_spill=record_spill,
                               origin_tier=origin_tier or tier)
        res = self._residency.setdefault(name, {})
        old = res.get(node)
        if old == tier:
            self._touch(name, node, tier)
            return tier
        if old is not None:                    # moving between tiers on-node
            self._drop_replica(name, node, old)
        key = (node, tier)
        self._usage[key] = self._usage.get(key, 0.0) + nbytes
        res[node] = tier
        self._touch(name, node, tier)
        # cascade-evict until this tier fits again
        while self._usage.get(key, 0.0) > cap:
            victim = self._victim(node, tier, protect=name)
            if victim is None:
                break
            self._evict(victim, node, tier, hops)
            self._sync_placement(victim)
        return tier

    def _demote(self, name: str, node: int, tier: str,
                hops: list[TierHop] | None = None) -> None:
        """Move one replica a tier down (to the remote PFS past the bottom).

        Past the bottom node tier the object's write policy decides the spill:
        write-through moves the bytes synchronously; write-back drops clean
        victims for free (the PFS already holds them) and enqueues dirty ones
        on the :class:`WriteBackQueue` for an asynchronous flush.
        """
        nbytes = self._sizes.get(name, 0.0)
        down = self.hierarchy.next_down(tier)
        while down is not None and nbytes > self.hierarchy.capacity(down):
            down = self.hierarchy.next_down(down)
        if down is None:                       # next stop: the parallel FS
            if (REMOTE_TIER in self._residency.get(name, {})
                    and name not in self._dirty):
                # the PFS already holds this exact version — eviction is a
                # free drop, not a second write (both policies agree; this is
                # the ledger/scalar mismatch the PR 2 review flagged)
                self._drop_replica(name, node, tier)
                self.clean_drops += 1
                self.bytes_clean_dropped += nbytes
                return
            if self.write_mode(name) == "back":
                self._writeback_evict(name, node, tier, nbytes, hops)
                return
        self._drop_replica(name, node, tier)
        landed = self._admit(name, node,
                             down if down is not None else "remote", hops,
                             spill=True)
        if landed == "remote":
            dst_node, dst_tier = REMOTE_TIER, "remote"
        else:
            dst_node, dst_tier = node, landed
        est = (self.hierarchy.media_seconds(nbytes, tier)
               + self.hierarchy.media_seconds(nbytes, dst_tier))
        hop = TierHop(node, tier, dst_node, dst_tier, nbytes, est)
        if hops is not None:
            hops.append(hop)
        self.bytes_demoted += nbytes
        self.demotions += 1
        self.transfers.append(Transfer(
            name, nbytes, node, dst_node, src_tier=tier, dst_tier=dst_tier,
            est_seconds=est, kind="demote", hops=(hop,)))

    def _writeback_evict(self, name: str, node: int, tier: str,
                         nbytes: float, hops: list[TierHop] | None) -> None:
        """Evict a dirty replica past the bottom node tier, write-back style:
        record the (logical) move to the remote tier now, enqueue the flush;
        the bytes cross the network when the runtime drains the queue, off
        the critical path. Caller holds the lock (clean replicas were already
        dropped for free by ``_demote``)."""
        self._drop_replica(name, node, tier)
        res = self._residency.setdefault(name, {})
        res[REMOTE_TIER] = "remote"
        if self.writeback.has(name):           # flush of this version pending
            return
        self._record_pfs_write(name, node, tier, nbytes, "writeback", hops,
                               read_src_tier=True)
        self.bytes_demoted += nbytes
        self.demotions += 1
        self.writebacks += 1
        self.writeback_bytes += nbytes
        self.writeback.push(name, node, tier, nbytes,
                            self.transfers[-1].est_seconds)

    def drain_writebacks(self, max_entries: int | None = None
                         ) -> list[WriteBackEntry]:
        """Flush pending asynchronous PFS writes, FIFO.

        The runtime calls this off the critical path (simulator: when it
        charges the background NIC lane; executor: drainer thread). Each
        drained entry makes the PFS copy durable, clearing the object's dirty
        bit. Entries whose object was deleted meanwhile are skipped (their
        enqueue-time accounting stands — the modelled bytes were in flight).
        """
        out: list[WriteBackEntry] = []
        consumed = 0
        while max_entries is None or consumed < max_entries:
            # pop under the store lock: put()/delete() cancel stale entries
            # while holding it, so an overwrite can never slip between the
            # pop and the dirty-bit clear and get its NEW version marked
            # durable on the strength of the OLD version's flush
            with self._lock:
                popped = self.writeback.pop()
                if popped is None:
                    break
                consumed += 1
                entry, live = popped
                if not live:            # tombstone: consume the slot only
                    continue
                if entry.node in self._failed_nodes:
                    # defense in depth: drop_node tombstones these, but a
                    # flush sourced on a dead node must NEVER launder the
                    # lost bytes into a "durable" PFS copy
                    self.phantom_durable += 1
                    continue
                if entry.name in self._values:
                    self._dirty.discard(entry.name)
                    res = self._residency.setdefault(entry.name, {})
                    res[REMOTE_TIER] = "remote"
                    self._sync_placement(entry.name)
            out.append(entry)
        return out

    # ------------------------------------------------- durability / failure
    def _fsync_object(self, name: str) -> bool:
        """Synchronously make ``name``'s current version durable on the PFS
        (``kind="fsync"`` transfer — the runtime charges it to the demand NIC
        lane: an ack/barrier waits on it). Supersedes any pending async
        flush. Caller holds the lock. Returns True if bytes moved."""
        if name not in self._dirty or name not in self._values:
            return False
        res = self._residency.setdefault(name, {})
        srcs = [n for n in res if n != REMOTE_TIER
                and n not in self._failed_nodes]
        if srcs:
            src = min(srcs, key=lambda n: self.hierarchy.rank(res[n]))
            src_tier = res[src]
        else:
            # writeback-evicted: the only residency is the flush's logical
            # REMOTE promise — the bytes still sit on the evicting node's
            # tier (that is what the queue entry records) until flushed
            pend = [e for e in self.writeback.pending_for(name)
                    if e.node not in self._failed_nodes]
            if not pend:
                return False               # no live replica to read from
            src, src_tier = pend[0].node, pend[0].src_tier
        nbytes = self._sizes.get(name, 0.0)
        self.writeback.cancel(name)        # the fsync IS the flush
        self._record_pfs_write(name, src, src_tier, nbytes, "fsync", None,
                               read_src_tier=True)
        res[REMOTE_TIER] = "remote"
        self._dirty.discard(name)
        self.fsyncs += 1
        self.fsync_bytes += nbytes
        self._sync_placement(name)
        return True

    def fsync(self, names: Iterable[str] | None = None) -> int:
        """Force-flush dirty objects to the PFS (all of them, or ``names``).
        Returns how many objects moved bytes."""
        with self._lock:
            todo = list(names) if names is not None else list(self._dirty)
            return sum(self._fsync_object(n) for n in todo)

    def barrier(self) -> int:
        """The ``fsync_on_barrier`` sync point: everything dirty becomes
        durable now. The runtime calls this at workflow barriers (simulator:
        every ``barrier_every`` task finishes; executor: after each task's
        outputs are put)."""
        return self.fsync()

    def drop_node(self, node: int) -> DropReport:
        """Atomically handle the failure of ``node``.

        One lock hold: (1) cancel pending write-back flushes sourced on the
        node and revoke the logical PFS residency they pre-recorded (the
        flush never delivered — leaving it would let a later drain mark the
        lost object durable: the phantom-PFS-copy bug), (2) forget every
        replica the node held, (3) clear the node's pin refcounts, then
        delete objects whose last copy died so ``exists()`` turns False and
        the caller can re-run producers."""
        with self._lock:
            self._failed_nodes.add(node)
            i = bisect.bisect_left(self._alive, node)
            if i < len(self._alive) and self._alive[i] == node:
                del self._alive[i]
            lost: list[str] = []
            survived: list[str] = []
            dirty_lost: list[str] = []
            # (1) in-flight flushes sourced on the dead node will never land
            phantom = 0
            cancelled = self.writeback.cancel_node(node)
            for e in cancelled:
                if e.name not in self._dirty:
                    continue               # a later fsync already delivered
                res = self._residency.get(e.name)
                if res is not None and res.get(REMOTE_TIER) == "remote":
                    del res[REMOTE_TIER]   # the promised PFS copy is a lie
                    phantom += 1
                    if not res:
                        # the phantom was the only residency: the dirty
                        # version lived nowhere but the dead node's queue
                        lost.append(e.name)
                        dirty_lost.append(e.name)
            # (2) replicas on the dead node
            for name in list(self._residency):
                res = self._residency[name]
                if node not in res:
                    continue
                self._drop_replica(name, node, res[node])
                if res:
                    survived.append(name)
                elif name not in lost:
                    lost.append(name)
                    if name in self._dirty:
                        dirty_lost.append(name)
            # (3) the node's pin refcounts shield nothing anymore
            released = 0
            for key in [k for k in self._pins if k[1] == node]:
                released += self._pins.pop(key)
            for name in lost:
                self.delete(name)          # data gone: producers must re-run
            for name in survived:
                self._sync_placement(name)
        # after the per-name record/drop events: one node-level event so
        # subscribers (schedulers) can purge per-node caches — stale
        # pre-assignments and prefetched-replica markers for the dead node
        self.loc.notify("drop_node", node, None)
        return DropReport(node=node, lost=tuple(lost),
                          survived=tuple(survived),
                          dirty_lost=tuple(dirty_lost),
                          cancelled_flushes=len(cancelled),
                          phantom_remote_revoked=phantom,
                          released_pins=released)

    def join_node(self, node: int) -> JoinReport:
        """Admit ``node`` into the cluster (saxml-style join: the node
        announces itself, the admin side updates membership).

        Handles both halves of elasticity: a *rejoin* clears the failed
        mark left by :meth:`drop_node` (the node returns with empty tiers —
        its data died with it), and a *growth* join extends ``n_nodes`` for
        a brand-new id. Either way the node re-enters default placement and
        a ``("join_node", node, None)`` event is published so event-driven
        subscribers (indexed scheduler mirrors, preplace eligibility, the
        simulator's candidate index and cached cluster views) absorb the
        newcomer without a rescan."""
        if node < 0:
            raise ValueError(f"node id must be >= 0, got {node}")
        with self._lock:
            rejoined = node in self._failed_nodes
            grew = node >= self.n_nodes
            self._failed_nodes.discard(node)
            if grew:
                # a gapped growth join (node 5 into a 4-node cluster) must
                # NOT silently admit the skipped ids: mark them failed so
                # alive + failed always partitions range(n_nodes) and a
                # later join_node/revive_node can admit them explicitly
                self._failed_nodes.update(range(self.n_nodes, node))
                self.n_nodes = node + 1
            i = bisect.bisect_left(self._alive, node)
            if i == len(self._alive) or self._alive[i] != node:
                self._alive.insert(i, node)
            # a rejoining node starts cold: defensively purge any residual
            # per-node state (drop_node already cleared these — this guards
            # against a join for a node that never went through drop_node)
            for key in [k for k in self._usage if k[0] == node]:
                del self._usage[key]
            for key in [k for k in self._last_access if k[0] == node]:
                del self._last_access[key]
            for key in [k for k in self._pins if k[1] == node]:
                del self._pins[key]
        self.loc.notify("join_node", node, None)
        return JoinReport(node=node, rejoined=rejoined, grew=grew)

    def revive_node(self, node: int) -> JoinReport:
        """Re-admit a node that previously failed (strict :meth:`join_node`:
        raises if ``node`` is not currently in the failed set)."""
        with self._lock:
            if node not in self._failed_nodes:
                raise ValueError(f"node {node} is not failed — use "
                                 f"join_node() for growth joins")
        return self.join_node(node)

    def rereplication_candidates(self, node: int, *,
                                 max_bytes: float = float("inf"),
                                 only_src: int | None = None
                                 ) -> list[tuple[str, int, str, float]]:
        """Objects worth copying toward ``node``, riskiest first.

        A candidate has exactly ONE node-local replica (a real PFS copy
        does not count — re-replication is about node-local locality and
        loss exposure), lives on a surviving node other than ``node``, and
        is not write-around (those are never replicated). Ordering is the
        write side of ``risk_aware``: *dirty* sole copies first (no durable
        PFS version — losing that node loses the data), then clean sole
        copies; under a real topology, sources in a *different rack* than
        ``node`` rank first within each class (copying them to ``node``
        buys rack-domain diversity — flat topologies make this component
        constant, keeping the order unchanged); largest-first next, name as
        the deterministic tiebreak. ``max_bytes`` caps the greedy budget
        (too-big entries are skipped, smaller ones keep filling).

        ``only_src`` restricts candidates to sole copies living on that one
        node — the predictive trigger draining a straggling/flaky suspect
        before its failure (the budget then applies to the suspect alone).

        Returns ``(name, src_node, src_tier, nbytes)`` tuples."""
        topo = self._topo_real
        out: list[tuple[int, int, float, str, int, str]] = []
        with self._lock:
            for name, res in self._residency.items():
                locals_ = [(n, t) for n, t in res.items() if n != REMOTE_TIER]
                if len(locals_) != 1:
                    continue
                src, src_tier = locals_[0]
                if src == node or src in self._failed_nodes:
                    continue
                if only_src is not None and src != only_src:
                    continue
                if self._mode.get(name, self.write_policy) == "around":
                    continue
                nbytes = self._sizes.get(name, 0.0)
                risk = 0 if name in self._dirty else 1
                diverse = (1 if topo is not None
                           and topo.same_rack(src, node) else 0)
                out.append((risk, diverse, -nbytes, name, src, src_tier))
        out.sort()
        picked: list[tuple[str, int, str, float]] = []
        budget = max_bytes
        for risk, _diverse, neg, name, src, src_tier in out:
            nbytes = -neg
            if nbytes > budget:
                continue
            budget -= nbytes
            picked.append((name, src, src_tier, nbytes))
        return picked

    def rereplicate_to(self, node: int, *, max_bytes: float = float("inf"),
                       tier: str | None = None,
                       only_src: int | None = None) -> tuple[str, ...]:
        """Copy sole-copy objects (dirty first) onto ``node`` — close the
        at-risk window a newcomer opens the capacity to close. ``tier`` is
        the landing tier on the newcomer (default: the hierarchy's bottom —
        bulk re-replication must not shoulder warm data out of fast tiers).
        ``only_src`` drains a single suspect node (predictive trigger)."""
        want = tier if tier is not None else self.hierarchy.bottom
        done: list[str] = []
        for name, _src, _src_tier, nbytes in self.rereplication_candidates(
                node, max_bytes=max_bytes, only_src=only_src):
            self.replicate(name, [node], tier=want)
            self.rereplications += 1
            self.bytes_rereplicated += nbytes
            done.append(name)
        return tuple(done)

    def _sync_placement(self, name: str) -> None:
        """Re-record the LocationService entry from the residency map."""
        res = self._residency.get(name)
        if not res:
            return
        prev = self.loc.lookup(name)
        nodes = tuple(res.keys())
        tiers = tuple(res.values())
        self.loc.record(name, Placement(
            nodes=nodes, tier=tiers[0], tiers=tiers,
            xattr=prev.xattr if prev is not None else {}))

    # ------------------------------------------------------------------ api
    def put(self, name: str, value: Any, *, loc: Any | None = None,
            tier: str | None = None,
            xattr: Mapping[str, Any] | None = None,
            mode: str | None = None) -> Placement:
        """Create an object; ``loc`` is the paper's ``S_LOC`` pinned placement.

        ``tier`` pins the starting tier on every node of the placement
        (default: the hierarchy's top tier — fresh output lands in the fastest
        memory and capacity pressure demotes it from there). ``mode``
        overrides the store's write policy for this object: ``"around"``
        streams it straight to the PFS (run-once output — it never occupies
        node tiers and reads are never cached).
        """
        if mode is not None and mode not in WRITE_POLICIES:
            raise ValueError(f"unknown write mode {mode!r}")
        eff_mode = mode or self.write_policy
        placement = (self._norm_loc(loc) if loc is not None
                     else self._default_placement(name))
        if eff_mode == "around" and (tier is not None
                                     or len(placement.nodes) > 1):
            # the object will live on the PFS only — a tier pin or a
            # multi-node placement contradicts the mode; reject rather than
            # silently drop the caller's pins
            raise ValueError("mode='around' streams to the PFS: it cannot "
                             "honor a tier= pin or a multi-node placement "
                             "(loc names the single producer node)")
        for n in placement.nodes:
            if n != REMOTE_TIER and not (0 <= n < self.n_nodes):
                raise ValueError(f"node {n} out of range for {self.n_nodes} nodes")
        placement.xattr.update(xattr or {})
        placement.xattr.setdefault("ctime", time.time())
        placement.xattr.setdefault("size", sizeof(value))
        want = self.hierarchy.normalize(tier if tier is not None
                                        else placement.tier)
        with self._lock:
            if name in self._residency:      # overwrite: clear old replicas
                for n, t in list(self._residency[name].items()):
                    self._drop_replica(name, n, t)
                self._residency.pop(name, None)
                self._dirty.discard(name)
                self.writeback.cancel(name)  # stale version: never flush it
            self._values[name] = value
            nbytes = sizeof(value)
            self._sizes[name] = nbytes
            self._mode[name] = eff_mode
            if eff_mode == "around":
                # streaming output: written straight past the node tiers to
                # the PFS. A node placement names the producer, so the bytes
                # cross the network now; a PFS placement is the data's origin.
                src = placement.nodes[0]
                res = self._residency.setdefault(name, {})
                res[REMOTE_TIER] = "remote"
                if src != REMOTE_TIER:
                    self._record_pfs_write(name, src, self.hierarchy.top,
                                           nbytes, "writearound", None)
            else:
                for n in placement.nodes:
                    # an explicit PFS placement is where the data starts, not
                    # a movement; a node placement that cascades to the PFS is
                    self._admit(name, n,
                                "remote" if n == REMOTE_TIER else want,
                                spill=n != REMOTE_TIER, record_spill=True,
                                origin_tier=want)
            if REMOTE_TIER in self._residency[name]:
                self._dirty.discard(name)    # the PFS holds this version
            else:
                self._dirty.add(name)        # fresh data, no durable PFS copy
                if self.durability == "flush_before_ack":
                    # the ack is gated on durability: the PFS write happens
                    # NOW (kind="fsync", producer's demand NIC lane)
                    self._fsync_object(name)
            nodes = tuple(self._residency[name].keys())
            tiers = tuple(self._residency[name].values())
        final = Placement(nodes=nodes, tier=tiers[0], tiers=tiers,
                          xattr=placement.xattr)
        self.loc.record(name, final)
        return final

    def exists(self, name: str) -> bool:
        return self.loc.lookup(name) is not None

    def stat(self, name: str) -> Placement:
        p = self.loc.lookup(name)
        if p is None:
            raise KeyError(name)
        return p

    def getxattr(self, name: str, key: str) -> Any:
        """POSIX ``getxattr`` equivalent, incl. the location metadata."""
        p = self.stat(name)
        if key == "real_loc":
            return p.real_loc
        if key == "nodes":
            return p.nodes
        if key == "tier":
            return p.tier
        return p.xattr[key]

    def get(self, name: str, *, at: int | None = None) -> tuple[Any, Transfer | None]:
        """Read an object from node ``at``; returns (value, movement record).

        If the object is resident on ``at`` the movement record is a local hit
        (``Transfer.local``) whose ``est_seconds`` is the resident tier's media
        time, and the replica is promoted back to the top tier; otherwise the
        nearest (highest-tier, then closest) replica is the source and the
        store notes a network transfer. ``at=None`` skips accounting
        (metadata read).
        """
        self.stat(name)                       # raises KeyError if unknown
        with self._lock:
            value = self._values[name]
            if at is None:
                return value, None
            nbytes = self._sizes.get(name, sizeof(value))
            res = self._residency.get(name, {})
            if at in res:
                src_tier = res[at]
                hops: list[TierHop] = [TierHop(at, src_tier, at, src_tier,
                                               nbytes,
                                               self.hierarchy.media_seconds(
                                                   nbytes, src_tier))]
                self._touch(name, at, src_tier)
                dst_tier = src_tier
                if (self.promote_on_access
                        and self.hierarchy.is_node_tier(src_tier)
                        and src_tier != self.hierarchy.top):
                    # victim demotions this admit causes are recorded as
                    # their own kind="demote" transfers, not in our hops
                    landed = self._admit(name, at, self.hierarchy.top)
                    if landed != src_tier:
                        self.promotions += 1
                        self.bytes_promoted += nbytes
                        hops.append(TierHop(
                            at, src_tier, at, landed, nbytes,
                            self.hierarchy.media_seconds(nbytes, landed)))
                        dst_tier = landed
                    self._sync_placement(name)
                t = Transfer(name, nbytes, at, at, src_tier=src_tier,
                             dst_tier=dst_tier,
                             est_seconds=hops[0].est_seconds,
                             kind="fetch", hops=tuple(hops))
                self.bytes_local += nbytes
                self.tier_reads[src_tier] = (self.tier_reads.get(src_tier, 0.0)
                                             + nbytes)
                self.transfers.append(t)
                return value, t
            # remote replica: prefer non-PFS, then the fastest tier, then
            # near — under a real topology "near" means rack-local first
            # (a same-ToR replica skips the spine); the rack component is
            # constant on flat topologies, so flat choices are unchanged
            topo = self._topo_real
            if topo is None:
                src = min(res, key=lambda n: (n == REMOTE_TIER,
                                              self.hierarchy.rank(res[n]),
                                              abs(n - at)))
            else:
                src = min(res, key=lambda n: (n == REMOTE_TIER,
                                              self.hierarchy.rank(res[n]),
                                              0 if topo.same_rack(n, at) else 1,
                                              abs(n - at)))
            src_tier = res[src]
            dst_tier = self.hierarchy.top
            est = (self.hierarchy.media_seconds(nbytes, src_tier)
                   + self.hierarchy.media_seconds(nbytes, dst_tier))
            hop = TierHop(src, src_tier, at, dst_tier, nbytes, est)
            t = Transfer(name, nbytes, src, at, src_tier=src_tier,
                         dst_tier=dst_tier, est_seconds=est, kind="fetch",
                         hops=(hop,))
            self._touch(name, src, src_tier)
            self.bytes_moved += nbytes
            if src == REMOTE_TIER:
                self.remote_bytes += nbytes
            self.tier_reads[src_tier] = (self.tier_reads.get(src_tier, 0.0)
                                         + nbytes)
            self.transfers.append(t)
        return value, t

    def promote(self, name: str, node: int, tier: str | None = None) -> Placement:
        """Explicitly move a replica already resident on ``node`` to ``tier``
        (default: top) — the storage half of a device-targeted prefetch. Use
        :meth:`replicate` to create a replica on a new node."""
        want = self.hierarchy.normalize(tier)
        with self._lock:
            res = self._residency.get(name)
            if res is None or node not in res:
                raise KeyError(f"{name!r} has no replica on node {node}")
            have = res[node]
            if have != want:
                if self.hierarchy.rank(want) < self.hierarchy.rank(have):
                    self.promotions += 1       # moved up-tier; down is a pin
                    self.bytes_promoted += self._sizes.get(name, 0.0)
                self._admit(name, node, want)
            self._sync_placement(name)
        return self.stat(name)

    def migrate(self, name: str, loc: Any) -> Transfer:
        """Re-pin an object (the runtime->FS feedback channel).

        Returns the transfer that re-pinning implies. The value itself stays in
        the in-process dict (host RAM) — on a real deployment this issues the
        copy; device-resident arrays are re-placed by the executor.
        """
        p = self.stat(name)
        new = self._norm_loc(loc)
        new.xattr.update(p.xattr)
        new.xattr["migrated_from"] = p.nodes
        with self._lock:
            nbytes = self._sizes.get(name, 0.0)
            src = p.real_loc
            self.migrations += 1
            if not set(new.nodes) & set(p.nodes):
                self.bytes_moved += nbytes
                if src == REMOTE_TIER or REMOTE_TIER in new.nodes:
                    self.remote_bytes += nbytes
            for n, t in list(self._residency.get(name, {}).items()):
                self._drop_replica(name, n, t)
            self._residency.pop(name, None)
            self._residency[name] = {}
            want = self.hierarchy.normalize(new.tier)
            for n in new.nodes:
                self._admit(name, n, "remote" if n == REMOTE_TIER else want,
                            spill=n != REMOTE_TIER, record_spill=True,
                            origin_tier=want)
            if REMOTE_TIER in self._residency[name]:
                self._dirty.discard(name)
            elif name in self._values:
                # the re-pin dropped the PFS replica: no durable copy anymore
                # (a pending flush, if any, will restore one when drained)
                self._dirty.add(name)
                if self.durability == "flush_before_ack":
                    self._fsync_object(name)   # the window must stay closed
            nodes = tuple(self._residency[name].keys())
            tiers = tuple(self._residency[name].values())
        final = Placement(nodes=nodes, tier=tiers[0], tiers=tiers,
                          xattr=new.xattr)
        self.loc.record(name, final)
        tr = Transfer(name, nbytes, src, final.real_loc,
                      src_tier=p.tier, dst_tier=final.tier, kind="migrate")
        if not set(final.nodes) & set(p.nodes):
            with self._lock:
                self.transfers.append(tr)      # the copy the re-pin implies
        return tr

    def replicate(self, name: str, extra_nodes: Iterable[int],
                  tier: str | None = None) -> Placement:
        """Add replicas (used by the prefetch engine: the original stays).

        ``tier`` targets a tier on the new nodes (default: top — a prefetch
        is supposed to land the data in the fastest memory). Write-around
        objects are read exactly once: replicating them is a no-op — their
        only home is the PFS.
        """
        self.stat(name)                       # raises KeyError if unknown
        if self.write_mode(name) == "around":
            return self.stat(name)
        want = self.hierarchy.normalize(tier)
        with self._lock:
            for n in extra_nodes:
                self._admit(name, int(n),
                            "remote" if int(n) == REMOTE_TIER else want,
                            spill=int(n) != REMOTE_TIER, record_spill=True,
                            origin_tier=want)
            self._sync_placement(name)
        return self.stat(name)

    def delete(self, name: str) -> None:
        with self._lock:
            self._values.pop(name, None)
            for n, t in list(self._residency.get(name, {}).items()):
                self._drop_replica(name, n, t)
            self._residency.pop(name, None)
            self._sizes.pop(name, None)
            self._dirty.discard(name)
            self._mode.pop(name, None)
            for key in [k for k in self._pins if k[0] == name]:
                del self._pins[key]
            self.writeback.cancel(name)
        self.loc.drop(name)

    def forget_replica(self, name: str, node: int) -> None:
        """Drop one node's replica from the residency map (failure handling).

        Dropping the LAST replica deletes the object entirely — the data is
        lost and ``exists()`` turns False so the caller can re-run the
        producer (what the simulator's failure path does)."""
        with self._lock:
            res = self._residency.get(name)
            if res is None or node not in res:
                return
            self._drop_replica(name, node, res[node])
            if res:
                self._sync_placement(name)
            else:
                self.delete(name)

    # ------------------------------------------------------------ reporting
    def movement_report(self) -> Mapping[str, float]:
        total = self.bytes_moved + self.bytes_local
        return {
            "bytes_moved": self.bytes_moved,
            "bytes_local": self.bytes_local,
            "locality_hit_rate": (self.bytes_local / total) if total else 1.0,
            "remote_bytes": self.remote_bytes,
            "bytes_demoted": self.bytes_demoted,
            "demotions": float(self.demotions),
            "promotions": float(self.promotions),
            "bytes_promoted": self.bytes_promoted,
            "migrations": float(self.migrations),
            "transfers": float(len(self.transfers)),
            "writebacks": float(self.writebacks),
            "writeback_bytes": self.writeback_bytes,
            "writeback_pending": float(len(self.writeback)),
            "clean_drops": float(self.clean_drops),
            "bytes_clean_dropped": self.bytes_clean_dropped,
            "coord_drops": float(self.coord_drops),
            "bytes_coord_dropped": self.bytes_coord_dropped,
            "pin_protected_evictions": float(self.pin_protected_evictions),
            "pins": float(len(self._pins)),
            "fsyncs": float(self.fsyncs),
            "fsync_bytes": self.fsync_bytes,
            "phantom_durable": float(self.phantom_durable),
            "rereplications": float(self.rereplications),
            "bytes_rereplicated": self.bytes_rereplicated,
        }

    def tier_used(self, node: int, tier: str | None = None) -> float:
        """Resident bytes in one node's ``tier`` (default: top) — the O(1)
        admission-pressure probe. ``tier_report`` walks every replica in the
        store to build its full per-tier table, which is fine for end-of-run
        reporting but not for a router pricing every follow-up at 10^5
        sessions; this reads the maintained usage counter directly."""
        t = self.hierarchy.normalize(tier)
        with self._lock:
            return self._usage.get((node, t), 0.0)

    def tier_report(self, node: int | None = None
                    ) -> Mapping[str, Mapping[str, float]]:
        """Per-tier residency and read traffic; ``node`` narrows residency to
        one node (bytes_read stays cluster-wide — reads are not attributed
        per node), which is how the serving Router measures an engine's
        tier pressure."""
        out: dict[str, dict[str, float]] = {
            t: {"resident_bytes": 0.0, "bytes_read": 0.0, "replicas": 0.0}
            for t in self.hierarchy.names()}
        with self._lock:
            for (n, tier), used in self._usage.items():
                if node is not None and n != node:
                    continue
                out.setdefault(tier, {"resident_bytes": 0.0, "bytes_read": 0.0,
                                      "replicas": 0.0})
                out[tier]["resident_bytes"] += used
            for res in self._residency.values():
                for n, tier in res.items():
                    if node is None or n == node:
                        out[tier]["replicas"] += 1
            for tier, nb in self.tier_reads.items():
                out[tier]["bytes_read"] += nb
        return out

    def reset_accounting(self) -> None:
        with self._lock:
            self.transfers.clear()
            self.bytes_moved = 0.0
            self.bytes_local = 0.0
            self.remote_bytes = 0.0
            self.bytes_demoted = 0.0
            self.demotions = 0
            self.promotions = 0
            self.bytes_promoted = 0.0
            self.migrations = 0
            self.tier_reads.clear()
            self.writebacks = 0
            self.writeback_bytes = 0.0
            self.clean_drops = 0
            self.bytes_clean_dropped = 0.0
            self.coord_drops = 0
            self.bytes_coord_dropped = 0.0
            self.pin_protected_evictions = 0
            self.fsyncs = 0
            self.fsync_bytes = 0.0
            self.phantom_durable = 0
            self.rereplications = 0
            self.bytes_rereplicated = 0.0
