"""Location-aware store — the paper's file-system layer (§B, first component).

Reproduces, on top of JAX/host memory instead of Memcached, the three file
system extensions the paper proposes for Hercules:

1. **Placement control at create** — ``LocStore.put(name, value, loc=...)`` is
   ``OPEN(..., O_CREAT | S_LOC)``: the caller pins where the object lives. With
   no ``loc``, the store falls back to its default policy (consistent hash over
   nodes — what Hercules/Memcached would do).
2. **Location in extended attributes** — every object carries a
   :class:`Placement` with an ``xattr`` dict; ``stat``/``getxattr`` expose it.
3. **Distributed location service** — :class:`LocationService` shards the
   name -> real-loc mapping by consistent hash into ``n_shards`` independent
   metadata shards (one per metadata server in a real deployment), so lookups
   scale with the cluster instead of bottlenecking on one server. The runtime
   may re-pin ("real-loc") any object at any time via ``migrate`` — this is the
   channel the scheduler uses for its feedback (paper challenge #3).

Values can be anything sized: JAX arrays (``.nbytes``), numpy arrays, bytes, or
:class:`SimObject` stand-ins for the simulator. ``get(name, at=node)`` returns
the value AND a :class:`Transfer` record of the bytes that had to move — the
accounting every benchmark in this repo is built on.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Iterable, Mapping

__all__ = ["Placement", "SimObject", "Transfer", "LocationService", "LocStore",
           "REMOTE_TIER"]

REMOTE_TIER = -1  # node id of the remote parallel-FS tier (Lustre analogue)


def _stable_hash(name: str) -> int:
    return int.from_bytes(hashlib.blake2b(name.encode(), digest_size=8).digest(),
                          "big")


@dataclasses.dataclass
class Placement:
    """Where an object lives: one or more node ids (+ the remote tier).

    ``nodes`` is a tuple because the store supports replication; the paper's
    ``real-loc`` is ``nodes[0]``. ``xattr`` is the extended-attribute dict the
    paper stores location metadata in.
    """

    nodes: tuple[int, ...]
    tier: str = "node"                      # "node" | "remote"
    xattr: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def real_loc(self) -> int:
        return self.nodes[0]

    def resident_on(self, node: int) -> bool:
        return node in self.nodes


@dataclasses.dataclass(frozen=True)
class SimObject:
    """A sized placeholder used by the simulator (no actual payload)."""

    nbytes: float


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One data movement the store had to perform to satisfy a ``get``."""

    name: str
    nbytes: float
    src: int
    dst: int

    @property
    def local(self) -> bool:
        return self.src == self.dst


def sizeof(value: Any) -> float:
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return float(nb)
    if isinstance(value, (bytes, bytearray, memoryview)):
        return float(len(value))
    return float(64)  # opaque python object — metadata-sized


class LocationService:
    """Distributed location-metadata service (consistent-hash sharded).

    Each shard is an independent dict + lock — the in-process model of one
    metadata server. ``shard_of`` is deterministic so any client can route a
    lookup without coordination. Counters let the benchmarks report per-shard
    load balance (the scalability argument for "distributed" in the paper).
    """

    def __init__(self, n_shards: int = 16) -> None:
        if n_shards < 1:
            raise ValueError("need at least one metadata shard")
        self.n_shards = n_shards
        self._shards: list[dict[str, Placement]] = [{} for _ in range(n_shards)]
        self._locks = [threading.Lock() for _ in range(n_shards)]
        self.lookups = [0] * n_shards
        self.records = [0] * n_shards

    def shard_of(self, name: str) -> int:
        return _stable_hash(name) % self.n_shards

    def record(self, name: str, placement: Placement) -> None:
        s = self.shard_of(name)
        with self._locks[s]:
            self._shards[s][name] = placement
            self.records[s] += 1

    def lookup(self, name: str) -> Placement | None:
        s = self.shard_of(name)
        with self._locks[s]:
            self.lookups[s] += 1
            return self._shards[s].get(name)

    def drop(self, name: str) -> None:
        s = self.shard_of(name)
        with self._locks[s]:
            self._shards[s].pop(name, None)

    def names(self) -> list[str]:
        out: list[str] = []
        for s, lock in zip(self._shards, self._locks):
            with lock:
                out.extend(s.keys())
        return out

    def load_balance(self) -> Mapping[str, Any]:
        sizes = [len(s) for s in self._shards]
        return {"shards": self.n_shards, "entries": sum(sizes),
                "max_shard": max(sizes, default=0),
                "min_shard": min(sizes, default=0),
                "lookups": sum(self.lookups)}


class LocStore:
    """The location-aware compute-node-side store.

    ``nodes`` are integer ids 0..N-1 (plus :data:`REMOTE_TIER`). Thread-safe:
    the executor's worker threads and the prefetch engine hit it concurrently.
    """

    def __init__(self, n_nodes: int, *, n_meta_shards: int = 16,
                 default_policy: str = "hash") -> None:
        if n_nodes < 1:
            raise ValueError("need at least one node")
        self.n_nodes = n_nodes
        self.loc = LocationService(n_meta_shards)
        self.default_policy = default_policy
        self._values: dict[str, Any] = {}
        self._lock = threading.RLock()
        self._rr = 0
        # accounting
        self.transfers: list[Transfer] = []
        self.bytes_moved = 0.0
        self.bytes_local = 0.0
        self.migrations = 0

    # ------------------------------------------------------------ placement
    def _default_placement(self, name: str) -> Placement:
        if self.default_policy == "hash":       # Hercules/Memcached behaviour
            node = _stable_hash(name) % self.n_nodes
        elif self.default_policy == "rr":
            with self._lock:
                node = self._rr % self.n_nodes
                self._rr += 1
        else:
            raise ValueError(f"unknown default policy {self.default_policy!r}")
        return Placement(nodes=(node,))

    def _norm_loc(self, loc: Any) -> Placement:
        if isinstance(loc, Placement):
            return loc
        if isinstance(loc, int):
            return Placement(nodes=(loc,))
        if isinstance(loc, (tuple, list)):
            return Placement(nodes=tuple(int(n) for n in loc))
        raise TypeError(f"cannot interpret location {loc!r}")

    # ------------------------------------------------------------------ api
    def put(self, name: str, value: Any, *, loc: Any | None = None,
            xattr: Mapping[str, Any] | None = None) -> Placement:
        """Create an object; ``loc`` is the paper's ``S_LOC`` pinned placement."""
        placement = (self._norm_loc(loc) if loc is not None
                     else self._default_placement(name))
        for n in placement.nodes:
            if n != REMOTE_TIER and not (0 <= n < self.n_nodes):
                raise ValueError(f"node {n} out of range for {self.n_nodes} nodes")
        placement.xattr.update(xattr or {})
        placement.xattr.setdefault("ctime", time.time())
        placement.xattr.setdefault("size", sizeof(value))
        with self._lock:
            self._values[name] = value
        self.loc.record(name, placement)
        return placement

    def exists(self, name: str) -> bool:
        return self.loc.lookup(name) is not None

    def stat(self, name: str) -> Placement:
        p = self.loc.lookup(name)
        if p is None:
            raise KeyError(name)
        return p

    def getxattr(self, name: str, key: str) -> Any:
        """POSIX ``getxattr`` equivalent, incl. the location metadata."""
        p = self.stat(name)
        if key == "real_loc":
            return p.real_loc
        if key == "nodes":
            return p.nodes
        return p.xattr[key]

    def get(self, name: str, *, at: int | None = None) -> tuple[Any, Transfer | None]:
        """Read an object from node ``at``; returns (value, movement record).

        If the object is resident on ``at`` the movement record is a
        zero-copy local hit (``Transfer.local``); otherwise the nearest replica
        is the source and the store notes a network transfer. ``at=None`` skips
        accounting (metadata read).
        """
        p = self.stat(name)
        with self._lock:
            value = self._values[name]
        if at is None:
            return value, None
        nbytes = sizeof(value)
        if p.resident_on(at):
            t = Transfer(name, nbytes, at, at)
            with self._lock:
                self.bytes_local += nbytes
                self.transfers.append(t)
            return value, t
        src = min(p.nodes, key=lambda n: (n == REMOTE_TIER, abs(n - at)))
        t = Transfer(name, nbytes, src, at)
        with self._lock:
            self.bytes_moved += nbytes
            self.transfers.append(t)
        return value, t

    def migrate(self, name: str, loc: Any) -> Transfer:
        """Re-pin an object (the runtime->FS feedback channel).

        Returns the transfer that re-pinning implies. The value itself stays in
        the in-process dict (host RAM) — on a real deployment this issues the
        copy; device-resident arrays are re-placed by the executor.
        """
        p = self.stat(name)
        new = self._norm_loc(loc)
        new.xattr.update(p.xattr)
        new.xattr["migrated_from"] = p.nodes
        with self._lock:
            value = self._values[name]
            nbytes = sizeof(value)
            src = p.real_loc
            self.migrations += 1
            if not set(new.nodes) & set(p.nodes):
                self.bytes_moved += nbytes
        self.loc.record(name, new)
        return Transfer(name, nbytes, src, new.real_loc)

    def replicate(self, name: str, extra_nodes: Iterable[int]) -> Placement:
        """Add replicas (used by the prefetch engine: the original stays)."""
        p = self.stat(name)
        nodes = tuple(dict.fromkeys((*p.nodes, *extra_nodes)))
        new = Placement(nodes=nodes, tier=p.tier, xattr=dict(p.xattr))
        self.loc.record(name, new)
        return new

    def delete(self, name: str) -> None:
        with self._lock:
            self._values.pop(name, None)
        self.loc.drop(name)

    # ------------------------------------------------------------ reporting
    def movement_report(self) -> Mapping[str, float]:
        total = self.bytes_moved + self.bytes_local
        return {
            "bytes_moved": self.bytes_moved,
            "bytes_local": self.bytes_local,
            "locality_hit_rate": (self.bytes_local / total) if total else 1.0,
            "migrations": float(self.migrations),
            "transfers": float(len(self.transfers)),
        }

    def reset_accounting(self) -> None:
        with self._lock:
            self.transfers.clear()
            self.bytes_moved = 0.0
            self.bytes_local = 0.0
            self.migrations = 0
