"""Hint-assisted workflow compiler (paper §B, second component).

Input: a :class:`~repro.core.dag.TaskGraph` whose tasks carry
:class:`~repro.core.hints.TaskHints` and whose external inputs carry ``@size``
hints. Output: the same graph with the "rich metadata" the paper describes —

  * every dataset's size propagated through ``@input-output-ratio``,
  * every task's estimated FLOPs (``@compute-complexity`` applied to its
    now-known input bytes) and estimated runtime (FLOPs / node throughput),
  * topological order, earliest start times, upward ranks (longest path to the
    final task) — the priorities handed to the runtime scheduler.

The hardware model doubles as the roofline calculator used by the benchmarks:
it knows per-node compute throughput, memory bandwidth, and link bandwidths of
the target (TPU v5e by default; the paper's HPC-cluster numbers are a config).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.dag import TaskGraph
from repro.core.locstore import REMOTE_TIER
from repro.core.topology import ClusterTopology

__all__ = ["HardwareModel", "TPU_V5E", "HPC_CLUSTER", "CompiledWorkflow",
           "compile_workflow"]


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Per-node hardware constants used for static cost estimation.

    ``link_gbps(src, dst)`` distinguishes intra-pod ICI from cross-pod DCN by
    pod index (node // nodes_per_pod) — the TPU analogue of the paper's
    node-to-node vs node-to-Lustre asymmetry.

    ``tier_gbps`` names the sustained media bandwidth of each storage tier
    (device HBM / host DRAM / burst buffer / remote PFS); ``tier_bw`` and
    ``move_seconds_tiered`` are the tier-aware cost model the compiler and
    the schedulers rank candidate workers with. ``None`` entries fall back to
    the scalar fields, so flat two-tier configs keep their original costs.

    ``topology`` optionally replaces the scalar pod arithmetic with an
    explicit :class:`~repro.core.topology.ClusterTopology` link graph:
    ``link_gbps`` then charges the max-utilized (minimum-capacity) link on
    the node -> ToR -> spine path. A *flat* topology (``topo.flat``,
    e.g. ``ClusterTopology.one_switch``) contributes structure only — the
    scalar model keeps answering, so costs stay bit-identical.
    """

    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_gbps: float = 819e9             # bytes/s per chip
    ici_gbps: float = 50e9              # bytes/s per ICI link
    dcn_gbps: float = 6.4e9             # bytes/s per host cross-pod
    remote_tier_gbps: float = 2.0e9     # parallel-FS tier (Lustre analogue)
    nodes_per_pod: int = 256
    efficiency: float = 0.5             # sustained fraction of peak for estimates
    tier_gbps: Mapping[str, float] | None = None
    topology: ClusterTopology | None = None

    def link_gbps(self, src: int, dst: int) -> float:
        topo = self.topology
        if topo is not None and not topo.flat:
            return topo.link_gbps(src, dst)
        if src == dst:
            return float("inf")
        if src < 0 or dst < 0:          # negative node id == remote tier
            return self.remote_tier_gbps
        if src // self.nodes_per_pod == dst // self.nodes_per_pod:
            return self.ici_gbps
        return self.dcn_gbps

    def with_topology(self, topo: ClusterTopology | None) -> "HardwareModel":
        """This model with ``topo`` attached (``None`` detaches)."""
        if topo is self.topology:
            return self
        return dataclasses.replace(self, topology=topo)

    def tier_bw(self, tier: str) -> float:
        """Media bandwidth of one storage tier (bytes/s)."""
        if self.tier_gbps is not None and tier in self.tier_gbps:
            return self.tier_gbps[tier]
        defaults = {"hbm": self.hbm_gbps, "bb": self.hbm_gbps / 100.0,
                    "remote": self.remote_tier_gbps}
        # "host"/"node" and unknown tiers are free in the flat model: the
        # link bandwidth already is the end-to-end number there.
        return defaults.get(tier, float("inf"))

    def est_task_seconds(self, flops: float, procs: int = 1) -> float:
        return flops / (self.peak_flops * self.efficiency * max(procs, 1))

    def move_seconds(self, nbytes: float, src: int, dst: int) -> float:
        bw = self.link_gbps(src, dst)
        return 0.0 if bw == float("inf") else nbytes / bw

    def _media_seconds(self, nbytes: float, tier: str | None) -> float:
        if tier is None:
            return 0.0
        bw = self.tier_bw(tier)
        return 0.0 if bw == float("inf") else nbytes / bw

    def move_seconds_tiered(self, nbytes: float, src: int, dst: int,
                            src_tier: str | None = None,
                            dst_tier: str | None = None) -> float:
        """Link time plus the media time of reading the source tier and
        writing the destination tier — the full per-hop cost of one fetch
        through the storage hierarchy."""
        return (self.move_seconds(nbytes, src, dst)
                + self._media_seconds(nbytes, src_tier)
                + self._media_seconds(nbytes, dst_tier))


TPU_V5E = HardwareModel()
# The paper's prototype platform class: commodity cluster, Hercules over
# 10GbE, Lustre behind ~1 GB/s per client.
HPC_CLUSTER = HardwareModel(
    name="hpc-cluster", peak_flops=1e12, hbm_gbps=100e9, ici_gbps=1.25e9,
    dcn_gbps=1.25e9, remote_tier_gbps=0.5e9, nodes_per_pod=1 << 30,
)

_DEFAULT_EXTERNAL_BYTES = 1 << 20  # 1 MiB when no @size hint was given


@dataclasses.dataclass
class CompiledWorkflow:
    """The compiler's product: the annotated graph + its static analyses."""

    graph: TaskGraph
    hw: HardwareModel
    topo: list[str]
    sizes: dict[str, float]             # dataset name -> bytes
    est_flops: dict[str, float]         # task -> flops
    est_seconds: dict[str, float]       # task -> seconds
    earliest_start: dict[str, float]
    upward_rank: dict[str, float]
    critical_path: list[str]
    critical_seconds: float
    # task -> est. seconds to stage its still-on-PFS external inputs through
    # the storage hierarchy (remote read + link + top-tier write). The
    # ProactiveScheduler feeds it into preplace to pick the prefetch tier per
    # dataset (hot inputs -> hbm, bulk -> bb); benchmarks report it.
    est_stage_seconds: dict[str, float] = dataclasses.field(default_factory=dict)
    # external dataset -> est. seconds to stage IT alone into fast memory —
    # the per-dataset term est_stage_seconds sums; the scheduler compares it
    # against the consumer's compute time to classify hot vs bulk inputs.
    stage_seconds: dict[str, float] = dataclasses.field(default_factory=dict)
    # dataset -> write-mode pin ("around" for run-once streaming outputs whose
    # single consumer is predicted to run on the producing node — they never
    # need to occupy node tiers for anyone else). The runtime decides whether
    # to honor these (simulator/executor: honor_write_modes=True).
    write_modes: dict[str, str] = dataclasses.field(default_factory=dict)

    def input_bytes(self, tid: str) -> float:
        return sum(self.sizes[n] for n in self.graph.tasks[tid].inputs)

    def output_bytes(self, tid: str) -> float:
        return sum(self.sizes[n] for n in self.graph.tasks[tid].outputs)

    def summary(self) -> Mapping[str, float]:
        return {
            "tasks": len(self.graph.tasks),
            "datasets": len(self.graph.data),
            "total_bytes": sum(self.sizes.values()),
            "total_flops": sum(self.est_flops.values()),
            "critical_seconds": self.critical_seconds,
        }


def compile_workflow(graph: TaskGraph, hw: HardwareModel = TPU_V5E, *,
                     strict: bool = False) -> CompiledWorkflow:
    """Run the paper's static-analysis passes over ``graph``.

    Mutates ``graph`` in place (fills ``DataSpec.size_bytes``,
    ``TaskSpec.est_flops``, ``TaskSpec.est_seconds``) and returns the bundled
    :class:`CompiledWorkflow`. ``strict=True`` refuses consumed external
    inputs without ``@size`` hints instead of defaulting them to 1 MiB.
    """
    graph.validate(strict=strict)  # cycles; size hints when strict
    topo = graph.topo_order()

    # -- pass 1: dataset size propagation via @size + @input-output-ratio ----
    sizes: dict[str, float] = {}
    for d in graph.data.values():
        if d.is_external:
            sizes[d.name] = (float(d.size_bytes) if d.size_bytes is not None
                             else float(_DEFAULT_EXTERNAL_BYTES))
    for tid in topo:
        t = graph.tasks[tid]
        in_bytes = sum(sizes[n] for n in t.inputs)
        for out in t.outputs:
            d = graph.data[out]
            if d.size_bytes is not None:        # explicit @size wins
                sizes[out] = float(d.size_bytes)
            else:
                per_out = in_bytes / max(len(t.outputs), 1)
                sizes[out] = t.hints.ratio_for(out) * (
                    per_out if len(t.outputs) > 1 else in_bytes)
            d.size_bytes = sizes[out]

    # -- pass 2: task cost estimation via @compute-complexity + @task -------
    est_flops: dict[str, float] = {}
    est_seconds: dict[str, float] = {}
    for tid in topo:
        t = graph.tasks[tid]
        in_bytes = sum(sizes[n] for n in t.inputs)
        f = t.hints.compute.flops(in_bytes)
        est_flops[tid] = f
        s = (t.hints.est_seconds if t.hints.est_seconds is not None
             else hw.est_task_seconds(f, t.hints.procs))
        est_seconds[tid] = s
        t.est_flops, t.est_seconds = f, s

    # -- pass 3: schedule-facing analyses ------------------------------------
    cost = lambda tid: est_seconds[tid]  # noqa: E731
    earliest = graph.earliest_start(cost)
    rank = graph.upward_rank(cost)
    cpath, cseconds = graph.critical_path()

    # -- pass 4: tier-aware stage-in estimates -------------------------------
    # External inputs start on the remote PFS; what does it cost each task to
    # pull them up the storage hierarchy into fast memory? (The per-tier
    # bandwidths live in the HardwareModel, so one config covers compiler,
    # schedulers and simulator.)
    external = {d.name for d in graph.external_inputs()}
    ds_stage = {n: hw.move_seconds_tiered(sizes[n], REMOTE_TIER, 0,
                                          "remote", "hbm")
                for n in external}
    stage: dict[str, float] = {}
    for tid in topo:
        t = graph.tasks[tid]
        stage[tid] = sum(ds_stage[n] for n in t.inputs if n in external)

    # -- pass 5: per-dataset write-mode pins ---------------------------------
    # A produced dataset with exactly ONE consumer whose locality-bound node
    # is the producing node is a write-around candidate: no other node will
    # ever read it, so it need not occupy node tiers on anyone's behalf.
    # Co-location is predicted statically the way the LocalityScheduler binds
    # tasks — the consumer runs where the majority of its input bytes sit, so
    # the pin fires only when this producer made a strict majority of them.
    write_modes: dict[str, str] = {}
    for d in graph.data.values():
        if d.is_external or len(d.consumers) != 1:
            continue
        consumer = graph.tasks[d.consumers[0]]
        total_in = sum(sizes[n] for n in consumer.inputs)
        from_producer = sum(sizes[n] for n in consumer.inputs
                            if graph.data[n].producer == d.producer)
        if total_in > 0 and from_producer * 2 > total_in:
            write_modes[d.name] = "around"
            d.xattr["write_mode"] = "around"

    return CompiledWorkflow(
        graph=graph, hw=hw, topo=topo, sizes=sizes,
        est_flops=est_flops, est_seconds=est_seconds,
        earliest_start=earliest, upward_rank=rank,
        critical_path=cpath, critical_seconds=cseconds,
        est_stage_seconds=stage, stage_seconds=ds_stage,
        write_modes=write_modes,
    )
