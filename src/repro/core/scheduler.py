"""Workflow schedulers — the paper's runtime layer (§B, third component).

Three schedulers, in the order the paper presents them:

* :class:`FCFSScheduler` — the baseline: "the scheduler in most cases works in
  a first-come-first-serve way". Ignores locality entirely.
* :class:`LocalityScheduler` — the paper's heuristic: each READY task gets a
  priority = (a) length of the longest path from it to the final task (upward
  rank, from the compiler) and is then bound to the available worker with the
  lowest data-movement cost for its inputs.
* :class:`ProactiveScheduler` — the paper's second algorithm: NON-ready tasks
  (even with only part of their inputs materialized) are *pre-assigned* using
  estimated movement costs, and prefetch requests are emitted so the store can
  pipeline inputs to the target node while predecessors still run.

Schedulers are pure decision engines over an abstract :class:`ClusterView`, so
the same code drives both the discrete-event simulator (1000+ nodes) and the
real JAX executor.

**Indexed decision path.** The paper's cross-layer argument only holds if the
scheduler itself stays off the data path at 1000+-node scale — per-decision
cost must be microseconds, not milliseconds. ``attach_store(store)`` wires the
scheduler to the store's metadata-change events
(:meth:`~repro.core.locstore.LocationService.subscribe`) and switches the
decision loop to incremental, event-invalidated structures that are
**decision-identical** to the rescanning path:

* a **placement mirror** (dataset -> Placement) maintained from
  record/drop events, so candidate generation and cost scoring stop paying a
  hash + shard lock per ``locate()`` per input per candidate;
* a **per-(input, node) move-cost term cache**: ``move_seconds`` sums cached
  per-input terms and recomputes only the terms whose dataset's placement
  changed since the last decision;
* a **ready-queue priority heap** updated by deltas (task became ready,
  at-risk bytes of an input changed) instead of re-sorting the whole ready
  set every scheduling tick. Queue keys are unique (FIFO arrival breaks
  ties), so heap order is exactly the full-sort order.

``attach_store(store, indexed=False)`` keeps the event wiring (which also
drives the pre-assignment/prefetch-marker invalidation bugfixes) but decides
via the original full-rescan path — the reference the equivalence tests
compare against. Event callbacks run on the mutating thread and only touch
plain dicts/sets (atomic under the GIL); decisions themselves are
single-threaded in both the simulator and the executor's scheduling loop.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import TYPE_CHECKING, Iterable, Iterator, Protocol, Sequence

from repro.core.locstore import Placement, REMOTE_TIER
from repro.core.wfcompiler import CompiledWorkflow

if TYPE_CHECKING:                      # pragma: no cover - typing only
    from repro.core.locstore import LocStore

__all__ = ["ClusterView", "Assignment", "PrefetchRequest", "SchedulerBase",
           "FCFSScheduler", "LocalityScheduler", "ProactiveScheduler"]


class ClusterView(Protocol):
    """What a scheduler may observe about the cluster ("dynamic available
    workers and the data movement cost", per the paper)."""

    def free_workers(self) -> Sequence[int]: ...
    def locate(self, data_name: str) -> Placement | None: ...
    def link_gbps(self, src: int, dst: int) -> float: ...
    def is_durable(self, data_name: str) -> bool:
        """True when the PFS holds the current version of ``data_name`` (it
        would survive any node failure). Views may omit this — risk-aware
        priority then treats everything as durable (no reordering)."""
        ...
    def worker_speed(self, node: int) -> float:
        """Relative throughput (1.0 = nominal). Stragglers report < 1."""
        ...
    def tier_gbps(self, tier: str) -> float:
        """Sustained media bandwidth of a storage tier (inf = free). Views
        without a storage hierarchy may omit this — costs fall back to the
        flat link-only model."""
        ...
    def top_tier(self) -> str:
        """Name of the fastest node-local tier (where fetches land). Views
        may omit this; the cost model assumes "hbm"."""
        ...
    def bulk_tier(self) -> str:
        """Name of the slowest (largest) node-local tier — where bulk
        prefetches stage. Views may omit this; tier pinning assumes "bb"
        (a hierarchy without one normalizes it to its top tier)."""
        ...
    def alive_nodes(self) -> Sequence[int]:
        """Every non-failed node, free or busy. Views may omit this —
        proactive pre-placement then skips ticks with no free worker
        instead of guessing a node."""
        ...
    def link_row(self, src: int) -> "tuple[Sequence[float], float | None] | None":
        """``(row, uniform)`` where ``row[dst] == link_gbps(src, dst)`` for
        every node, and ``uniform`` is the single off-diagonal bandwidth when
        the row has one (None for non-uniform rows). Views may omit this (or
        return None) — batched scoring then calls ``link_gbps`` per node.
        Under a topology-backed view the row carries real path bandwidths
        (rack-local > cross-spine), which is how candidate scoring prefers
        rack-local replicas with no scheduler-side topology code."""
        ...
    def node_queue_seconds(self, node: int) -> float:
        """Seconds of already-queued demand traffic behind ``node``'s NIC
        and its rack uplink — lets placement route around saturated links.
        Views may omit this (or return 0.0, as every flat view does): the
        penalty is only added when positive, so flat decisions are
        unchanged."""
        ...


@dataclasses.dataclass(frozen=True)
class Assignment:
    tid: str
    node: int
    rank: float
    move_seconds: float


@dataclasses.dataclass(frozen=True)
class PrefetchRequest:
    """"Tell the file system to start pipelining the data to the target
    server" — one input dataset to stage onto ``dst``, into ``tier`` (device
    prefetch = promote to "hbm"; a flat store clamps to its top tier)."""

    data_name: str
    dst: int
    for_task: str
    est_bytes: float
    tier: str = "hbm"


class SchedulerBase:
    def __init__(self, wf: CompiledWorkflow) -> None:
        self.wf = wf
        self._arrival: dict[str, int] = {}
        self._counter = 0
        # -- indexed decision path (attach_store) -----------------------------
        self._store: "LocStore | None" = None
        self._indexed = False
        # event-maintained mirror of LocationService.lookup — kept whenever a
        # store is attached (cheap; the bugfix invalidations diff it), but
        # consulted by _locate only when indexed
        self._placements: dict[str, Placement] = {}
        # per-(input dataset, node) move-cost terms, invalidated whole-dataset
        # on any record/drop event for that dataset
        self._term_cache: dict[str, dict[int, float]] = {}

    # -- store wiring ---------------------------------------------------------
    def attach_store(self, store: "LocStore", *, indexed: bool = True) -> None:
        """Subscribe to ``store``'s metadata-change events.

        ``indexed=True`` (default) switches decisions to the incremental
        indexed structures; ``indexed=False`` keeps the original full-rescan
        decision path but still wires the events (pre-assignment and
        prefetch-marker invalidation depend on them) — the reference mode the
        equivalence tests compare against.
        """
        if self._store is not None:
            self._store.loc.unsubscribe(self._on_store_event)
        self._store = store
        self._indexed = indexed
        self._placements = {}
        self._term_cache = {}
        self._reset_index()
        store.loc.subscribe(self._on_store_event)
        for name in store.loc.names():     # snapshot pre-attach placements
            p = store.loc.lookup(name)
            if p is not None:
                # replay as a record event so every subclass index (mirror,
                # availability counts, risk keys) initializes uniformly
                self._on_store_event("record", name, p)

    def detach_store(self) -> None:
        if self._store is not None:
            self._store.loc.unsubscribe(self._on_store_event)
        self._store = None
        self._indexed = False
        self._placements = {}
        self._term_cache = {}
        self._reset_index()

    def _reset_index(self) -> None:
        """Subclass hook: clear decision-path indexes on (re-)attach."""

    def _on_store_event(self, event: str, key, placement) -> None:
        if event == "record":
            self._placements[key] = placement
            self._term_cache.pop(key, None)
        elif event == "drop":
            self._placements.pop(key, None)
            self._term_cache.pop(key, None)

    def _locate(self, cluster: ClusterView, name: str) -> Placement | None:
        """``cluster.locate`` via the event-maintained mirror when indexed —
        the mirror holds the exact Placement objects the LocationService
        would return, so the two paths are decision-identical."""
        if self._indexed and self._store is not None:
            return self._placements.get(name)
        return cluster.locate(name)

    # -- bookkeeping ---------------------------------------------------------
    def note_ready(self, tid: str) -> None:
        """Record FIFO arrival order (what FCFS schedules by)."""
        if tid not in self._arrival:
            self._arrival[tid] = self._counter
            self._counter += 1

    # -- costs ----------------------------------------------------------------
    @staticmethod
    def _tier_seconds(cluster: ClusterView, tier: str | None,
                      size: float) -> float:
        """Media time of reading ``size`` bytes out of ``tier`` — 0 when the
        cluster view exposes no storage hierarchy (flat two-tier model)."""
        if tier is None:
            return 0.0
        fn = getattr(cluster, "tier_gbps", None)
        if fn is None:
            return 0.0
        bw = fn(tier)
        return 0.0 if bw == float("inf") else size / bw

    def move_seconds(self, tid: str, node: int, cluster: ClusterView,
                     *, assume: dict[str, int] | None = None) -> float:
        """Data-movement cost of running ``tid`` on ``node`` (paper's second
        scoring term), tier-aware: a replica on ``node`` but parked in a slow
        tier (burst buffer) still costs its media read time, and a remote
        fetch pays the source tier's media time on top of the link. Missing
        inputs fall back to ``assume`` (estimated producer locations) or the
        remote tier — "estimated and not accurate".

        The cost is a sum of independent per-(input, node) terms; when a
        store is attached (indexed mode) each term is cached and only
        recomputed after a store event touched that input's placement.
        ``assume``-derived terms depend on the caller's estimate, not the
        store, and are never cached.
        """
        # fetched data lands in the destination's top tier; mirror the store's
        # Transfer.est_seconds (src read + link + dst write) so the estimate
        # matches what the simulator charges
        dst_tier = getattr(cluster, "top_tier", lambda: "hbm")()
        cache = (self._term_cache
                 if self._indexed and self._store is not None else None)
        total = 0.0
        for name in self.wf.graph.tasks[tid].inputs:
            if cache is not None:
                terms = cache.get(name)
                if terms is not None:
                    cached = terms.get(node)
                    if cached is not None:
                        total += cached
                        continue
            p = self._locate(cluster, name)
            size = self.wf.sizes.get(name, 0.0)
            if p is not None and p.resident_on(node):
                term = self._tier_seconds(cluster, p.tier_on(node), size)
            else:
                src_tier: str | None = None
                if p is not None:
                    src = p.real_loc
                    src_tier = p.tier_on(src)
                elif assume and name in assume:
                    if assume[name] == node:
                        continue
                    src = assume[name]
                else:
                    src = REMOTE_TIER
                    src_tier = "remote"
                term = self._one_term(cluster, size,
                                      cluster.link_gbps(src, node),
                                      src_tier, dst_tier)
                if p is None:
                    # unplaced input: the term depends on the CALLER's
                    # ``assume`` estimate (or its absence), which the cache
                    # key cannot see — a REMOTE-fallback term cached here
                    # would be served to a later call whose assume covers the
                    # dataset. Never cache; a record event re-enables caching.
                    total += term
                    continue
            if cache is not None:
                cache.setdefault(name, {})[node] = term
            total += term
        return total

    @staticmethod
    def _one_term(cluster: ClusterView, size: float, bw: float,
                  src_tier: str | None, dst_tier: str | None) -> float:
        """One input's fetch term — the exact arithmetic (same operation
        order) ``move_seconds`` uses, shared so batched scoring is bitwise
        identical to the per-node path."""
        term = 0.0
        if bw != float("inf"):
            term += size / bw
        term += SchedulerBase._tier_seconds(cluster, src_tier, size)
        term += SchedulerBase._tier_seconds(cluster, dst_tier, size)
        return term

    def _score_nodes(self, tid: str, nodes: Sequence[int],
                     cluster: ClusterView,
                     assume: dict[str, int] | None = None) -> list[float]:
        """``[move_seconds(tid, n, cluster, assume=assume) for n in nodes]``,
        computed input-major so shared per-input work (locate, source tier,
        the remote fetch term) is hoisted out of the per-node loop.

        Bitwise-identical to the per-node path: per node, terms accumulate in
        the same input order with the same grouping, and a remote term is
        reused across nodes only when their link bandwidths are EQUAL (same
        operands -> same float). With a uniform link row (``link_row``) the
        whole remote column collapses to one C-level list add, which is what
        makes scoring ~250 candidates x 256 inputs per decision affordable.
        """
        dst_tier = getattr(cluster, "top_tier", lambda: "hbm")()
        totals = [0.0] * len(nodes)
        idx = {node: i for i, node in enumerate(nodes)}
        row_fn = getattr(cluster, "link_row", None)
        for name in self.wf.graph.tasks[tid].inputs:
            p = self._locate(cluster, name)
            size = self.wf.sizes.get(name, 0.0)
            # exceptions: candidate indices whose term is NOT the shared
            # remote fetch term (resident replicas; the assume==node skip)
            exc: dict[int, float | None] = {}
            src_tier: str | None = None
            if p is not None:
                src = p.real_loc
                src_tier = p.tier_on(src)
                for rn in p.nodes:
                    i = idx.get(rn)
                    if i is not None:
                        exc[i] = self._tier_seconds(cluster, p.tier_on(rn),
                                                    size)
            elif assume and name in assume:
                src = assume[name]
                i = idx.get(src)
                if i is not None:
                    exc[i] = None          # runs where the input appears: 0
            else:
                src = REMOTE_TIER
                src_tier = "remote"
            rowinfo = row_fn(src) if row_fn is not None else None
            uniform = rowinfo[1] if rowinfo is not None else None
            if uniform is not None:
                rt = self._one_term(cluster, size, uniform, src_tier,
                                    dst_tier)
                if exc:
                    fix = [(i, totals[i]) for i in exc]
                    totals = [t + rt for t in totals]
                    for i, prev in fix:
                        lt = exc[i]
                        totals[i] = prev if lt is None else prev + lt
                else:
                    totals = [t + rt for t in totals]
                continue
            row = rowinfo[0] if rowinfo is not None else None
            rt_by_bw: dict[float, float] = {}
            for i, node in enumerate(nodes):
                if i in exc:
                    lt = exc[i]
                    if lt is not None:
                        totals[i] += lt
                    continue
                bw = row[node] if row is not None else cluster.link_gbps(
                    src, node)
                r = rt_by_bw.get(bw)
                if r is None:
                    r = self._one_term(cluster, size, bw, src_tier, dst_tier)
                    rt_by_bw[bw] = r
                totals[i] += r
        return totals

    # -- interface -------------------------------------------------------------
    def select(self, ready: Sequence[str], cluster: ClusterView) -> list[Assignment]:
        raise NotImplementedError


class FCFSScheduler(SchedulerBase):
    """Paper baseline: first-come-first-serve onto the next available worker.

    Workers are taken round-robin, which is how a locality-oblivious load
    balancer (Swift/T's ADLB) spreads tasks; picking lowest-id-free instead
    would hand FCFS accidental locality that the real system does not have.
    The rotor strides over the tick's *stable* free-worker ordering —
    indexing a list that shrinks as the loop assigns (the old code) made the
    effective stride drift within a multi-assignment tick and biased
    placement toward low node ids.
    """

    def __init__(self, wf: CompiledWorkflow) -> None:
        super().__init__(wf)
        self._rr = 0

    def select(self, ready: Sequence[str], cluster: ClusterView) -> list[Assignment]:
        for tid in ready:
            self.note_ready(tid)
        free = sorted(cluster.free_workers())
        if not free:
            return []
        queue = sorted(ready, key=lambda t: self._arrival[t])
        out: list[Assignment] = []
        n = len(free)
        for i, tid in enumerate(queue[:n]):
            # consecutive rotor positions over the tick-stable list: ≤ n
            # assignments hit n distinct nodes, with a uniform stride of 1
            node = free[(self._rr + i) % n]
            out.append(Assignment(tid, node, self.wf.upward_rank[tid],
                                  self.move_seconds(tid, node, cluster)))
        self._rr += len(out)
        return out


class LocalityScheduler(SchedulerBase):
    """Paper heuristic: upward-rank priority, then min-movement worker.

    ``speed_aware`` additionally penalizes stragglers by the estimated compute
    time on that worker (beyond-paper; off by default to keep the faithful
    reproduction exact).
    """

    def __init__(self, wf: CompiledWorkflow, *, speed_aware: bool = False,
                 max_candidates: int = 32, risk_aware: bool = False) -> None:
        super().__init__(wf)
        self.speed_aware = speed_aware
        # [beyond-paper] durability as a scheduling signal: among equal-rank
        # ready tasks, run the ones whose inputs are a sole, non-durable copy
        # first — consuming at-risk data is the scheduler's contribution to
        # shrinking the durability window the storage layer leaves open (a
        # node failure before the consumer runs re-runs the producer; after,
        # only the consumer's own output is exposed).
        self.risk_aware = risk_aware
        # [beyond-paper] 1000+-node scalability: evaluating the movement cost
        # on EVERY free worker is O(N) per task. Instead score the free
        # workers that HOLD an input (locality candidates, the only nodes
        # where the cost can be zero) plus a strided sample of the rest
        # (power-of-k-choices for load). Decision cost becomes O(k).
        self.max_candidates = max_candidates
        # ready-queue priority heap (indexed mode): entries (key, seq, tid),
        # one live seq per tid; stale entries are skipped lazily at pop.
        # Queue keys end in the unique FIFO arrival counter, so pop order ==
        # full-sort order and the heap is decision-identical to sorted().
        self._heap: list[tuple[tuple, int, str]] = []
        self._heap_seq: dict[str, int] = {}
        self._heap_counter = 0
        # tids whose queue key may have changed (a store event touched one of
        # their inputs — only at-risk bytes can move; rank and arrival are
        # static). Their heap entries are re-keyed at the next select().
        self._key_dirty: set[str] = set()

    def _reset_index(self) -> None:
        self._heap = []
        self._heap_seq = {}
        self._key_dirty = set()

    def _on_store_event(self, event: str, key, placement) -> None:
        if self.risk_aware and event in ("record", "drop"):
            d = self.wf.graph.data.get(key)
            if d is not None:
                self._key_dirty.update(d.consumers)
        super()._on_store_event(event, key, placement)

    def _candidates(self, tid: str, free: list[int],
                    cluster: ClusterView) -> list[int]:
        if len(free) <= self.max_candidates:
            return free
        free_set = set(free)
        cands: dict[int, None] = {}
        for name in self.wf.graph.tasks[tid].inputs:
            p = self._locate(cluster, name)
            if p is not None:
                for n in p.nodes:
                    if n in free_set:
                        cands[n] = None
        stride = max(len(free) // self.max_candidates, 1)
        for n in free[::stride]:
            cands[n] = None
            if len(cands) >= self.max_candidates:
                break
        return list(cands)

    def _at_risk_bytes(self, tid: str, cluster: ClusterView) -> float:
        """Bytes of ``tid``'s inputs living as a sole node-local, non-durable
        copy — one node failure re-runs their producers (0.0 when the view
        exposes no durability signal)."""
        fn = getattr(cluster, "is_durable", None)
        if fn is None:
            return 0.0
        total = 0.0
        for name in self.wf.graph.tasks[tid].inputs:
            p = self._locate(cluster, name)
            if p is None:
                continue
            nodes = [n for n in p.nodes if n != REMOTE_TIER]
            if len(nodes) == 1 and len(p.nodes) == 1 and not fn(name):
                total += self.wf.sizes.get(name, 0.0)
        return total

    def _queue_key(self, tid: str, cluster: ClusterView) -> tuple:
        """Ready-queue priority: critical path first, then (risk-aware only)
        most at-risk bytes, then FIFO arrival."""
        risk = self._at_risk_bytes(tid, cluster) if self.risk_aware else 0.0
        return (-self.wf.upward_rank[tid], -risk, self._arrival[tid])

    def _ordered_ready(self, ready: Sequence[str],
                       cluster: ClusterView) -> Iterator[str]:
        """Ready tasks in queue-priority order.

        Indexed mode maintains the order in a persistent heap updated by
        deltas: only newly-ready tasks and tasks whose key a store event
        dirtied are (re-)pushed; everything else keeps its entry across
        ticks. Popped-but-unassigned tasks (the caller ran out of workers)
        simply lose their entry and are re-pushed at the next call.
        """
        if not (self._indexed and self._store is not None):
            yield from sorted(ready, key=lambda t: self._queue_key(t, cluster))
            return
        for tid in ready:
            if tid not in self._heap_seq or tid in self._key_dirty:
                self._heap_counter += 1
                self._heap_seq[tid] = self._heap_counter
                heapq.heappush(self._heap, (self._queue_key(tid, cluster),
                                            self._heap_counter, tid))
        self._key_dirty.clear()
        ready_set = set(ready)
        heap = self._heap
        while heap:
            _key, seq, tid = heap[0]
            if self._heap_seq.get(tid) != seq:
                heapq.heappop(heap)        # superseded by a re-keyed entry
                continue
            heapq.heappop(heap)
            del self._heap_seq[tid]
            if tid not in ready_set:
                continue                   # left the ready set since pushed
            yield tid

    def _pick_node(self, tid: str, free: list[int], cluster: ClusterView,
                   assume: dict[str, int] | None = None) -> tuple[int, float]:
        free = self._candidates(tid, free, cluster)
        costs = self._score_nodes(tid, free, cluster, assume)
        best, best_cost = free[0], float("inf")
        est = self.wf.est_seconds[tid] if self.speed_aware else 0.0
        qfn = getattr(cluster, "node_queue_seconds", None)
        for node, c in zip(free, costs):
            if self.speed_aware:
                c += est / max(cluster.worker_speed(node), 1e-6)
            if qfn is not None:
                # route around saturated links: a candidate behind a backed-up
                # NIC/uplink pays its queue delay. Flat views report 0.0 or
                # None — a Protocol subclass inherits the stub body — (the
                # guard skips the add), so flat decisions are bit-identical.
                q = qfn(node) or 0.0
                if q > 0.0:
                    c += q
            if c < best_cost:
                best, best_cost = node, c
        return best, best_cost

    def select(self, ready: Sequence[str], cluster: ClusterView) -> list[Assignment]:
        for tid in ready:
            self.note_ready(tid)
        free = list(cluster.free_workers())
        # highest upward rank first — critical path tasks must not wait
        out: list[Assignment] = []
        for tid in self._ordered_ready(ready, cluster):
            if not free:
                break
            node, cost = self._pick_node(tid, free, cluster)
            free.remove(node)
            out.append(Assignment(tid, node, self.wf.upward_rank[tid], cost))
        return out


class ProactiveScheduler(LocalityScheduler):
    """Locality scheduling + the paper's proactive pre-scheduling.

    ``preplace`` may be called at any scheduling tick with the set of tasks
    that are NOT ready but have >= ``min_inputs_ready`` materialized inputs.
    It (1) picks a tentative node per task using *estimated* movement costs
    (unknown inputs assumed to appear where their producer runs), (2) records
    the pre-assignment, and (3) returns the prefetch requests for every
    already-materialized input that is not resident on the target — each
    pinned to a storage tier chosen from the compiler's ``est_stage_seconds``
    (hot inputs -> the top tier, bulk -> the burst buffer; see ``_pin_tier``).

    ``select`` then honours pre-assignments when the node is still free —
    by construction its inputs are (being) pipelined there.

    With an attached store, the per-(dataset, node) prefetch markers and the
    pre-assignments are *invalidated by store events*: a prefetched replica
    that is later evicted or demoted off its target node (or lost with the
    node) becomes re-prefetchable, and pre-assignments pointing at a failed
    node are purged instead of emitting prefetches toward a dead NIC.
    """

    def __init__(self, wf: CompiledWorkflow, *, speed_aware: bool = False,
                 min_inputs_ready: int = 1, horizon: int = 64,
                 prefetch_tier: str = "auto",
                 bulk_stage_ratio: float = 1.0,
                 risk_aware: bool = False) -> None:
        super().__init__(wf, speed_aware=speed_aware, risk_aware=risk_aware)
        self.min_inputs_ready = min_inputs_ready
        self.horizon = horizon
        # "auto" = tier pinning from the compiler's est_stage_seconds (hot
        # inputs -> the top tier, bulk -> the burst buffer); a tier name pins
        # every prefetch to that tier (the pre-PR3 behaviour).
        self.prefetch_tier = prefetch_tier
        self.bulk_stage_ratio = bulk_stage_ratio
        self.preassignment: dict[str, int] = {}
        # dataset -> nodes a prefetch was already emitted toward (pruned by
        # store events; without the pruning a once-prefetched-then-evicted
        # replica could never be prefetched again)
        self._prefetched: dict[str, set[int]] = {}
        # indexed mode: task -> number of its inputs currently materialized
        # (the min_inputs_ready gate without rescanning), and preassigned
        # task -> inputs whose prefetch should be emitted at the next
        # preplace tick. Both are event-maintained; the reference path
        # derives the same facts by rescanning every tick.
        self._avail: dict[str, int] = {}
        self._eligible: dict[str, set[str]] = {}

    def _reset_index(self) -> None:
        super()._reset_index()
        self._avail = {}
        self._eligible = {}

    def _on_store_event(self, event: str, key, placement) -> None:
        if event == "record":
            prev = self._placements.get(key) if self._store is not None else None
            if prev is not None:
                gone = set(prev.nodes) - set(placement.nodes)
                if gone:
                    fetched = self._prefetched.get(key)
                    if fetched:    # replica left those nodes: re-prefetchable
                        fetched -= gone
            elif self._indexed:    # dataset newly materialized
                d = self.wf.graph.data.get(key)
                if d is not None:
                    for c in d.consumers:
                        self._avail[c] = self._avail.get(c, 0) + 1
        elif event == "drop":
            self._prefetched.pop(key, None)
            if self._indexed and key in self._placements:
                d = self.wf.graph.data.get(key)
                if d is not None:
                    for c in d.consumers:
                        self._avail[c] = self._avail.get(c, 1) - 1
        elif event == "drop_node":
            for fetched in self._prefetched.values():
                fetched.discard(key)
            for tid in [t for t, n in self.preassignment.items() if n == key]:
                del self.preassignment[tid]
                self._eligible.pop(tid, None)
        elif event == "join_node":
            # deliberate no-op: a joining node holds no data, so no
            # placement mirror / prefetch marker / preassignment refers to
            # it (drop_node purged them at failure time). Its eligibility
            # as a preplace target flows from the cluster views the caller
            # passes per tick — nothing here to index.
            pass
        super()._on_store_event(event, key, placement)
        if self._indexed and event in ("record", "drop"):
            self._refresh_eligible(key)

    def _refresh_eligible(self, key: str) -> None:
        """Re-derive, for every preassigned consumer of ``key``, whether its
        prefetch should be (re-)emitted — after ``key``'s placement or
        prefetch markers changed. Mirrors the reference path's per-tick
        check: materialized, not resident on the target, marker clear."""
        d = self.wf.graph.data.get(key)
        if d is None:
            return
        p = self._placements.get(key)
        fetched = self._prefetched.get(key, ())
        for tid in d.consumers:
            elig = self._eligible.get(tid)
            if elig is None:
                continue
            node = self.preassignment.get(tid)
            if (node is not None and p is not None
                    and not p.resident_on(node) and node not in fetched):
                elig.add(key)
            else:
                elig.discard(key)

    def _mark_emitted(self, name: str, node: int) -> None:
        """A prefetch of ``name`` toward ``node`` was just emitted: every
        consumer preassigned to that node loses its pending emission."""
        d = self.wf.graph.data.get(name)
        if d is None:
            return
        for c in d.consumers:
            if self.preassignment.get(c) == node:
                e = self._eligible.get(c)
                if e is not None:
                    e.discard(name)

    def _pin_tier(self, name: str, tid: str, cluster: ClusterView) -> str:
        """The storage tier a prefetch of ``name`` for ``tid`` should land in.

        Feeds the compiler's stage estimates back into placement: an input
        whose PFS stage-in time is hideable within its consumer's compute
        time is *hot* — pin it to the fastest tier so the task reads it at
        HBM speed. An input whose staging dominates the consumer (bulk) would
        squat scarce fast memory for longer than it helps — stage it into the
        burst buffer instead (a flat store normalizes that to its only tier).
        """
        if self.prefetch_tier != "auto":
            return self.prefetch_tier
        top = getattr(cluster, "top_tier", lambda: "hbm")()
        stage = self.wf.stage_seconds.get(name)
        if stage is None:
            # internal dataset: produced on a node, cheap to pin fast
            return top
        # per-dataset: THIS input's staging time vs its consumer's compute
        # (a task with nine hot inputs and one bulk one pins nine fast)
        compute = self.wf.est_seconds.get(tid, 0.0)
        if stage > self.bulk_stage_ratio * compute:
            return getattr(cluster, "bulk_tier", lambda: "bb")()
        return top

    # -- proactive pass --------------------------------------------------------
    def preplace(self, candidates: Iterable[str], cluster: ClusterView,
                 running_at: dict[str, int] | None = None) -> list[PrefetchRequest]:
        running_at = running_at or {}
        indexed = self._indexed and self._store is not None
        # estimated location of not-yet-materialized data = where its producer
        # runs (or is pre-assigned) — the paper's "estimated and not accurate".
        # Built lazily: only a NEW pre-assignment needs it, and the snapshot at
        # first use equals the snapshot at entry (pre-assignments added later
        # this tick were never visible to the eager build either).
        assume: dict[str, int] | None = None

        workers = list(cluster.free_workers())
        if not workers:
            # every worker is busy: pre-assign onto any *alive* node (the old
            # `or [0]` fallback pre-assigned node 0 even when node 0 was the
            # failed one, emitting prefetches toward a dead NIC). With no
            # alive-node signal, skip picking NEW pre-assignments this tick —
            # already pre-assigned tasks still pipeline their inputs below.
            alive = getattr(cluster, "alive_nodes", None)
            nodes = alive() if alive is not None else None
            workers = list(nodes) if nodes is not None else []
        reqs: list[PrefetchRequest] = []
        ranked = sorted(candidates, key=lambda t: -self.wf.upward_rank[t])
        for tid in ranked[: self.horizon]:
            t = self.wf.graph.tasks[tid]
            if indexed:
                if self._avail.get(tid, 0) < self.min_inputs_ready:
                    continue
            else:
                ready_inputs = [n for n in t.inputs
                                if self._locate(cluster, n) is not None]
                if len(ready_inputs) < self.min_inputs_ready:
                    continue
            node = self.preassignment.get(tid)
            if node is None:
                if not workers:
                    continue
                if assume is None:
                    assume = {}
                    for atid, anode in {**self.preassignment,
                                        **running_at}.items():
                        for out in self.wf.graph.tasks[atid].outputs:
                            assume[out] = anode
                node, _ = self._pick_node(tid, workers, cluster, assume=assume)
                self.preassignment[tid] = node
            if indexed:
                elig = self._eligible.get(tid)
                if elig is None:
                    # first tick with this pre-assignment (or a manually poked
                    # one): derive the pending-emission set once; events keep
                    # it current from here on
                    elig = set()
                    for name in t.inputs:
                        p = self._placements.get(name)
                        if (p is not None and not p.resident_on(node)
                                and node not in self._prefetched.get(name, ())):
                            elig.add(name)
                    self._eligible[tid] = elig
                if elig:
                    # iterate t.inputs, not elig, to preserve the reference
                    # path's emission order (inputs order, filtered)
                    for name in t.inputs:
                        if name in elig:
                            self._prefetched.setdefault(name, set()).add(node)
                            reqs.append(PrefetchRequest(
                                data_name=name, dst=node, for_task=tid,
                                est_bytes=self.wf.sizes.get(name, 0.0),
                                tier=self._pin_tier(name, tid, cluster)))
                            self._mark_emitted(name, node)
                continue
            for name in ready_inputs:
                p = self._locate(cluster, name)
                if p is not None and not p.resident_on(node):
                    fetched = self._prefetched.setdefault(name, set())
                    if node not in fetched:
                        fetched.add(node)
                        reqs.append(PrefetchRequest(
                            data_name=name, dst=node, for_task=tid,
                            est_bytes=self.wf.sizes.get(name, 0.0),
                            tier=self._pin_tier(name, tid, cluster)))
        return reqs

    # -- ready-task pass --------------------------------------------------------
    def select(self, ready: Sequence[str], cluster: ClusterView) -> list[Assignment]:
        for tid in ready:
            self.note_ready(tid)
        free = list(cluster.free_workers())
        free_set = set(free)
        out: list[Assignment] = []
        for tid in self._ordered_ready(ready, cluster):
            if not free:
                break
            pre = self.preassignment.get(tid)
            if pre is not None and pre in free_set:
                node, cost = pre, self.move_seconds(tid, pre, cluster)
            else:
                node, cost = self._pick_node(tid, free, cluster)
            free.remove(node)
            free_set.discard(node)
            self.preassignment.pop(tid, None)
            self._eligible.pop(tid, None)
            out.append(Assignment(tid, node, self.wf.upward_rank[tid], cost))
        return out
