"""Workflow schedulers — the paper's runtime layer (§B, third component).

Three schedulers, in the order the paper presents them:

* :class:`FCFSScheduler` — the baseline: "the scheduler in most cases works in
  a first-come-first-serve way". Ignores locality entirely.
* :class:`LocalityScheduler` — the paper's heuristic: each READY task gets a
  priority = (a) length of the longest path from it to the final task (upward
  rank, from the compiler) and is then bound to the available worker with the
  lowest data-movement cost for its inputs.
* :class:`ProactiveScheduler` — the paper's second algorithm: NON-ready tasks
  (even with only part of their inputs materialized) are *pre-assigned* using
  estimated movement costs, and prefetch requests are emitted so the store can
  pipeline inputs to the target node while predecessors still run.

Schedulers are pure decision engines over an abstract :class:`ClusterView`, so
the same code drives both the discrete-event simulator (1000+ nodes) and the
real JAX executor.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Protocol, Sequence

from repro.core.locstore import Placement, REMOTE_TIER
from repro.core.wfcompiler import CompiledWorkflow

__all__ = ["ClusterView", "Assignment", "PrefetchRequest", "SchedulerBase",
           "FCFSScheduler", "LocalityScheduler", "ProactiveScheduler"]


class ClusterView(Protocol):
    """What a scheduler may observe about the cluster ("dynamic available
    workers and the data movement cost", per the paper)."""

    def free_workers(self) -> Sequence[int]: ...
    def locate(self, data_name: str) -> Placement | None: ...
    def link_gbps(self, src: int, dst: int) -> float: ...
    def is_durable(self, data_name: str) -> bool:
        """True when the PFS holds the current version of ``data_name`` (it
        would survive any node failure). Views may omit this — risk-aware
        priority then treats everything as durable (no reordering)."""
        ...
    def worker_speed(self, node: int) -> float:
        """Relative throughput (1.0 = nominal). Stragglers report < 1."""
        ...
    def tier_gbps(self, tier: str) -> float:
        """Sustained media bandwidth of a storage tier (inf = free). Views
        without a storage hierarchy may omit this — costs fall back to the
        flat link-only model."""
        ...
    def top_tier(self) -> str:
        """Name of the fastest node-local tier (where fetches land). Views
        may omit this; the cost model assumes "hbm"."""
        ...
    def bulk_tier(self) -> str:
        """Name of the slowest (largest) node-local tier — where bulk
        prefetches stage. Views may omit this; tier pinning assumes "bb"
        (a hierarchy without one normalizes it to its top tier)."""
        ...


@dataclasses.dataclass(frozen=True)
class Assignment:
    tid: str
    node: int
    rank: float
    move_seconds: float


@dataclasses.dataclass(frozen=True)
class PrefetchRequest:
    """"Tell the file system to start pipelining the data to the target
    server" — one input dataset to stage onto ``dst``, into ``tier`` (device
    prefetch = promote to "hbm"; a flat store clamps to its top tier)."""

    data_name: str
    dst: int
    for_task: str
    est_bytes: float
    tier: str = "hbm"


class SchedulerBase:
    def __init__(self, wf: CompiledWorkflow) -> None:
        self.wf = wf
        self._arrival: dict[str, int] = {}
        self._counter = 0

    # -- bookkeeping ---------------------------------------------------------
    def note_ready(self, tid: str) -> None:
        """Record FIFO arrival order (what FCFS schedules by)."""
        if tid not in self._arrival:
            self._arrival[tid] = self._counter
            self._counter += 1

    # -- costs ----------------------------------------------------------------
    @staticmethod
    def _tier_seconds(cluster: ClusterView, tier: str | None,
                      size: float) -> float:
        """Media time of reading ``size`` bytes out of ``tier`` — 0 when the
        cluster view exposes no storage hierarchy (flat two-tier model)."""
        if tier is None:
            return 0.0
        fn = getattr(cluster, "tier_gbps", None)
        if fn is None:
            return 0.0
        bw = fn(tier)
        return 0.0 if bw == float("inf") else size / bw

    def move_seconds(self, tid: str, node: int, cluster: ClusterView,
                     *, assume: dict[str, int] | None = None) -> float:
        """Data-movement cost of running ``tid`` on ``node`` (paper's second
        scoring term), tier-aware: a replica on ``node`` but parked in a slow
        tier (burst buffer) still costs its media read time, and a remote
        fetch pays the source tier's media time on top of the link. Missing
        inputs fall back to ``assume`` (estimated producer locations) or the
        remote tier — "estimated and not accurate".
        """
        # fetched data lands in the destination's top tier; mirror the store's
        # Transfer.est_seconds (src read + link + dst write) so the estimate
        # matches what the simulator charges
        dst_tier = getattr(cluster, "top_tier", lambda: "hbm")()
        total = 0.0
        for name in self.wf.graph.tasks[tid].inputs:
            p = cluster.locate(name)
            size = self.wf.sizes.get(name, 0.0)
            src_tier: str | None = None
            if p is not None:
                if p.resident_on(node):
                    total += self._tier_seconds(cluster, p.tier_on(node), size)
                    continue
                src = p.real_loc
                src_tier = p.tier_on(src)
            elif assume and name in assume:
                src = assume[name]
                if src == node:
                    continue
            else:
                src = REMOTE_TIER
                src_tier = "remote"
            bw = cluster.link_gbps(src, node)
            if bw != float("inf"):
                total += size / bw
            total += self._tier_seconds(cluster, src_tier, size)
            total += self._tier_seconds(cluster, dst_tier, size)
        return total

    # -- interface -------------------------------------------------------------
    def select(self, ready: Sequence[str], cluster: ClusterView) -> list[Assignment]:
        raise NotImplementedError


class FCFSScheduler(SchedulerBase):
    """Paper baseline: first-come-first-serve onto the next available worker.

    Workers are taken round-robin, which is how a locality-oblivious load
    balancer (Swift/T's ADLB) spreads tasks; picking lowest-id-free instead
    would hand FCFS accidental locality that the real system does not have.
    """

    def __init__(self, wf: CompiledWorkflow) -> None:
        super().__init__(wf)
        self._rr = 0

    def select(self, ready: Sequence[str], cluster: ClusterView) -> list[Assignment]:
        for tid in ready:
            self.note_ready(tid)
        free = sorted(cluster.free_workers())
        queue = sorted(ready, key=lambda t: self._arrival[t])
        out: list[Assignment] = []
        for tid in queue[: len(free)]:
            node = free[self._rr % len(free)]
            free.remove(node)
            self._rr += 1
            out.append(Assignment(tid, node, self.wf.upward_rank[tid],
                                  self.move_seconds(tid, node, cluster)))
        return out


class LocalityScheduler(SchedulerBase):
    """Paper heuristic: upward-rank priority, then min-movement worker.

    ``speed_aware`` additionally penalizes stragglers by the estimated compute
    time on that worker (beyond-paper; off by default to keep the faithful
    reproduction exact).
    """

    def __init__(self, wf: CompiledWorkflow, *, speed_aware: bool = False,
                 max_candidates: int = 32, risk_aware: bool = False) -> None:
        super().__init__(wf)
        self.speed_aware = speed_aware
        # [beyond-paper] durability as a scheduling signal: among equal-rank
        # ready tasks, run the ones whose inputs are a sole, non-durable copy
        # first — consuming at-risk data is the scheduler's contribution to
        # shrinking the durability window the storage layer leaves open (a
        # node failure before the consumer runs re-runs the producer; after,
        # only the consumer's own output is exposed).
        self.risk_aware = risk_aware
        # [beyond-paper] 1000+-node scalability: evaluating the movement cost
        # on EVERY free worker is O(N) per task. Instead score the free
        # workers that HOLD an input (locality candidates, the only nodes
        # where the cost can be zero) plus a strided sample of the rest
        # (power-of-k-choices for load). Decision cost becomes O(k).
        self.max_candidates = max_candidates

    def _candidates(self, tid: str, free: list[int],
                    cluster: ClusterView) -> list[int]:
        if len(free) <= self.max_candidates:
            return free
        free_set = set(free)
        cands: dict[int, None] = {}
        for name in self.wf.graph.tasks[tid].inputs:
            p = cluster.locate(name)
            if p is not None:
                for n in p.nodes:
                    if n in free_set:
                        cands[n] = None
        stride = max(len(free) // self.max_candidates, 1)
        for n in free[::stride]:
            cands[n] = None
            if len(cands) >= self.max_candidates:
                break
        return list(cands)

    def _at_risk_bytes(self, tid: str, cluster: ClusterView) -> float:
        """Bytes of ``tid``'s inputs living as a sole node-local, non-durable
        copy — one node failure re-runs their producers (0.0 when the view
        exposes no durability signal)."""
        fn = getattr(cluster, "is_durable", None)
        if fn is None:
            return 0.0
        total = 0.0
        for name in self.wf.graph.tasks[tid].inputs:
            p = cluster.locate(name)
            if p is None:
                continue
            nodes = [n for n in p.nodes if n != REMOTE_TIER]
            if len(nodes) == 1 and len(p.nodes) == 1 and not fn(name):
                total += self.wf.sizes.get(name, 0.0)
        return total

    def _queue_key(self, tid: str, cluster: ClusterView) -> tuple:
        """Ready-queue priority: critical path first, then (risk-aware only)
        most at-risk bytes, then FIFO arrival."""
        risk = self._at_risk_bytes(tid, cluster) if self.risk_aware else 0.0
        return (-self.wf.upward_rank[tid], -risk, self._arrival[tid])

    def _pick_node(self, tid: str, free: list[int], cluster: ClusterView,
                   assume: dict[str, int] | None = None) -> tuple[int, float]:
        free = self._candidates(tid, free, cluster)
        best, best_cost = free[0], float("inf")
        for node in free:
            c = self.move_seconds(tid, node, cluster, assume=assume)
            if self.speed_aware:
                c += (self.wf.est_seconds[tid] / max(cluster.worker_speed(node),
                                                     1e-6))
            if c < best_cost:
                best, best_cost = node, c
        return best, best_cost

    def select(self, ready: Sequence[str], cluster: ClusterView) -> list[Assignment]:
        for tid in ready:
            self.note_ready(tid)
        free = list(cluster.free_workers())
        # highest upward rank first — critical path tasks must not wait
        queue = sorted(ready, key=lambda t: self._queue_key(t, cluster))
        out: list[Assignment] = []
        for tid in queue:
            if not free:
                break
            node, cost = self._pick_node(tid, free, cluster)
            free.remove(node)
            out.append(Assignment(tid, node, self.wf.upward_rank[tid], cost))
        return out


class ProactiveScheduler(LocalityScheduler):
    """Locality scheduling + the paper's proactive pre-scheduling.

    ``preplace`` may be called at any scheduling tick with the set of tasks
    that are NOT ready but have >= ``min_inputs_ready`` materialized inputs.
    It (1) picks a tentative node per task using *estimated* movement costs
    (unknown inputs assumed to appear where their producer runs), (2) records
    the pre-assignment, and (3) returns the prefetch requests for every
    already-materialized input that is not resident on the target — each
    pinned to a storage tier chosen from the compiler's ``est_stage_seconds``
    (hot inputs -> the top tier, bulk -> the burst buffer; see ``_pin_tier``).

    ``select`` then honours pre-assignments when the node is still free —
    by construction its inputs are (being) pipelined there.
    """

    def __init__(self, wf: CompiledWorkflow, *, speed_aware: bool = False,
                 min_inputs_ready: int = 1, horizon: int = 64,
                 prefetch_tier: str = "auto",
                 bulk_stage_ratio: float = 1.0,
                 risk_aware: bool = False) -> None:
        super().__init__(wf, speed_aware=speed_aware, risk_aware=risk_aware)
        self.min_inputs_ready = min_inputs_ready
        self.horizon = horizon
        # "auto" = tier pinning from the compiler's est_stage_seconds (hot
        # inputs -> the top tier, bulk -> the burst buffer); a tier name pins
        # every prefetch to that tier (the pre-PR3 behaviour).
        self.prefetch_tier = prefetch_tier
        self.bulk_stage_ratio = bulk_stage_ratio
        self.preassignment: dict[str, int] = {}
        self._prefetched: set[tuple[str, int]] = set()

    def _pin_tier(self, name: str, tid: str, cluster: ClusterView) -> str:
        """The storage tier a prefetch of ``name`` for ``tid`` should land in.

        Feeds the compiler's stage estimates back into placement: an input
        whose PFS stage-in time is hideable within its consumer's compute
        time is *hot* — pin it to the fastest tier so the task reads it at
        HBM speed. An input whose staging dominates the consumer (bulk) would
        squat scarce fast memory for longer than it helps — stage it into the
        burst buffer instead (a flat store normalizes that to its only tier).
        """
        if self.prefetch_tier != "auto":
            return self.prefetch_tier
        top = getattr(cluster, "top_tier", lambda: "hbm")()
        stage = self.wf.stage_seconds.get(name)
        if stage is None:
            # internal dataset: produced on a node, cheap to pin fast
            return top
        # per-dataset: THIS input's staging time vs its consumer's compute
        # (a task with nine hot inputs and one bulk one pins nine fast)
        compute = self.wf.est_seconds.get(tid, 0.0)
        if stage > self.bulk_stage_ratio * compute:
            return getattr(cluster, "bulk_tier", lambda: "bb")()
        return top

    # -- proactive pass --------------------------------------------------------
    def preplace(self, candidates: Iterable[str], cluster: ClusterView,
                 running_at: dict[str, int] | None = None) -> list[PrefetchRequest]:
        running_at = running_at or {}
        # estimated location of not-yet-materialized data = where its producer
        # runs (or is pre-assigned) — the paper's "estimated and not accurate".
        assume: dict[str, int] = {}
        for tid, node in {**self.preassignment, **running_at}.items():
            for out in self.wf.graph.tasks[tid].outputs:
                assume[out] = node

        workers = list(cluster.free_workers()) or [0]
        reqs: list[PrefetchRequest] = []
        ranked = sorted(candidates, key=lambda t: -self.wf.upward_rank[t])
        for tid in ranked[: self.horizon]:
            t = self.wf.graph.tasks[tid]
            ready_inputs = [n for n in t.inputs if cluster.locate(n) is not None]
            if len(ready_inputs) < self.min_inputs_ready:
                continue
            node = self.preassignment.get(tid)
            if node is None:
                node, _ = self._pick_node(tid, workers, cluster, assume=assume)
                self.preassignment[tid] = node
            for name in ready_inputs:
                p = cluster.locate(name)
                if p is not None and not p.resident_on(node):
                    key = (name, node)
                    if key not in self._prefetched:
                        self._prefetched.add(key)
                        reqs.append(PrefetchRequest(
                            data_name=name, dst=node, for_task=tid,
                            est_bytes=self.wf.sizes.get(name, 0.0),
                            tier=self._pin_tier(name, tid, cluster)))
        return reqs

    # -- ready-task pass --------------------------------------------------------
    def select(self, ready: Sequence[str], cluster: ClusterView) -> list[Assignment]:
        for tid in ready:
            self.note_ready(tid)
        free = list(cluster.free_workers())
        queue = sorted(ready, key=lambda t: self._queue_key(t, cluster))
        out: list[Assignment] = []
        for tid in queue:
            if not free:
                break
            pre = self.preassignment.get(tid)
            if pre is not None and pre in free:
                node, cost = pre, self.move_seconds(tid, pre, cluster)
            else:
                node, cost = self._pick_node(tid, free, cluster)
            free.remove(node)
            self.preassignment.pop(tid, None)
            out.append(Assignment(tid, node, self.wf.upward_rank[tid], cost))
        return out
