"""Workflow executor — really runs a TaskGraph, closing the paper's loop.

This is the runtime that puts the three layers together on actual hardware
(here: CPU threads standing in for nodes; on a pod: one executor per host,
``device_of`` mapping nodes to local TPU devices):

  compiler (CompiledWorkflow)  ->  scheduler (policy)  ->  executor (this)
                                        |                        |
                                        v                        v
                    prefetch engine  <-  feedback  ->  LocStore placement

After every placement decision the executor *feeds back* to the storage layer
(the paper's missing challenge #3): task outputs are put AT the node that
produced them, and proactive pre-assignments trigger pipelining of inputs.

Task bodies are ``fn(**inputs) -> dict[output_name, value]``. Bodies run on a
thread pool with one logical slot per node; JAX computations inside bodies are
free to use devices — the executor only manages placement + ordering.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Mapping, Sequence

from repro.core.dag import TaskGraph
from repro.core.locstore import LocStore, Placement, StorageHierarchy
from repro.core.prefetch import PrefetchEngine
from repro.core.scheduler import (Assignment, ClusterView, ProactiveScheduler,
                                  SchedulerBase)
from repro.core.wfcompiler import CompiledWorkflow, HardwareModel, TPU_V5E

__all__ = ["ExecResult", "WorkflowExecutor"]


@dataclasses.dataclass
class ExecResult:
    wall_seconds: float
    io_wait_total: float
    bytes_moved: float
    bytes_local: float
    bytes_prefetched: float
    outputs: dict[str, Any]
    task_records: dict[str, dict]
    remote_bytes: float = 0.0
    bytes_demoted: float = 0.0
    demotions: int = 0
    promotions: int = 0
    writebacks: int = 0
    writeback_bytes: float = 0.0
    clean_drops: int = 0
    coord_drops: int = 0

    @property
    def locality_hit_rate(self) -> float:
        tot = self.bytes_local + self.bytes_moved
        return self.bytes_local / tot if tot else 1.0


class _ExecCluster(ClusterView):
    def __init__(self, ex: "WorkflowExecutor") -> None:
        self.ex = ex

    def free_workers(self) -> Sequence[int]:
        with self.ex._lock:
            return sorted(self.ex._free)

    def locate(self, data_name: str) -> Placement | None:
        return self.ex.store.loc.lookup(data_name)

    def is_durable(self, data_name: str) -> bool:
        return self.ex.store.durable(data_name)

    def link_gbps(self, src: int, dst: int) -> float:
        return self.ex.hw.link_gbps(src, dst)

    def tier_gbps(self, tier: str) -> float:
        return self.ex.store.hierarchy.bw(tier)

    def top_tier(self) -> str:
        return self.ex.store.hierarchy.top

    def bulk_tier(self) -> str:
        return self.ex.store.hierarchy.bottom

    def worker_speed(self, node: int) -> float:
        return 1.0

    def alive_nodes(self) -> Sequence[int]:
        # the executor has no failure model: every node is alive
        return range(self.ex.n_nodes)


class WorkflowExecutor:
    def __init__(
        self,
        wf: CompiledWorkflow,
        scheduler: SchedulerBase,
        *,
        n_nodes: int = 4,
        hw: HardwareModel = TPU_V5E,
        store: LocStore | None = None,
        hierarchy: StorageHierarchy | None = None,
        device_of: Callable[[int], Any] | None = None,
        inject_inputs: Mapping[str, Any] | None = None,
        write_policy: str = "through",
        coordinated_eviction: bool = False,
        durability: str = "none",
    ) -> None:
        if store is not None and hierarchy is not None:
            raise ValueError("pass either store= or hierarchy=, not both — "
                             "an explicit store already owns its hierarchy")
        if store is not None and (write_policy != "through"
                                  or coordinated_eviction
                                  or durability != "none"):
            raise ValueError("write_policy/coordinated_eviction/durability "
                             "configure the executor-built store — an "
                             "explicit store already owns its policies")
        self.wf = wf
        self.sched = scheduler
        self.hw = hw
        self.n_nodes = n_nodes
        self.store = store or LocStore(n_nodes, hierarchy=hierarchy,
                                       write_policy=write_policy,
                                       coordinated_eviction=coordinated_eviction,
                                       durability=durability)
        self.prefetch = PrefetchEngine(self.store, device_of=device_of)
        # same event wiring the simulator uses: placement mirror + move-cost
        # term cache for decisions, and event-driven invalidation of the
        # proactive pre-assignments/prefetch markers (a replica evicted off
        # its prefetch target becomes re-prefetchable). Events fire on the
        # mutating worker thread; the mirror dicts are plain dicts (atomic
        # under the GIL), so decision reads are no racier than the direct
        # ``loc.lookup`` they replace.
        scheduler.attach_store(self.store, indexed=True)
        self.cluster = _ExecCluster(self)
        self._free: set[int] = set(range(n_nodes))
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._running_at: dict[str, int] = {}
        self._records: dict[str, dict] = {}
        self._io_wait = 0.0
        self._wb_stop = threading.Event()
        for name, value in (inject_inputs or {}).items():
            if not self.store.exists(name):
                self.store.put(name, value)

    def _wb_drainer(self) -> None:
        """Background flusher: drains the store's write-back queue while the
        workers compute — spill-to-PFS never blocks a task body."""
        while not self._wb_stop.wait(0.002):
            self.store.drain_writebacks()
        self.store.drain_writebacks()

    # ------------------------------------------------------------------ run
    def run(self) -> ExecResult:
        wf = self.wf
        g: TaskGraph = wf.graph
        unfinished = {tid: sum(1 for _ in g.predecessors(tid)) for tid in g.tasks}
        state = {tid: "pending" for tid in g.tasks}
        ready = {tid for tid, n in unfinished.items() if n == 0}
        for tid in ready:
            state[tid] = "ready"
        pool = ThreadPoolExecutor(max_workers=self.n_nodes,
                                  thread_name_prefix="xflow-worker")
        wb_thread = threading.Thread(target=self._wb_drainer, daemon=True,
                                     name="xflow-writeback")
        wb_thread.start()
        t0 = time.perf_counter()
        done_total = 0
        errors: list[BaseException] = []

        def body(a: Assignment) -> None:
            nonlocal done_total
            tid = a.tid
            t_assign = time.perf_counter()
            inputs: dict[str, Any] = {}
            for name in g.tasks[tid].inputs:
                # prefer a device/prefetched replica; else normal located get
                self.prefetch.wait(name, a.node, timeout=None) if \
                    (name, a.node) in self.prefetch._inflight else None
                dev = self.prefetch.device_copy(name, a.node)
                if dev is not None:
                    inputs[name] = dev
                    self.store.get(name, at=a.node)  # accounting: local hit
                else:
                    inputs[name], _ = self.store.get(name, at=a.node)
            t_start = time.perf_counter()
            try:
                fn = g.tasks[tid].fn
                out = fn(**inputs) if fn is not None else {}
                for oname in g.tasks[tid].outputs:
                    val = out.get(oname) if isinstance(out, Mapping) else None
                    pin = g.data[oname].pinned_loc
                    self.store.put(oname, val,
                                   loc=pin if pin is not None else a.node,
                                   xattr={"producer": tid})
                if self.store.durability == "fsync_on_barrier":
                    # task finish is the executor's sync point: everything
                    # still dirty (this task's outputs included) becomes
                    # durable before successors are released
                    self.store.barrier()
            except BaseException as e:  # noqa: BLE001 - propagated below
                errors.append(e)
            self.prefetch.release(tid)
            t_end = time.perf_counter()
            with self._cv:
                self._io_wait += t_start - t_assign
                self._records[tid] = {"node": a.node, "io_wait": t_start - t_assign,
                                      "run": t_end - t_start}
                self._running_at.pop(tid, None)
                self._free.add(a.node)
                state[tid] = "done"
                done_total += 1
                for s in g.successors(tid):
                    unfinished[s] -= 1
                    if unfinished[s] == 0 and state[s] == "pending":
                        state[s] = "ready"
                        ready.add(s)
                self._cv.notify_all()

        with self._cv:
            while done_total < len(g.tasks) and not errors:
                if ready and self._free:
                    assignments = self.sched.select(sorted(ready), self.cluster)
                    for a in assignments:
                        ready.discard(a.tid)
                        state[a.tid] = "running"
                        self._running_at[a.tid] = a.node
                        self._free.discard(a.node)
                        pool.submit(body, a)
                    if isinstance(self.sched, ProactiveScheduler):
                        cands = [tid for tid, st in state.items()
                                 if st == "pending" and any(
                                     self.store.exists(n)
                                     for n in g.tasks[tid].inputs)]
                        for req in self.sched.preplace(cands, self.cluster,
                                                       dict(self._running_at)):
                            # pinned do-not-evict until for_task finishes, so
                            # capacity pressure cannot undo the prefetch
                            self.prefetch.submit(req.data_name, req.dst,
                                                 tier=req.tier,
                                                 pin_for=req.for_task)
                    if assignments:
                        continue
                self._cv.wait(timeout=0.5)
        pool.shutdown(wait=True)
        self.prefetch.drain()
        self._wb_stop.set()
        wb_thread.join(timeout=5.0)
        if errors:
            raise errors[0]
        wall = time.perf_counter() - t0
        rep = self.store.movement_report()
        sink_outputs = {}
        for tid in g.sinks():
            for oname in g.tasks[tid].outputs:
                sink_outputs[oname], _ = self.store.get(oname)
        return ExecResult(
            wall_seconds=wall,
            io_wait_total=self._io_wait,
            bytes_moved=rep["bytes_moved"],
            bytes_local=rep["bytes_local"],
            bytes_prefetched=self.prefetch.bytes_prefetched,
            outputs=sink_outputs,
            task_records=self._records,
            remote_bytes=rep["remote_bytes"],
            bytes_demoted=rep["bytes_demoted"],
            demotions=int(rep["demotions"]),
            promotions=int(rep["promotions"]),
            writebacks=int(rep["writebacks"]),
            writeback_bytes=rep["writeback_bytes"],
            clean_drops=int(rep["clean_drops"]),
            coord_drops=int(rep["coord_drops"]),
        )
