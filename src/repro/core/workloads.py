"""Canonical scientific-workflow DAGs used by tests and benchmarks.

``fig2_workflow`` reproduces the shape of the paper's Fig. 2 example (a Swift/T
script: two parallel analysis chains over a shared input, merged at the end).
The others are the standard shapes from the workflow-scheduling literature the
paper positions against: map-reduce, montage-like (diamond fan-in/out), and
random layered DAGs for property tests and scale sweeps.

All generators take sizes in bytes and return an *uncompiled* TaskGraph; run
:func:`repro.core.wfcompiler.compile_workflow` to fill the rich metadata.

``flops_per_byte`` sets the compute intensity of every task. Scientific
kernels are O(10^3) FLOP/byte; the 2000 default puts task runtimes in the
seconds-per-GB regime the paper's platform operates in (so data movement is
meaningful but hideable — the regime the paper targets).
"""

from __future__ import annotations

import random

from repro.core.dag import TaskGraph
from repro.core.hints import Complexity, size_hint, task

__all__ = ["fig2_workflow", "mapreduce_workflow", "montage_workflow",
           "pipeline_chain_workflow", "random_layered_workflow",
           "serving_session_workflow", "training_epoch_workflow"]

MB = float(1 << 20)
GB = float(1 << 30)


def fig2_workflow(input_bytes: float = 4 * GB, *,
                  flops_per_byte: float = 2000.0) -> TaskGraph:
    """The paper's Fig. 2 script shape: read -> two parallel chains -> merge."""
    C = lambda law: Complexity(law, flops_per_byte=flops_per_byte)  # noqa: E731
    g = TaskGraph()
    g.add_data("raw", size_bytes=size_hint(input_bytes))
    g.add_task("split", inputs=("raw",), outputs=("part_a", "part_b"),
               hints=task(compute=C("linear"), io_ratio=0.5))
    g.add_task("filter_a", inputs=("part_a",), outputs=("fa",),
               hints=task(compute=C("linear"), io_ratio=0.25))
    g.add_task("filter_b", inputs=("part_b",), outputs=("fb",),
               hints=task(compute=C("linear"), io_ratio=0.25))
    g.add_task("analyze_a", inputs=("fa",), outputs=("ra",),
               hints=task(compute=C("nlogn"), io_ratio=0.1))
    g.add_task("analyze_b", inputs=("fb",), outputs=("rb",),
               hints=task(compute=C("nlogn"), io_ratio=0.1))
    g.add_task("merge", inputs=("ra", "rb"), outputs=("result",),
               hints=task(compute=C("linear"), io_ratio=1.0))
    g.mark_sink("result")
    return g


def mapreduce_workflow(n_map: int = 64, n_reduce: int = 8,
                       shard_bytes: float = 512 * MB, *,
                       flops_per_byte: float = 2000.0) -> TaskGraph:
    C = lambda law: Complexity(law, flops_per_byte=flops_per_byte)  # noqa: E731
    g = TaskGraph()
    for i in range(n_map):
        g.add_data(f"shard{i}", size_bytes=size_hint(shard_bytes))
        g.add_task(f"map{i}", inputs=(f"shard{i}",),
                   outputs=tuple(f"m{i}_r{j}" for j in range(n_reduce)),
                   hints=task(compute=C("linear"), io_ratio=0.2))
    for j in range(n_reduce):
        g.add_task(f"reduce{j}",
                   inputs=tuple(f"m{i}_r{j}" for i in range(n_map)),
                   outputs=(f"out{j}",),
                   hints=task(compute=C("linear"), io_ratio=0.05))
    g.add_task("collect", inputs=tuple(f"out{j}" for j in range(n_reduce)),
               outputs=("final",), hints=task(compute=C("linear")))
    g.mark_sink("final")
    return g


def montage_workflow(width: int = 32, tile_bytes: float = 256 * MB, *,
                     flops_per_byte: float = 2000.0) -> TaskGraph:
    """Montage-like mosaic: project each tile, pairwise-diff neighbours,
    fit a background model, correct every tile, then co-add."""
    C = lambda law: Complexity(law, flops_per_byte=flops_per_byte)  # noqa: E731
    g = TaskGraph()
    for i in range(width):
        g.add_data(f"tile{i}", size_bytes=size_hint(tile_bytes))
        g.add_task(f"project{i}", inputs=(f"tile{i}",), outputs=(f"proj{i}",),
                   hints=task(compute=C("linear"), io_ratio=1.2))
    for i in range(width - 1):
        g.add_task(f"diff{i}", inputs=(f"proj{i}", f"proj{i+1}"),
                   outputs=(f"fit{i}",), hints=task(compute=C("linear"),
                                                    io_ratio=0.01))
    g.add_task("bgmodel", inputs=tuple(f"fit{i}" for i in range(width - 1)),
               outputs=("model",), hints=task(compute=C("nlogn"), io_ratio=1.0))
    for i in range(width):
        g.add_task(f"correct{i}", inputs=(f"proj{i}", "model"),
                   outputs=(f"corr{i}",), hints=task(compute=C("linear"),
                                                     io_ratio=1.0))
    g.add_task("coadd", inputs=tuple(f"corr{i}" for i in range(width)),
               outputs=("mosaic",), hints=task(compute=C("linear"), io_ratio=0.5))
    g.mark_sink("mosaic")
    return g


def random_layered_workflow(n_layers: int = 8, width: int = 16, *,
                            seed: int = 0, fan_in: int = 3,
                            bytes_lo: float = 16 * MB,
                            bytes_hi: float = 2 * GB,
                            flops_per_byte: float = 2000.0) -> TaskGraph:
    """Random layered DAG (each task reads 1..fan_in outputs from the previous
    layer) — the adversarial shape for property tests."""
    rng = random.Random(seed)
    C = lambda law: Complexity(law, flops_per_byte=flops_per_byte)  # noqa: E731
    g = TaskGraph()
    prev: list[str] = []
    for i in range(width):
        name = f"ext{i}"
        g.add_data(name, size_bytes=size_hint(rng.uniform(bytes_lo, bytes_hi)))
        prev.append(name)
    for layer in range(n_layers):
        cur: list[str] = []
        for i in range(width):
            k = rng.randint(1, min(fan_in, len(prev)))
            ins = tuple(rng.sample(prev, k))
            out = f"d{layer}_{i}"
            g.add_task(f"t{layer}_{i}", inputs=ins, outputs=(out,),
                       hints=task(compute=C(rng.choice(["linear", "nlogn"])),
                                  io_ratio=rng.uniform(0.05, 1.5)))
            cur.append(out)
        prev = cur
    g.add_task("sink", inputs=tuple(prev), outputs=("final",),
               hints=task(compute=C("linear"), io_ratio=0.01))
    # only the last layer feeds the sink; unsampled d<layer>_<i> outputs are
    # intentionally dead (see analysis_allowlist.json)
    g.mark_sink("final")
    return g


def serving_session_workflow(n_sessions: int = 8, n_turns: int = 4, *,
                             kv_bytes: float = 256 * MB,
                             prompt_bytes: float = 64 * 1024.0,
                             flops_per_byte: float = 2000.0,
                             compute_skew: float = 0.35) -> TaskGraph:
    """Multi-turn serving AS a workflow — a session's KV cache is the paper's
    "file". Per session: a ``prefill`` task turns the first prompt into
    ``kv_<s>_0``; each follow-up ``turn`` task consumes the previous turn's
    KV cache plus a fresh (tiny, external) prompt and produces the next KV
    cache. The KV chain is what a locality scheduler must keep on one node:
    every migrated turn re-moves ``kv_bytes``, the sim analogue of the
    serving engine's re-prefill. ``compute_skew`` spreads per-session turn
    durations (session s runs at ``1 + s*skew`` relative cost) so turn
    completions desynchronize — with identical durations every chain's next
    turn is the only ready task the moment its producer's node frees up, and
    even FCFS gets accidental 100% locality."""
    g = TaskGraph()
    for s in range(n_sessions):
        C = lambda law: Complexity(law, flops_per_byte=flops_per_byte  # noqa: E731,E501
                                   * (1.0 + s * compute_skew))
        g.add_data(f"prompt{s}_0", size_bytes=size_hint(prompt_bytes))
        g.add_data(f"kv{s}_0", size_bytes=size_hint(kv_bytes))
        g.add_task(f"prefill{s}", inputs=(f"prompt{s}_0",),
                   outputs=(f"kv{s}_0",), hints=task(compute=C("linear")))
        for t in range(1, n_turns):
            g.add_data(f"prompt{s}_{t}", size_bytes=size_hint(prompt_bytes))
            g.add_data(f"kv{s}_{t}", size_bytes=size_hint(kv_bytes))
            g.add_task(f"turn{s}_{t}",
                       inputs=(f"kv{s}_{t-1}", f"prompt{s}_{t}"),
                       outputs=(f"kv{s}_{t}",),
                       hints=task(compute=C("linear")))
        g.mark_sink(f"kv{s}_{n_turns - 1}")   # last turn's KV is the result
    return g


def pipeline_chain_workflow(n_chains: int = 8, depth: int = 6, *,
                            stage_bytes: float = 512 * MB,
                            flops_per_byte: float = 2000.0) -> TaskGraph:
    """Parallel deep pipelines — the failure-sensitivity stress shape.

    Each chain is ``depth`` sequential stages, every intermediate consumed by
    exactly one successor, so under compute-on-data-path each stage's output
    is a *sole copy* on the node that produced it: losing that node before
    the next stage reads it re-runs the producer. The rerun exposure of a
    durability window is therefore proportional to how many stages sit
    un-flushed when a failure hits — the quantity ``bench_failures`` sweeps.
    A final sink joins the chains (one task; its fan-in is not the point)."""
    C = lambda law: Complexity(law, flops_per_byte=flops_per_byte)  # noqa: E731
    g = TaskGraph()
    finals = []
    for c in range(n_chains):
        g.add_data(f"src{c}", size_bytes=size_hint(stage_bytes))
        prev = f"src{c}"
        for s in range(depth):
            out = f"c{c}_s{s}"
            g.add_task(f"stage{c}_{s}", inputs=(prev,), outputs=(out,),
                       hints=task(compute=C("linear"), io_ratio=0.3))
            prev = out
        finals.append(prev)
    g.add_task("join", inputs=tuple(finals), outputs=("final",),
               hints=task(compute=C("linear"), io_ratio=0.05))
    g.mark_sink("final")
    return g


def training_epoch_workflow(n_steps: int = 8, n_dp: int = 4, *,
                            batch_bytes: float = 64 * MB,
                            ckpt_every: int = 4,
                            step_flops: float = 1e12) -> TaskGraph:
    """A training epoch AS a workflow — how the framework itself uses the
    paper's machinery: per-step data-load tasks feeding per-shard train tasks,
    periodic checkpoint tasks consuming the updated state."""
    g = TaskGraph()
    g.add_data("corpus", size_bytes=size_hint(n_steps * n_dp * batch_bytes))
    g.add_data("params0", size_bytes=size_hint(2 * GB))
    prev_params = "params0"
    for s in range(n_steps):
        batches = []
        for d in range(n_dp):
            b = f"batch_{s}_{d}"
            g.add_task(f"load_{s}_{d}", inputs=("corpus",), outputs=(b,),
                       hints=task(compute="const",
                                  io_ratio=1.0 / (n_steps * n_dp)))
            batches.append(b)
        new_params = f"params{s+1}"
        g.add_task(f"step_{s}", inputs=(prev_params, *batches),
                   outputs=(new_params,),
                   hints=task(compute=Complexity("const",
                                                 flops_per_byte=step_flops),
                              io_ratio=1.0, procs=n_dp))
        if (s + 1) % ckpt_every == 0:
            g.add_task(f"ckpt_{s}", inputs=(new_params,),
                       outputs=(f"ckpt_file_{s}",),
                       hints=task(compute="const", io_ratio=1.0))
            g.mark_sink(f"ckpt_file_{s}")
        prev_params = new_params
    if not g.data[prev_params].consumers:   # epoch length not a ckpt multiple
        g.mark_sink(prev_params)
    return g
