"""Core — the paper's cross-layer contribution as a composable library.

Layers (paper §B):
  * storage:   :mod:`repro.core.locstore` — location-aware store + location service
  * compiler:  :mod:`repro.core.hints` + :mod:`repro.core.wfcompiler`
  * runtime:   :mod:`repro.core.scheduler` + :mod:`repro.core.prefetch`
               + :mod:`repro.core.executor` (real) / :mod:`repro.core.simulator`
"""

from repro.core.config import ServingConfig, SimConfig
from repro.core.dag import DataSpec, TaskGraph, TaskSpec
from repro.core.executor import WorkflowExecutor
from repro.core.hints import Complexity, TaskHints, size_hint, task
from repro.core.locstore import (DropReport, FLAT_HIERARCHY, JoinReport,
                                 LocationService, LocStore,
                                 Placement, REMOTE_TIER, SimObject,
                                 StorageHierarchy, TierHop, TierSpec, Transfer,
                                 WriteBackEntry, WriteBackQueue,
                                 tiered_hierarchy)
from repro.core.prefetch import PrefetchEngine
from repro.core.scheduler import (Assignment, FCFSScheduler, LocalityScheduler,
                                  PrefetchRequest, ProactiveScheduler)
from repro.core.simulator import SimResult, WorkflowSimulator, simulate
from repro.core.topology import ClusterTopology, NodeProfile
from repro.core.wfcompiler import (CompiledWorkflow, HardwareModel, HPC_CLUSTER,
                                   TPU_V5E, compile_workflow)

__all__ = [
    "DataSpec", "TaskGraph", "TaskSpec",
    "Complexity", "TaskHints", "size_hint", "task",
    "LocationService", "LocStore", "Placement", "REMOTE_TIER", "SimObject",
    "Transfer", "TierHop", "TierSpec", "StorageHierarchy", "FLAT_HIERARCHY",
    "tiered_hierarchy", "WriteBackEntry", "WriteBackQueue",
    "DropReport", "JoinReport",
    "CompiledWorkflow", "HardwareModel", "HPC_CLUSTER", "TPU_V5E",
    "compile_workflow", "ClusterTopology", "NodeProfile",
    "Assignment", "FCFSScheduler", "LocalityScheduler", "PrefetchRequest",
    "ProactiveScheduler",
    "PrefetchEngine", "WorkflowExecutor",
    "ServingConfig", "SimConfig", "SimResult", "WorkflowSimulator", "simulate",
]
