"""Unified model API for the 10 assigned architectures.

Public surface (all pure functions of a frozen :class:`ModelConfig`):

  init_params(cfg, key)                 -> param pytree (stacked layers)
  loss_fn(cfg, params, batch)           -> (loss, metrics)     [train_step core]
  prefill(cfg, params, batch, max_seq)  -> (last_logits, decode_state)
  init_decode_state(cfg, batch, max_seq)-> decode_state        [for dry-run]
  decode_step(cfg, params, state, tok)  -> (logits, decode_state)
  param_count(cfg) / active_param_count(cfg)

Batch convention: ``{"tokens": (B,S) i32, "labels": (B,S) i32}`` plus
``"frames": (B, n_frames, d)`` for encdec (whisper — audio frontend stubbed to
precomputed frame embeddings) and ``"patches": (B, n_patches, d)`` for vlm
(llama-3.2-vision — patch embeddings stubbed likewise).

Implementation notes
  * layers are stacked and driven by ``lax.scan`` (small HLO, fast compiles at
    61-100 layers) with per-layer remat (``nothing_saveable``) during training;
  * decode keeps KV/SSM caches in the scan *carry* and updates slices in place
    (single cache buffer; pairs with buffer donation in the serve step);
  * architectures with periodic special layers (zamba2 shared attention,
    llama-vision cross-attention) scan over *groups* so special-layer params
    and caches have exact shapes (no dead weights);
  * vocab sizes are padded to a multiple of 256 for clean TP sharding; padded
    logits are masked to -inf in the loss/decode heads.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (AttnDims, apply_rope, attention,
                                 cross_attention_block, decode_attention,
                                 init_attn, init_mlp, mlp_block, rms_norm,
                                 softmax_xent, init_linear,
                                 uniform_scale_init)

Pytree = Any

# --------------------------------------------------------------------- misc
REMAT_POLICY = jax.checkpoint_policies.nothing_saveable


def _remat(fn):
    return jax.checkpoint(fn, policy=REMAT_POLICY)


def padded_vocab(cfg: ModelConfig) -> int:
    return int(np.ceil(cfg.vocab / 256) * 256)


def _logit_mask(cfg: ModelConfig) -> jax.Array | float:
    vp = padded_vocab(cfg)
    if vp == cfg.vocab:
        return 0.0
    return jnp.where(jnp.arange(vp) < cfg.vocab, 0.0, -1e30)


def _dims(cfg: ModelConfig) -> AttnDims:
    return AttnDims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def stacked_init(fn, key: jax.Array, n: int) -> Pytree:
    return jax.vmap(fn)(jax.random.split(key, n))


def _positions(tokens: jax.Array) -> jax.Array:
    B, S = tokens.shape
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


def _embed_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": uniform_scale_init(k1, (padded_vocab(cfg), cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["head"] = init_linear(k2, cfg.d_model, padded_vocab(cfg), dt)
    return p


def _embed(p: Pytree, tokens: jax.Array) -> jax.Array:
    from repro.dist.hints import hint
    h = jnp.take(p["embed"]["tok"], tokens, axis=0)
    return hint(h, "dp", *([None] * (h.ndim - 1)))


def _head(cfg: ModelConfig, p: Pytree, h: jax.Array) -> jax.Array:
    from repro.dist.hints import hint
    e = p["embed"]
    w = e["head"] if "head" in e else e["tok"].T
    return hint((h @ w), "dp", None, "tp") + _logit_mask(cfg)


def _lm_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array):
    return softmax_xent(logits, labels)


# ============================================================ dense / gemma3
def _windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer sliding window (0 = full attention)."""
    L = cfg.n_layers
    if cfg.family != "localglobal":
        return np.zeros((L,), np.int32)
    w = np.full((L,), cfg.sliding_window, np.int32)
    w[cfg.global_every - 1::cfg.global_every] = 0        # 1 global per group
    return w


def _dense_block_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": init_attn(k1, _dims(cfg), dt, cfg.n_layers),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dt, cfg.n_layers)}


def _dense_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    ke, kb, kf = jax.random.split(key, 3)
    return {"embed": _embed_init(cfg, ke),
            "blocks": stacked_init(partial(_dense_block_init, cfg), kb,
                                   cfg.n_layers),
            "final_norm": jnp.zeros((cfg.d_model,), _dtype(cfg))}


def _gqa_layer(cfg: ModelConfig, p: Pytree, h: jax.Array, positions, window,
               *, build_cache: int = 0):
    """One GQA decoder layer. If build_cache>0, also return (k, v) padded to
    that capacity."""
    dims = _dims(cfg)
    B, S, _ = h.shape
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    q = (hn @ p["attn"]["wq"]).reshape(B, S, dims.n_heads, dims.hd)
    k = (hn @ p["attn"]["wk"]).reshape(B, S, dims.n_kv_heads, dims.hd)
    v = (hn @ p["attn"]["wv"]).reshape(B, S, dims.n_kv_heads, dims.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, q_pos=positions, k_pos=positions, causal=True,
                  window=window)
    from repro.dist.hints import hint
    h = h + o.reshape(B, S, dims.n_heads * dims.hd) @ p["attn"]["wo"]
    h = hint(h, "dp", "sp_seq", None)     # Megatron-SP residual (opt-in)
    h = h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))
    h = hint(h, "dp", "sp_seq", None)
    if build_cache:
        pad = build_cache - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return h, (kc, vc)
    return h


def _dense_hidden(cfg: ModelConfig, params: Pytree, tokens: jax.Array):
    h = _embed(params, tokens)
    positions = _positions(tokens)
    windows = jnp.asarray(_windows(cfg))

    body = _remat(lambda p, h, w: _gqa_layer(cfg, p, h, positions, w))

    def step(h, pw):
        p, w = pw
        return body(p, h, w), None

    h, _ = jax.lax.scan(step, h, (params["blocks"], windows))
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def _dense_train(cfg: ModelConfig, params: Pytree, batch: Pytree):
    h = _dense_hidden(cfg, params, batch["tokens"])
    logits = _head(cfg, params, h)
    loss = _lm_loss(cfg, logits, batch["labels"])
    return loss, {"loss": loss}


def _dense_prefill(cfg: ModelConfig, params: Pytree, batch: Pytree,
                   max_seq: int):
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed(params, tokens)
    positions = _positions(tokens)
    windows = jnp.asarray(_windows(cfg))

    def step(h, pw):
        p, w = pw
        h, kv = _gqa_layer(cfg, p, h, positions, w, build_cache=max_seq)
        return h, kv

    h, (ck, cv) = jax.lax.scan(step, h, (params["blocks"], windows))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h[:, -1:])
    state = {"pos": jnp.full((B,), S, jnp.int32), "k": ck, "v": cv}
    return logits, state


def _dense_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Pytree:
    dims = _dims(cfg)
    shape = (cfg.n_layers, batch, max_seq, dims.n_kv_heads, dims.hd)
    return {"pos": jnp.zeros((batch,), jnp.int32),
            "k": jnp.zeros(shape, _dtype(cfg)),
            "v": jnp.zeros(shape, _dtype(cfg))}


def _dense_decode(cfg: ModelConfig, params: Pytree, state: Pytree,
                  tokens: jax.Array):
    dims = _dims(cfg)
    B = tokens.shape[0]
    pos = state["pos"]                                     # (B,)
    h = _embed(params, tokens)                             # (B,1,d)
    windows = jnp.asarray(_windows(cfg))
    bidx = jnp.arange(B)

    def step(carry, x):
        h, ck, cv = carry
        p, li, w = x
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q = (hn @ p["attn"]["wq"]).reshape(B, 1, dims.n_heads, dims.hd)
        k = (hn @ p["attn"]["wk"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
        v = (hn @ p["attn"]["wv"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
        k_l = jax.lax.dynamic_index_in_dim(ck, li, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(cv, li, 0, keepdims=False)
        k_l = k_l.at[bidx, pos].set(k[:, 0])
        v_l = v_l.at[bidx, pos].set(v[:, 0])
        o = decode_attention(q, k_l, v_l, q_pos=pos, window=w)
        h = h + o.reshape(B, 1, dims.n_heads * dims.hd) @ p["attn"]["wo"]
        h = h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))
        ck = jax.lax.dynamic_update_index_in_dim(ck, k_l, li, 0)
        cv = jax.lax.dynamic_update_index_in_dim(cv, v_l, li, 0)
        return (h, ck, cv), None

    (h, ck, cv), _ = jax.lax.scan(
        step, (h, state["k"], state["v"]),
        (params["blocks"], jnp.arange(cfg.n_layers), windows))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"pos": pos + 1, "k": ck, "v": cv}


# ======================================================================= moe
def _moe_attn_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    if cfg.mla is not None:
        return mla_mod.init_mla(key, cfg, dt, cfg.n_layers)
    return init_attn(key, _dims(cfg), dt, cfg.n_layers)


def _moe_attn_apply(cfg: ModelConfig, p: Pytree, h: jax.Array, positions):
    if cfg.mla is not None:
        return mla_mod.mla_attention(cfg, p, h, positions)
    dims = _dims(cfg)
    B, S, _ = h.shape
    q = (h @ p["wq"]).reshape(B, S, dims.n_heads, dims.hd)
    k = (h @ p["wk"]).reshape(B, S, dims.n_kv_heads, dims.hd)
    v = (h @ p["wv"]).reshape(B, S, dims.n_kv_heads, dims.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, q_pos=positions, k_pos=positions, causal=True)
    return o.reshape(B, S, dims.n_heads * dims.hd) @ p["wo"]


def _moe_block_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": jnp.zeros((cfg.d_model,), dt),
         "attn": _moe_attn_init(cfg, k1),
         "ln2": jnp.zeros((cfg.d_model,), dt),
         "moe": moe_mod.init_moe(k2, cfg, dt, cfg.n_layers)}
    if cfg.dense_residual:
        p["dense_mlp"] = init_mlp(k3, cfg.d_model, cfg.d_ff, dt, cfg.n_layers)
    return p


def _dense_ffn_block_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": _moe_attn_init(cfg, k1),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dt, cfg.n_layers)}


def _moe_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    ke, kd, km, kmtp = jax.random.split(key, 4)
    dt = _dtype(cfg)
    n_moe = cfg.n_layers - cfg.first_dense_layers
    p = {"embed": _embed_init(cfg, ke),
         "moe_blocks": stacked_init(partial(_moe_block_init, cfg), km, n_moe),
         "final_norm": jnp.zeros((cfg.d_model,), dt)}
    if cfg.first_dense_layers:
        p["dense_blocks"] = stacked_init(partial(_dense_ffn_block_init, cfg),
                                         kd, cfg.first_dense_layers)
    if cfg.mtp_depth:
        k1, k2 = jax.random.split(kmtp)
        p["mtp"] = {"proj": init_linear(k1, 2 * cfg.d_model, cfg.d_model, dt),
                    "block": _dense_ffn_block_init(cfg, k2),
                    "norm": jnp.zeros((cfg.d_model,), dt)}
    return p


def _moe_layer(cfg: ModelConfig, p: Pytree, h: jax.Array, positions):
    from repro.dist.hints import hint
    h = h + _moe_attn_apply(cfg, p["attn"],
                            rms_norm(h, p["ln1"], cfg.norm_eps), positions)
    h = hint(h, "dp", "sp_seq", None)
    hn = rms_norm(h, p["ln2"], cfg.norm_eps)
    y, aux = moe_mod.moe_ffn(cfg, p["moe"], hn)
    if cfg.dense_residual:
        y = y + mlp_block(p["dense_mlp"], hn)
    return hint(h + y, "dp", "sp_seq", None), aux


def _dense_ffn_layer(cfg: ModelConfig, p: Pytree, h: jax.Array, positions):
    from repro.dist.hints import hint
    h = h + _moe_attn_apply(cfg, p["attn"],
                            rms_norm(h, p["ln1"], cfg.norm_eps), positions)
    h = hint(h, "dp", "sp_seq", None)
    return hint(h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps)),
                "dp", "sp_seq", None)


def _moe_hidden(cfg: ModelConfig, params: Pytree, tokens: jax.Array):
    h = _embed(params, tokens)
    positions = _positions(tokens)
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.first_dense_layers:
        dense_body = _remat(lambda p, h: _dense_ffn_layer(cfg, p, h, positions))
        h, _ = jax.lax.scan(lambda h, p: (dense_body(p, h), None), h,
                            params["dense_blocks"])
    moe_body = _remat(lambda p, h: _moe_layer(cfg, p, h, positions))

    def step(carry, p):
        h, aux = carry
        h, a = moe_body(p, h)
        return (h, aux + a), None

    (h, aux_total), _ = jax.lax.scan(step, (h, aux_total), params["moe_blocks"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps), aux_total


def _moe_train(cfg: ModelConfig, params: Pytree, batch: Pytree):
    h, aux = _moe_hidden(cfg, params, batch["tokens"])
    logits = _head(cfg, params, h)
    xent = _lm_loss(cfg, logits, batch["labels"])
    loss = xent + aux
    metrics = {"loss": loss, "xent": xent, "aux": aux}
    if cfg.mtp_depth:
        # multi-token prediction: fuse h with the embedding of the (t+1) token
        # and predict t+2 through one extra dense layer + the shared head.
        m = params["mtp"]
        emb_next = _embed(params, batch["labels"].clip(0))
        z = jnp.concatenate([rms_norm(h, m["norm"], cfg.norm_eps),
                             emb_next], axis=-1) @ m["proj"]
        z = _dense_ffn_layer(cfg, m["block"], z, _positions(batch["tokens"]))
        mtp_logits = _head(cfg, params, z)
        labels2 = jnp.concatenate(
            [batch["labels"][:, 1:],
             jnp.full_like(batch["labels"][:, :1], -1)], axis=1)
        mtp = softmax_xent(mtp_logits, labels2)
        loss = loss + 0.3 * mtp
        metrics.update({"mtp": mtp, "loss": loss})
    return loss, metrics


def _moe_prefill(cfg: ModelConfig, params: Pytree, batch: Pytree,
                 max_seq: int):
    assert cfg.mla is not None or cfg.first_dense_layers == 0
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed(params, tokens)
    positions = _positions(tokens)

    def emit_cache(p, hn):
        if cfg.mla is not None:
            c_kv, k_rope = mla_mod._latents(cfg, p["attn"], hn, positions)
            pad = max_seq - S
            return (jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                    jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))))
        dims = _dims(cfg)
        k = (hn @ p["attn"]["wk"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        v = (hn @ p["attn"]["wv"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        k = apply_rope(k, positions, cfg.rope_theta)
        pad = max_seq - S
        return (jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))

    def dense_step(h, p):
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        cache = emit_cache(p, hn)
        h = h + _moe_attn_apply(cfg, p["attn"], hn, positions)
        h = h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))
        return h, cache

    def moe_step(h, p):
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        cache = emit_cache(p, hn)
        h = h + _moe_attn_apply(cfg, p["attn"], hn, positions)
        hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
        y, _ = moe_mod.moe_ffn(cfg, p["moe"], hn2)
        if cfg.dense_residual:
            y = y + mlp_block(p["dense_mlp"], hn2)
        return h + y, cache

    state = {"pos": jnp.full((B,), S, jnp.int32)}
    if cfg.first_dense_layers:
        h, dc = jax.lax.scan(dense_step, h, params["dense_blocks"])
        state["dense_cache"] = dc
    h, mc = jax.lax.scan(moe_step, h, params["moe_blocks"])
    state["moe_cache"] = mc
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _head(cfg, params, h[:, -1:]), state


def _moe_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Pytree:
    dt = _dtype(cfg)
    n_moe = cfg.n_layers - cfg.first_dense_layers
    state = {"pos": jnp.zeros((batch,), jnp.int32)}

    def cache(n):
        if cfg.mla is not None:
            m = cfg.mla
            return (jnp.zeros((n, batch, max_seq, m.kv_lora_rank), dt),
                    jnp.zeros((n, batch, max_seq, m.qk_rope_head_dim), dt))
        dims = _dims(cfg)
        return (jnp.zeros((n, batch, max_seq, dims.n_kv_heads, dims.hd), dt),
                jnp.zeros((n, batch, max_seq, dims.n_kv_heads, dims.hd), dt))

    if cfg.first_dense_layers:
        state["dense_cache"] = cache(cfg.first_dense_layers)
    state["moe_cache"] = cache(n_moe)
    return state


def _moe_attn_decode(cfg: ModelConfig, p: Pytree, h, cache_pair, li, pos):
    """One-layer attention decode; returns (attn_out, updated (c1_l, c2_l))."""
    B = h.shape[0]
    bidx = jnp.arange(B)
    c1, c2 = cache_pair
    c1_l = jax.lax.dynamic_index_in_dim(c1, li, 0, keepdims=False)
    c2_l = jax.lax.dynamic_index_in_dim(c2, li, 0, keepdims=False)
    if cfg.mla is not None:
        out, new = mla_mod.mla_decode(cfg, p, h, {"c_kv": c1_l, "k_rope": c2_l},
                                      pos)
        return out, (new["c_kv"], new["k_rope"])
    dims = _dims(cfg)
    q = (h @ p["wq"]).reshape(B, 1, dims.n_heads, dims.hd)
    k = (h @ p["wk"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
    v = (h @ p["wv"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    c1_l = c1_l.at[bidx, pos].set(k[:, 0])
    c2_l = c2_l.at[bidx, pos].set(v[:, 0])
    o = decode_attention(q, c1_l, c2_l, q_pos=pos)
    return o.reshape(B, 1, dims.n_heads * dims.hd) @ p["wo"], (c1_l, c2_l)


def _moe_decode(cfg: ModelConfig, params: Pytree, state: Pytree,
                tokens: jax.Array):
    B = tokens.shape[0]
    pos = state["pos"]
    h = _embed(params, tokens)
    new_state = {"pos": pos + 1}

    def mk_step(moe: bool):
        def step(carry, x):
            h, c1, c2 = carry
            p, li = x
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            o, (c1_l, c2_l) = _moe_attn_decode(cfg, p["attn"], hn, (c1, c2),
                                               li, pos)
            h = h + o
            hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
            if moe:
                y, _ = moe_mod.moe_ffn(cfg, p["moe"], hn2)
                if cfg.dense_residual:
                    y = y + mlp_block(p["dense_mlp"], hn2)
            else:
                y = mlp_block(p["mlp"], hn2)
            h = h + y
            c1 = jax.lax.dynamic_update_index_in_dim(c1, c1_l, li, 0)
            c2 = jax.lax.dynamic_update_index_in_dim(c2, c2_l, li, 0)
            return (h, c1, c2), None
        return step

    if cfg.first_dense_layers:
        c1, c2 = state["dense_cache"]
        (h, c1, c2), _ = jax.lax.scan(
            mk_step(False), (h, c1, c2),
            (params["dense_blocks"], jnp.arange(cfg.first_dense_layers)))
        new_state["dense_cache"] = (c1, c2)
    c1, c2 = state["moe_cache"]
    n_moe = cfg.n_layers - cfg.first_dense_layers
    (h, c1, c2), _ = jax.lax.scan(
        mk_step(True), (h, c1, c2),
        (params["moe_blocks"], jnp.arange(n_moe)))
    new_state["moe_cache"] = (c1, c2)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _head(cfg, params, h), new_state


# ================================================================ hybrid (zamba2)
def _hybrid_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, n_tail): groups of (attn_every mamba + 1 shared attn)."""
    n_groups = cfg.n_layers // cfg.attn_every
    return n_groups, cfg.n_layers - n_groups * cfg.attn_every


def _hybrid_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    ke, kg, kt, ka = jax.random.split(key, 4)
    G, tail = _hybrid_layout(cfg)

    def mamba_layer(k):
        return {"norm": jnp.zeros((cfg.d_model,), dt),
                "mamba": ssm_mod.init_mamba2(k, cfg, dt, cfg.n_layers)}

    p = {"embed": _embed_init(cfg, ke),
         "groups": jax.vmap(lambda k: stacked_init(
             mamba_layer, k, cfg.attn_every))(jax.random.split(kg, G)),
         "shared_attn": {"ln": jnp.zeros((cfg.d_model,), dt),
                         "attn": init_attn(ka, _dims(cfg), dt, cfg.n_layers),
                         "ln2": jnp.zeros((cfg.d_model,), dt),
                         "mlp": init_mlp(jax.random.fold_in(ka, 1),
                                         cfg.d_model, cfg.d_ff, dt,
                                         cfg.n_layers)},
         "final_norm": jnp.zeros((cfg.d_model,), dt)}
    if tail:
        p["tail"] = stacked_init(mamba_layer, kt, tail)
    return p


def _hybrid_hidden(cfg: ModelConfig, params: Pytree, tokens: jax.Array,
                   *, build_cache: int = 0):
    h = _embed(params, tokens)
    positions = _positions(tokens)
    B, S = tokens.shape
    sa = params["shared_attn"]
    dims = _dims(cfg)

    mamba_body = _remat(lambda p, h: h + ssm_mod.mamba2_block(
        cfg, p["mamba"], rms_norm(h, p["norm"], cfg.norm_eps)))

    def attn_apply(h):
        hn = rms_norm(h, sa["ln"], cfg.norm_eps)
        q = (hn @ sa["attn"]["wq"]).reshape(B, S, dims.n_heads, dims.hd)
        k = (hn @ sa["attn"]["wk"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        v = (hn @ sa["attn"]["wv"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention(q, k, v, q_pos=positions, k_pos=positions, causal=True)
        h = h + o.reshape(B, S, dims.n_heads * dims.hd) @ sa["attn"]["wo"]
        h = h + mlp_block(sa["mlp"], rms_norm(h, sa["ln2"], cfg.norm_eps))
        return h, (k, v)

    def group_step(h, gp):
        h, _ = jax.lax.scan(lambda h, p: (mamba_body(p, h), None), h, gp)
        h, (k, v) = attn_apply(h)
        if build_cache:
            pad = build_cache - S
            return h, (jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                       jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
        return h, None

    if not build_cache:
        # group-granular remat: residual carry saved 13x not 81x
        body = _remat(lambda gp, h: group_step(h, gp)[0])
        h, cache = jax.lax.scan(lambda h, gp: (body(gp, h), None), h,
                                params["groups"]), None
        h = h[0] if isinstance(h, tuple) else h
    else:
        h, cache = jax.lax.scan(group_step, h, params["groups"])
    if "tail" in params:
        h, _ = jax.lax.scan(lambda h, p: (mamba_body(p, h), None), h,
                            params["tail"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps), cache


def _hybrid_train(cfg: ModelConfig, params: Pytree, batch: Pytree):
    h, _ = _hybrid_hidden(cfg, params, batch["tokens"])
    logits = _head(cfg, params, h)
    loss = _lm_loss(cfg, logits, batch["labels"])
    return loss, {"loss": loss}


def _hybrid_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Pytree:
    dt = _dtype(cfg)
    G, tail = _hybrid_layout(cfg)
    dims = _dims(cfg)
    d_in, H, P, N = ssm_mod.ssm_dims(cfg)
    conv_ch = d_in + 2 * N

    def mamba_states(n):
        return {"conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, conv_ch),
                                  jnp.float32),
                "ssm": jnp.zeros((n, batch, H, P, N), jnp.float32)}

    st = {"pos": jnp.zeros((batch,), jnp.int32),
          "groups": mamba_states(G * cfg.attn_every),
          "attn_k": jnp.zeros((G, batch, max_seq, dims.n_kv_heads, dims.hd), dt),
          "attn_v": jnp.zeros((G, batch, max_seq, dims.n_kv_heads, dims.hd), dt)}
    if tail:
        st["tail"] = mamba_states(tail)
    return st


def _hybrid_prefill(cfg: ModelConfig, params: Pytree, batch: Pytree,
                    max_seq: int):
    """Parallel (chunked-SSD) pass that also exports exact decode states:
    mamba2_block(return_state=True) yields the post-sequence conv/SSM states,
    and each shared-attention application emits its K/V cache."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed(params, tokens)
    positions = _positions(tokens)
    sa = params["shared_attn"]
    dims = _dims(cfg)
    pad = max_seq - S

    def mamba_step(h, p):
        y, st = ssm_mod.mamba2_block(cfg, p["mamba"],
                                     rms_norm(h, p["norm"], cfg.norm_eps),
                                     return_state=True)
        return h + y, st

    def group_step(h, gp):
        h, states = jax.lax.scan(mamba_step, h, gp)
        hn = rms_norm(h, sa["ln"], cfg.norm_eps)
        q = (hn @ sa["attn"]["wq"]).reshape(B, S, dims.n_heads, dims.hd)
        k = (hn @ sa["attn"]["wk"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        v = (hn @ sa["attn"]["wv"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention(q, k, v, q_pos=positions, k_pos=positions, causal=True)
        h = h + o.reshape(B, S, dims.n_heads * dims.hd) @ sa["attn"]["wo"]
        h = h + mlp_block(sa["mlp"], rms_norm(h, sa["ln2"], cfg.norm_eps))
        return h, (states,
                   jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                   jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))

    G, tail = _hybrid_layout(cfg)
    h, (gstates, ck, cv) = jax.lax.scan(group_step, h, params["groups"])
    state = {"pos": jnp.full((B,), S, jnp.int32),
             "groups": jax.tree.map(
                 lambda a: a.reshape(G * cfg.attn_every, *a.shape[2:]),
                 gstates),
             "attn_k": ck, "attn_v": cv}
    if tail:
        h, tstates = jax.lax.scan(mamba_step, h, params["tail"])
        state["tail"] = tstates
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _head(cfg, params, h[:, -1:]), state


def _hybrid_decode(cfg: ModelConfig, params: Pytree, state: Pytree,
                   tokens: jax.Array):
    B = tokens.shape[0]
    pos = state["pos"]
    dims = _dims(cfg)
    sa = params["shared_attn"]
    bidx = jnp.arange(B)
    G, tail = _hybrid_layout(cfg)
    A = cfg.attn_every

    def mamba_step(carry, x):
        h, conv, ssm = carry
        p, li = x
        cs = jax.lax.dynamic_index_in_dim(conv, li, 0, keepdims=False)
        ss = jax.lax.dynamic_index_in_dim(ssm, li, 0, keepdims=False)
        y, new = ssm_mod.mamba2_step(
            cfg, p["mamba"], {"conv": cs, "ssm": ss},
            rms_norm(h, p["norm"], cfg.norm_eps))
        h = h + y
        conv = jax.lax.dynamic_update_index_in_dim(conv, new["conv"], li, 0)
        ssm = jax.lax.dynamic_update_index_in_dim(ssm, new["ssm"], li, 0)
        return (h, conv, ssm), None

    def group_step(carry, x):
        h, conv, ssm, ak, av = carry
        gp, gi = x
        lids = gi * A + jnp.arange(A)
        (h, conv, ssm), _ = jax.lax.scan(mamba_step, (h, conv, ssm),
                                         (gp, lids))
        hn = rms_norm(h, sa["ln"], cfg.norm_eps)
        q = (hn @ sa["attn"]["wq"]).reshape(B, 1, dims.n_heads, dims.hd)
        k = (hn @ sa["attn"]["wk"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
        v = (hn @ sa["attn"]["wv"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
        k_g = jax.lax.dynamic_index_in_dim(ak, gi, 0, keepdims=False)
        v_g = jax.lax.dynamic_index_in_dim(av, gi, 0, keepdims=False)
        k_g = k_g.at[bidx, pos].set(k[:, 0])
        v_g = v_g.at[bidx, pos].set(v[:, 0])
        o = decode_attention(q, k_g, v_g, q_pos=pos)
        h = h + o.reshape(B, 1, dims.n_heads * dims.hd) @ sa["attn"]["wo"]
        h = h + mlp_block(sa["mlp"], rms_norm(h, sa["ln2"], cfg.norm_eps))
        ak = jax.lax.dynamic_update_index_in_dim(ak, k_g, gi, 0)
        av = jax.lax.dynamic_update_index_in_dim(av, v_g, gi, 0)
        return (h, conv, ssm, ak, av), None

    h = _embed(params, tokens)
    carry = (h, state["groups"]["conv"], state["groups"]["ssm"],
             state["attn_k"], state["attn_v"])
    carry, _ = jax.lax.scan(group_step, carry,
                            (params["groups"], jnp.arange(G)))
    h, conv, ssm, ak, av = carry
    new_state = {"pos": pos + 1, "groups": {"conv": conv, "ssm": ssm},
                 "attn_k": ak, "attn_v": av}
    if tail:
        tconv, tssm = state["tail"]["conv"], state["tail"]["ssm"]
        (h, tconv, tssm), _ = jax.lax.scan(
            mamba_step, (h, tconv, tssm),
            (params["tail"], jnp.arange(tail)))
        new_state["tail"] = {"conv": tconv, "ssm": tssm}
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _head(cfg, params, h), new_state


# ======================================================================= rwkv
def _rwkv_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    ke, kb = jax.random.split(key)

    def block(k):
        kb1, kb2 = jax.random.split(k)
        return {"ln1": jnp.zeros((cfg.d_model,), dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                **rwkv_mod.init_rwkv_block(kb1, cfg, dt, cfg.n_layers)}

    return {"embed": _embed_init(cfg, ke),
            "blocks": stacked_init(block, kb, cfg.n_layers),
            "final_norm": jnp.zeros((cfg.d_model,), dt)}


def _rwkv_train(cfg: ModelConfig, params: Pytree, batch: Pytree):
    h = _embed(params, batch["tokens"])

    def body(p, h):
        out, _, _ = rwkv_mod.time_mix(cfg, p["tm"],
                                      rms_norm(h, p["ln1"], cfg.norm_eps))
        h = h + out
        out, _ = rwkv_mod.channel_mix(cfg, p["cm"],
                                      rms_norm(h, p["ln2"], cfg.norm_eps))
        return h + out

    body = _remat(body)
    h, _ = jax.lax.scan(lambda h, p: (body(p, h), None), h, params["blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    loss = _lm_loss(cfg, logits, batch["labels"])
    return loss, {"loss": loss}


def _rwkv_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Pytree:
    H, K = rwkv_mod.rwkv_dims(cfg)
    L, d = cfg.n_layers, cfg.d_model
    return {"pos": jnp.zeros((batch,), jnp.int32),
            "tm_x": jnp.zeros((L, batch, d), jnp.float32),
            "cm_x": jnp.zeros((L, batch, d), jnp.float32),
            "wkv": jnp.zeros((L, batch, H, K, K), jnp.float32)}


def _rwkv_forward_stateful(cfg: ModelConfig, params: Pytree, state: Pytree,
                           tokens: jax.Array):
    """Runs S tokens (S>=1) carrying recurrent state — decode AND prefill."""
    h = _embed(params, tokens)

    def step(carry, x):
        h, tmx, cmx, wkv = carry
        p, li = x
        tm_last = jax.lax.dynamic_index_in_dim(tmx, li, 0, keepdims=False)
        cm_last = jax.lax.dynamic_index_in_dim(cmx, li, 0, keepdims=False)
        S0 = jax.lax.dynamic_index_in_dim(wkv, li, 0, keepdims=False)
        out, tm_new, S1 = rwkv_mod.time_mix(
            cfg, p["tm"], rms_norm(h, p["ln1"], cfg.norm_eps),
            last_x=tm_last, state=S0)
        h = h + out
        out, cm_new = rwkv_mod.channel_mix(
            cfg, p["cm"], rms_norm(h, p["ln2"], cfg.norm_eps), last_x=cm_last)
        h = h + out
        tmx = jax.lax.dynamic_update_index_in_dim(
            tmx, tm_new.astype(jnp.float32), li, 0)
        cmx = jax.lax.dynamic_update_index_in_dim(
            cmx, cm_new.astype(jnp.float32), li, 0)
        wkv = jax.lax.dynamic_update_index_in_dim(wkv, S1, li, 0)
        return (h, tmx, cmx, wkv), None

    carry = (h, state["tm_x"], state["cm_x"], state["wkv"])
    carry, _ = jax.lax.scan(step, carry,
                            (params["blocks"], jnp.arange(cfg.n_layers)))
    h, tmx, cmx, wkv = carry
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    new_state = {"pos": state["pos"] + tokens.shape[1], "tm_x": tmx,
                 "cm_x": cmx, "wkv": wkv}
    return _head(cfg, params, h), new_state


def _rwkv_prefill(cfg: ModelConfig, params: Pytree, batch: Pytree,
                  max_seq: int):
    state = _rwkv_decode_state(cfg, batch["tokens"].shape[0], max_seq)
    logits, state = _rwkv_forward_stateful(cfg, params, state, batch["tokens"])
    return logits[:, -1:], state


def _rwkv_decode(cfg: ModelConfig, params: Pytree, state: Pytree,
                 tokens: jax.Array):
    return _rwkv_forward_stateful(cfg, params, state, tokens)


# ==================================================================== encdec
def _sinusoid(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def _encdec_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    ke, kenc, kdec = jax.random.split(key, 3)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.zeros((cfg.d_model,), dt),
                "attn": init_attn(k1, _dims(cfg), dt, cfg.encoder_layers),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dt,
                                cfg.encoder_layers, gated=False)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.zeros((cfg.d_model,), dt),
                "attn": init_attn(k1, _dims(cfg), dt, cfg.n_layers),
                "lnx": jnp.zeros((cfg.d_model,), dt),
                "xattn": init_attn(k2, _dims(cfg), dt, cfg.n_layers),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dt, cfg.n_layers,
                                gated=False)}

    return {"embed": _embed_init(cfg, ke),
            "enc_blocks": stacked_init(enc_block, kenc, cfg.encoder_layers),
            "enc_norm": jnp.zeros((cfg.d_model,), dt),
            "dec_blocks": stacked_init(dec_block, kdec, cfg.n_layers),
            "final_norm": jnp.zeros((cfg.d_model,), dt)}


def _encode(cfg: ModelConfig, params: Pytree, frames: jax.Array) -> jax.Array:
    B, F, d = frames.shape
    h = frames + jnp.asarray(_sinusoid(F, d), frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
    dims = _dims(cfg)

    def body(p, h):
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q = (hn @ p["attn"]["wq"]).reshape(B, F, dims.n_heads, dims.hd)
        k = (hn @ p["attn"]["wk"]).reshape(B, F, dims.n_kv_heads, dims.hd)
        v = (hn @ p["attn"]["wv"]).reshape(B, F, dims.n_kv_heads, dims.hd)
        o = attention(q, k, v, q_pos=positions, k_pos=positions, causal=False)
        h = h + o.reshape(B, F, dims.n_heads * dims.hd) @ p["attn"]["wo"]
        return h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))

    body = _remat(body)
    h, _ = jax.lax.scan(lambda h, p: (body(p, h), None), h,
                        params["enc_blocks"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _encdec_train(cfg: ModelConfig, params: Pytree, batch: Pytree):
    enc_out = _encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed(params, tokens)
    positions = _positions(tokens)
    dims = _dims(cfg)

    def body(p, h):
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q = (hn @ p["attn"]["wq"]).reshape(B, S, dims.n_heads, dims.hd)
        k = (hn @ p["attn"]["wk"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        v = (hn @ p["attn"]["wv"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention(q, k, v, q_pos=positions, k_pos=positions, causal=True)
        h = h + o.reshape(B, S, dims.n_heads * dims.hd) @ p["attn"]["wo"]
        h = h + cross_attention_block(p["xattn"],
                                      rms_norm(h, p["lnx"], cfg.norm_eps),
                                      enc_out, dims)
        return h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))

    body = _remat(body)
    h, _ = jax.lax.scan(lambda h, p: (body(p, h), None), h,
                        params["dec_blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    loss = _lm_loss(cfg, logits, batch["labels"])
    return loss, {"loss": loss}


def _encdec_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Pytree:
    dt = _dtype(cfg)
    dims = _dims(cfg)
    L = cfg.n_layers
    return {"pos": jnp.zeros((batch,), jnp.int32),
            "k": jnp.zeros((L, batch, max_seq, dims.n_kv_heads, dims.hd), dt),
            "v": jnp.zeros((L, batch, max_seq, dims.n_kv_heads, dims.hd), dt),
            "xk": jnp.zeros((L, batch, cfg.n_frames, dims.n_kv_heads,
                             dims.hd), dt),
            "xv": jnp.zeros((L, batch, cfg.n_frames, dims.n_kv_heads,
                             dims.hd), dt)}


def _encdec_prefill(cfg: ModelConfig, params: Pytree, batch: Pytree,
                    max_seq: int):
    """Encode frames, precompute cross K/V, then run the prompt through the
    decoder building the self-attn cache."""
    enc_out = _encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed(params, tokens)
    positions = _positions(tokens)
    dims = _dims(cfg)
    F = enc_out.shape[1]

    def body(h, p):
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q = (hn @ p["attn"]["wq"]).reshape(B, S, dims.n_heads, dims.hd)
        k = (hn @ p["attn"]["wk"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        v = (hn @ p["attn"]["wv"]).reshape(B, S, dims.n_kv_heads, dims.hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention(q, k, v, q_pos=positions, k_pos=positions, causal=True)
        h = h + o.reshape(B, S, dims.n_heads * dims.hd) @ p["attn"]["wo"]
        hx = rms_norm(h, p["lnx"], cfg.norm_eps)
        xk = (enc_out @ p["xattn"]["wk"]).reshape(B, F, dims.n_kv_heads,
                                                  dims.hd)
        xv = (enc_out @ p["xattn"]["wv"]).reshape(B, F, dims.n_kv_heads,
                                                  dims.hd)
        h = h + cross_attention_block(p["xattn"], hx, enc_out, dims)
        h = h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))
        pad = max_seq - S
        return h, (jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                   jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))), xk, xv)

    h, (ck, cv, xk, xv) = jax.lax.scan(body, h, params["dec_blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    state = {"pos": jnp.full((B,), S, jnp.int32), "k": ck, "v": cv,
             "xk": xk, "xv": xv}
    return _head(cfg, params, h[:, -1:]), state


def _encdec_decode(cfg: ModelConfig, params: Pytree, state: Pytree,
                   tokens: jax.Array):
    B = tokens.shape[0]
    pos = state["pos"]
    dims = _dims(cfg)
    bidx = jnp.arange(B)
    h = _embed(params, tokens)

    def step(carry, x):
        h, ck, cv = carry
        p, li, xk_l, xv_l = x
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q = (hn @ p["attn"]["wq"]).reshape(B, 1, dims.n_heads, dims.hd)
        k = (hn @ p["attn"]["wk"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
        v = (hn @ p["attn"]["wv"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
        k_l = jax.lax.dynamic_index_in_dim(ck, li, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(cv, li, 0, keepdims=False)
        k_l = k_l.at[bidx, pos].set(k[:, 0])
        v_l = v_l.at[bidx, pos].set(v[:, 0])
        o = decode_attention(q, k_l, v_l, q_pos=pos)
        h = h + o.reshape(B, 1, dims.n_heads * dims.hd) @ p["attn"]["wo"]
        # cross attention against the precomputed encoder K/V
        hx = rms_norm(h, p["lnx"], cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"]).reshape(B, 1, dims.n_heads, dims.hd)
        F = xk_l.shape[1]
        ox = decode_attention(qx, xk_l, xv_l,
                              q_pos=jnp.full((B,), F - 1, jnp.int32))
        h = h + ox.reshape(B, 1, dims.n_heads * dims.hd) @ p["xattn"]["wo"]
        h = h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))
        ck = jax.lax.dynamic_update_index_in_dim(ck, k_l, li, 0)
        cv = jax.lax.dynamic_update_index_in_dim(cv, v_l, li, 0)
        return (h, ck, cv), None

    (h, ck, cv), _ = jax.lax.scan(
        step, (h, state["k"], state["v"]),
        (params["dec_blocks"], jnp.arange(cfg.n_layers), state["xk"],
         state["xv"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    new_state = dict(state, pos=pos + 1, k=ck, v=cv)
    return _head(cfg, params, h), new_state


# ======================================================================== vlm
def _vlm_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, self_per_group): groups of (self×k + 1 cross)."""
    per = cfg.cross_every
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per, per - 1


def _vlm_init(cfg: ModelConfig, key: jax.Array) -> Pytree:
    dt = _dtype(cfg)
    G, S_per = _vlm_layout(cfg)
    ke, ks, kx = jax.random.split(key, 3)

    def cross_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln": jnp.zeros((cfg.d_model,), dt),
                "attn": init_attn(k1, _dims(cfg), dt, cfg.n_layers),
                "gate": jnp.zeros((), jnp.float32),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dt, cfg.n_layers),
                "gate_mlp": jnp.zeros((), jnp.float32)}

    return {"embed": _embed_init(cfg, ke),
            "self_groups": jax.vmap(lambda k: stacked_init(
                partial(_dense_block_init, cfg), k, S_per))(
                    jax.random.split(ks, G)),
            "cross_blocks": stacked_init(cross_block, kx, G),
            "final_norm": jnp.zeros((cfg.d_model,), dt)}


def _vlm_hidden(cfg: ModelConfig, params: Pytree, tokens, patches):
    h = _embed(params, tokens)
    positions = _positions(tokens)
    dims = _dims(cfg)
    zero_w = jnp.zeros((), jnp.int32)

    self_body = _remat(lambda p, h: _gqa_layer(cfg, p, h, positions, zero_w))

    def group_body(gp, h):
        sp, xp = gp
        h, _ = jax.lax.scan(lambda h, p: (self_body(p, h), None), h, sp)
        hn = rms_norm(h, xp["ln"], cfg.norm_eps)
        xo = cross_attention_block(xp["attn"], hn, patches, dims)
        h = h + jnp.tanh(xp["gate"]).astype(h.dtype) * xo
        h = h + jnp.tanh(xp["gate_mlp"]).astype(h.dtype) * mlp_block(
            xp["mlp"], rms_norm(h, xp["ln2"], cfg.norm_eps))
        return h

    # remat at GROUP granularity: the scan carry (B,S,d) is saved once per
    # group (20x) instead of per layer (100x) — 5x cut on saved residuals.
    group_body = _remat(group_body)
    h, _ = jax.lax.scan(lambda h, gp: (group_body(gp, h), None), h,
                        (params["self_groups"], params["cross_blocks"]))
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def _vlm_train(cfg: ModelConfig, params: Pytree, batch: Pytree):
    h = _vlm_hidden(cfg, params, batch["tokens"], batch["patches"])
    logits = _head(cfg, params, h)
    loss = _lm_loss(cfg, logits, batch["labels"])
    return loss, {"loss": loss}


def _vlm_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Pytree:
    dt = _dtype(cfg)
    dims = _dims(cfg)
    G, S_per = _vlm_layout(cfg)
    return {"pos": jnp.zeros((batch,), jnp.int32),
            "k": jnp.zeros((G, S_per, batch, max_seq, dims.n_kv_heads,
                            dims.hd), dt),
            "v": jnp.zeros((G, S_per, batch, max_seq, dims.n_kv_heads,
                            dims.hd), dt),
            "xk": jnp.zeros((G, batch, cfg.n_patches, dims.n_kv_heads,
                             dims.hd), dt),
            "xv": jnp.zeros((G, batch, cfg.n_patches, dims.n_kv_heads,
                             dims.hd), dt)}


def _vlm_prefill(cfg: ModelConfig, params: Pytree, batch: Pytree,
                 max_seq: int):
    tokens, patches = batch["tokens"], batch["patches"]
    B, S = tokens.shape
    h = _embed(params, tokens)
    positions = _positions(tokens)
    dims = _dims(cfg)
    pad = max_seq - S

    def group_step(h, gp):
        sp, xp = gp

        def self_step(hh, p):
            hh, (k, v) = _gqa_layer(cfg, p, hh, positions, 0,
                                    build_cache=max_seq)
            return hh, (k, v)

        h, (ks, vs) = jax.lax.scan(self_step, h, sp)       # (S_per, B, ...)
        hn = rms_norm(h, xp["ln"], cfg.norm_eps)
        xk = (patches @ xp["attn"]["wk"]).reshape(B, cfg.n_patches,
                                                  dims.n_kv_heads, dims.hd)
        xv = (patches @ xp["attn"]["wv"]).reshape(B, cfg.n_patches,
                                                  dims.n_kv_heads, dims.hd)
        xo = cross_attention_block(xp["attn"], hn, patches, dims)
        h = h + jnp.tanh(xp["gate"]).astype(h.dtype) * xo
        h = h + jnp.tanh(xp["gate_mlp"]).astype(h.dtype) * mlp_block(
            xp["mlp"], rms_norm(h, xp["ln2"], cfg.norm_eps))
        return h, (ks, vs, xk, xv)

    h, (ck, cv, xk, xv) = jax.lax.scan(
        group_step, h, (params["self_groups"], params["cross_blocks"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    state = {"pos": jnp.full((B,), S, jnp.int32), "k": ck, "v": cv,
             "xk": xk, "xv": xv}
    return _head(cfg, params, h[:, -1:]), state


def _vlm_decode(cfg: ModelConfig, params: Pytree, state: Pytree,
                tokens: jax.Array):
    B = tokens.shape[0]
    pos = state["pos"]
    dims = _dims(cfg)
    bidx = jnp.arange(B)
    G, S_per = _vlm_layout(cfg)
    h = _embed(params, tokens)

    def group_step(carry, x):
        h, ck, cv = carry
        sp, xp, gi, xk_g, xv_g = x

        def self_step(carry2, x2):
            h, ck, cv = carry2
            p, si = x2
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            q = (hn @ p["attn"]["wq"]).reshape(B, 1, dims.n_heads, dims.hd)
            k = (hn @ p["attn"]["wk"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
            v = (hn @ p["attn"]["wv"]).reshape(B, 1, dims.n_kv_heads, dims.hd)
            q = apply_rope(q, pos[:, None], cfg.rope_theta)
            k = apply_rope(k, pos[:, None], cfg.rope_theta)
            idx = gi * S_per + si
            k_l = jax.lax.dynamic_index_in_dim(
                ck.reshape(G * S_per, *ck.shape[2:]), idx, 0, keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(
                cv.reshape(G * S_per, *cv.shape[2:]), idx, 0, keepdims=False)
            k_l = k_l.at[bidx, pos].set(k[:, 0])
            v_l = v_l.at[bidx, pos].set(v[:, 0])
            o = decode_attention(q, k_l, v_l, q_pos=pos)
            h = h + o.reshape(B, 1, dims.n_heads * dims.hd) @ p["attn"]["wo"]
            h = h + mlp_block(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps))
            ck = jax.lax.dynamic_update_index_in_dim(
                ck.reshape(G * S_per, *ck.shape[2:]), k_l, idx, 0
            ).reshape(ck.shape)
            cv = jax.lax.dynamic_update_index_in_dim(
                cv.reshape(G * S_per, *cv.shape[2:]), v_l, idx, 0
            ).reshape(cv.shape)
            return (h, ck, cv), None

        (h, ck, cv), _ = jax.lax.scan(self_step, (h, ck, cv),
                                      (sp, jnp.arange(S_per)))
        hx = rms_norm(h, xp["ln"], cfg.norm_eps)
        qx = (hx @ xp["attn"]["wq"]).reshape(B, 1, dims.n_heads, dims.hd)
        P = xk_g.shape[1]
        ox = decode_attention(qx, xk_g, xv_g,
                              q_pos=jnp.full((B,), P - 1, jnp.int32))
        h = h + jnp.tanh(xp["gate"]).astype(h.dtype) * (
            ox.reshape(B, 1, dims.n_heads * dims.hd) @ xp["attn"]["wo"])
        h = h + jnp.tanh(xp["gate_mlp"]).astype(h.dtype) * mlp_block(
            xp["mlp"], rms_norm(h, xp["ln2"], cfg.norm_eps))
        return (h, ck, cv), None

    (h, ck, cv), _ = jax.lax.scan(
        group_step, (h, state["k"], state["v"]),
        (params["self_groups"], params["cross_blocks"], jnp.arange(G),
         state["xk"], state["xv"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    new_state = dict(state, pos=pos + 1, k=ck, v=cv)
    return _head(cfg, params, h), new_state


# ================================================================== dispatch
_FAMILY = {
    "dense": (_dense_init, _dense_train, _dense_prefill, _dense_decode_state,
              _dense_decode),
    "localglobal": (_dense_init, _dense_train, _dense_prefill,
                    _dense_decode_state, _dense_decode),
    "moe": (_moe_init, _moe_train, _moe_prefill, _moe_decode_state,
            _moe_decode),
    "hybrid": (_hybrid_init, _hybrid_train, _hybrid_prefill,
               _hybrid_decode_state, _hybrid_decode),
    "rwkv": (_rwkv_init, _rwkv_train, _rwkv_prefill, _rwkv_decode_state,
             _rwkv_decode),
    "encdec": (_encdec_init, _encdec_train, _encdec_prefill,
               _encdec_decode_state, _encdec_decode),
    "vlm": (_vlm_init, _vlm_train, _vlm_prefill, _vlm_decode_state,
            _vlm_decode),
}


def init_params(cfg: ModelConfig, key: jax.Array) -> Pytree:
    cfg.validate()
    return _FAMILY[cfg.family][0](cfg, key)


def loss_fn(cfg: ModelConfig, params: Pytree, batch: Pytree):
    return _FAMILY[cfg.family][1](cfg, params, batch)


def prefill(cfg: ModelConfig, params: Pytree, batch: Pytree, max_seq: int):
    return _FAMILY[cfg.family][2](cfg, params, batch, max_seq)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Pytree:
    return _FAMILY[cfg.family][3](cfg, batch, max_seq)


def decode_step(cfg: ModelConfig, params: Pytree, state: Pytree,
                tokens: jax.Array):
    return _FAMILY[cfg.family][4](cfg, params, state, tokens)


# ------------------------------------------------------------------- counts
def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: only k routed experts active)."""
    total = param_count(cfg)
    if not cfg.is_moe:
        return total
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = (cfg.n_experts - cfg.experts_per_token) * per_expert \
        * n_moe_layers
    return total - int(inactive)
