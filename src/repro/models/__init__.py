"""Model zoo: 10 assigned architectures behind one functional API."""

from repro.models.model import (active_param_count, decode_step,
                                init_decode_state, init_params, loss_fn,
                                param_count, padded_vocab, prefill)

__all__ = ["init_params", "loss_fn", "prefill", "init_decode_state",
           "decode_step", "param_count", "active_param_count", "padded_vocab"]
