"""Multi-head Latent Attention (DeepSeek-V3).

Train/prefill: the latent ``c_kv`` is expanded to per-head keys/values
(standard formulation). Decode: the **absorbed** formulation — queries are
folded through ``W_uk`` into latent space so the per-token cache is only
``kv_lora_rank + rope_dim`` floats (the whole point of MLA: a 576-wide cache
instead of H*(192+128)), and attention runs directly against the latent cache.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import (NEG_INF, apply_rope, attention, init_linear,
                                 rms_norm)

Pytree = Any


def init_mla(key: jax.Array, cfg: ModelConfig, dtype, n_layers: int = 1) -> Pytree:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq_a": init_linear(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": init_linear(ks[1], m.q_lora_rank, H * m.qk_head_dim, dtype),
        "wkv_a": init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                             dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wkv_b": init_linear(ks[3], m.kv_lora_rank,
                             H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": init_linear(ks[4], H * m.v_head_dim, d, dtype,
                          scale=1.0 / np.sqrt(H * m.v_head_dim)
                          / np.sqrt(2.0 * n_layers)),
    }


def _queries(cfg: ModelConfig, p: Pytree, x: jax.Array, positions: jax.Array):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(cfg: ModelConfig, p: Pytree, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    kv_a = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope                        # (B,S,kv_lora), (B,S,rope)


def mla_attention(cfg: ModelConfig, p: Pytree, x: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """Full-sequence causal MLA (train / prefill)."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    q_nope, q_rope = _queries(cfg, p, x, positions)
    c_kv, k_rope = _latents(cfg, p, x, positions)
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, S, H, m.qk_rope_head_dim))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = attention(q, k, v, q_pos=positions, k_pos=positions, causal=True,
                  softmax_scale=m.qk_head_dim ** -0.5)
    return o.reshape(B, S, H * m.v_head_dim) @ p["wo"]


def mla_prefill_cache(cfg: ModelConfig, p: Pytree, x: jax.Array,
                      positions: jax.Array, max_seq: int) -> Pytree:
    """Latent cache for decode, zero-padded to ``max_seq``."""
    B, S, _ = x.shape
    c_kv, k_rope = _latents(cfg, p, x, positions)
    pad = max_seq - S
    return {"c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
            "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))}


def mla_init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Pytree:
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype)}


def mla_decode(cfg: ModelConfig, p: Pytree, x: jax.Array, cache: Pytree,
               pos: jax.Array) -> tuple[jax.Array, Pytree]:
    """Absorbed-form single-token decode. x: (B, 1, d); pos: (B,)."""
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    positions = pos[:, None]
    q_nope, q_rope = _queries(cfg, p, x, positions)      # (B,1,H,·)
    c_new, kr_new = _latents(cfg, p, x, positions)       # (B,1,·)

    bidx = jnp.arange(B)
    c_kv = cache["c_kv"].at[bidx, pos].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[bidx, pos].set(
        kr_new[:, 0].astype(cache["k_rope"].dtype))

    # absorb W_uk into the query: q̃_h = q_nope_h @ W_uk_h  -> latent space
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[:, :, : m.qk_nope_head_dim]             # (c, H, nope)
    w_uv = wkv_b[:, :, m.qk_nope_head_dim:]              # (c, H, v)
    q_lat = jnp.einsum("bhd,chd->bhc", q_nope[:, 0], w_uk)

    S = c_kv.shape[1]
    scores = (jnp.einsum("bhc,bsc->bhs", q_lat, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0], k_rope,
                           preferred_element_type=jnp.float32))
    scores = scores * (m.qk_head_dim ** -0.5)
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    scores = scores + jnp.where(valid, 0.0, NEG_INF)[:, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsc->bhc", probs.astype(c_kv.dtype), c_kv)
    o = jnp.einsum("bhc,chv->bhv", o_lat, w_uv)          # (B,H,v)
    out = o.reshape(B, 1, H * m.v_head_dim) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
