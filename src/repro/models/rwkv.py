"""RWKV-6 "Finch" block — attention-free, data-dependent decay.

Faithful structure: token-shift with data-dependent lerp (low-rank), per-channel
decay ``w = exp(-exp(·))`` produced by a LoRA head, the WKV matrix-state
recurrence with first-token bonus ``u``, per-head group norm, silu gate, and
the squared-ReLU channel-mix. (Low-rank sizes follow the 1.6B release.)

The recurrence runs as ``lax.scan`` over time with an (B, H, K, V) f32 state —
on TPU this lowers to a fused while-loop; FLOPs are negligible next to the
channel mix so the scan is not the roofline term (see EXPERIMENTS §Roofline).
Decode is the same step function applied once.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import init_linear, uniform_scale_init

Pytree = Any

TM_LORA = 32      # token-shift lerp low-rank
W_LORA = 64       # decay low-rank


def rwkv_dims(cfg: ModelConfig) -> tuple[int, int]:
    K = cfg.rwkv_head_dim
    assert cfg.d_model % K == 0
    return cfg.d_model // K, K      # (heads, head_dim)


def init_rwkv_block(key: jax.Array, cfg: ModelConfig, dtype,
                    n_layers: int = 1) -> Pytree:
    d = cfg.d_model
    H, K = rwkv_dims(cfg)
    ks = jax.random.split(key, 16)
    out_scale = 1.0 / np.sqrt(d) / np.sqrt(2.0 * n_layers)
    return {
        "tm": {  # time-mix (wkv) ------------------------------------------------
            "mu": uniform_scale_init(ks[0], (5, d), dtype, 0.5),
            "tm_w1": init_linear(ks[1], d, 5 * TM_LORA, dtype),
            "tm_w2": uniform_scale_init(ks[2], (5, TM_LORA, d), dtype),
            "w0": jnp.full((d,), -2.0, jnp.float32),
            "w_w1": init_linear(ks[3], d, W_LORA, dtype),
            "w_w2": uniform_scale_init(ks[4], (W_LORA, d), dtype),
            "wr": init_linear(ks[5], d, d, dtype),
            "wk": init_linear(ks[6], d, d, dtype),
            "wv": init_linear(ks[7], d, d, dtype),
            "wg": init_linear(ks[8], d, d, dtype),
            "u": uniform_scale_init(ks[9], (H, K), jnp.float32, 0.3),
            "gn": jnp.zeros((d,), dtype),       # per-head group-norm gain
            "wo": init_linear(ks[10], d, d, dtype, scale=out_scale),
        },
        "cm": {  # channel-mix ---------------------------------------------------
            "mu_k": uniform_scale_init(ks[11], (d,), dtype, 0.5),
            "mu_r": uniform_scale_init(ks[12], (d,), dtype, 0.5),
            "wk": init_linear(ks[13], d, cfg.d_ff, dtype),
            "wv": init_linear(ks[14], cfg.d_ff, d, dtype, scale=out_scale),
            "wr": init_linear(ks[15], d, d, dtype),
        },
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """x_{t-1} along the sequence; ``last`` carries across decode steps."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _ddlerp(p: Pytree, x: jax.Array, xp: jax.Array):
    """Data-dependent lerp producing the 5 mixed inputs (r, k, v, w, g)."""
    delta = xp - x
    base = x + delta * p["mu"][0]                              # shared pre-mix
    lora = jnp.tanh(base @ p["tm_w1"])                         # (B,S,5*rank)
    lora = lora.reshape(*lora.shape[:-1], 5, TM_LORA)
    adj = jnp.einsum("bsfr,frd->bsfd", lora, p["tm_w2"])       # (B,S,5,d)
    mixed = x[..., None, :] + delta[..., None, :] * (p["mu"][None, None]
                                                     + adj)
    return [mixed[..., i, :] for i in range(5)]                # r,k,v,w,g


def _wkv_scan(r, k, v, w, u, state):
    """WKV recurrence. r/k/w: (B,S,H,K); v: (B,S,H,V); state: (B,H,K,V) f32.

    out_t = r_t · (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(S, inp):
        rt, kt, vt, wt = inp                                  # (B,H,K)/(B,H,V)
        kv = kt[..., :, None] * vt[..., None, :]              # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state, outs = jax.lax.scan(step, state, xs)
    return state, outs.transpose(1, 0, 2, 3)                  # (B,S,H,V)


def _group_norm(x: jax.Array, gain: jax.Array, H: int, eps: float) -> jax.Array:
    """Per-head layer norm of (B, S, d) viewed as (B, S, H, K)."""
    B, S, d = x.shape
    xh = x.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, S, d) * (1.0 + gain.astype(jnp.float32))).astype(x.dtype)


def time_mix(cfg: ModelConfig, p: Pytree, x: jax.Array, *,
             last_x: jax.Array | None = None,
             state: jax.Array | None = None):
    """RWKV time-mix. Returns (out, new_last_x, new_state)."""
    B, S, d = x.shape
    H, K = rwkv_dims(cfg)
    xp = _shift(x, last_x)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xp)
    r = (xr @ p["wr"]).reshape(B, S, H, K)
    k = (xk @ p["wk"]).reshape(B, S, H, K)
    v = (xv @ p["wv"]).reshape(B, S, H, K)
    g = jax.nn.silu(xg @ p["wg"])
    w_log = p["w0"] + jnp.tanh(xw @ p["w_w1"]).astype(jnp.float32) @ \
        p["w_w2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, S, H, K)           # (0,1)

    if state is None:
        state = jnp.zeros((B, H, K, K), jnp.float32)
    state, out = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), w, p["u"], state)
    out = _group_norm(out.reshape(B, S, d).astype(x.dtype), p["gn"], H,
                      cfg.norm_eps) * g
    return out @ p["wo"], x[:, -1, :], state


def channel_mix(cfg: ModelConfig, p: Pytree, x: jax.Array, *,
                last_x: jax.Array | None = None):
    xp = _shift(x, last_x)
    xk = x + (xp - x) * p["mu_k"]
    xr = x + (xp - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1, :]
