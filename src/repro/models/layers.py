"""Model primitives shared by all 10 architectures.

Everything is functional: ``init_*`` builds param pytrees, ``apply``-style
functions are pure. Attention is computed in query chunks with the scores kept
at chunk × key size (flash-style memory behaviour under XLA); the Pallas
kernels in :mod:`repro.kernels` implement the same math for the TPU hot path
and are validated against these functions.

Dtype policy: params and activations in ``cfg.dtype`` (bf16), softmax/norm
statistics in f32 — the standard TPU mixed-precision recipe.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Pytree = Any


# --------------------------------------------------------------------- init
def uniform_scale_init(key: jax.Array, shape: tuple[int, ...], dtype,
                       scale: float = 0.02) -> jax.Array:
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def init_linear(key: jax.Array, d_in: int, d_out: int, dtype,
                scale: float | None = None) -> jax.Array:
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return uniform_scale_init(key, (d_in, d_out), dtype, s)


# --------------------------------------------------------------------- norm
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return ((h * jax.lax.rsqrt(var + eps)).astype(x.dtype)
            * (1.0 + gamma.astype(x.dtype)))


# --------------------------------------------------------------------- rope
def rope_frequencies(hd: int, theta: float) -> jax.Array:
    """Inverse frequencies for the even half of the head dim (f32)."""
    half = hd // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate (…, S, H, hd) by per-position angles. ``positions``: (…, S)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
NEG_INF = -1e30


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
               window: jax.Array | int) -> jax.Array:
    """Additive mask bias (f32) of shape (…, Sq, Sk).

    ``window`` may be a traced scalar (per-layer value fed through
    ``lax.scan`` for the gemma local:global pattern); ``window <= 0`` means
    unwindowed, handled branchlessly.
    """
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    w = jnp.asarray(window)
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        ok &= dk <= dq
    ok &= (w <= 0) | (dq - dk < w)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_pos: jax.Array, k_pos: jax.Array, causal: bool = True,
              window: jax.Array | int = 0, q_chunk: int = 512,
              softmax_scale: float | None = None) -> jax.Array:
    """GQA attention, computed in query chunks (flash-style memory under XLA).

    q: (B, Sq, Hq, hd) — k/v: (B, Sk, Hkv, hd), Hq % Hkv == 0.
    positions are absolute (decode passes an offset q_pos).

    KV heads are expanded to Hq before the einsums so the whole computation
    shards on the model axis per q-head (a grouped (Hkv, G) layout cannot
    carry a 'model' sharding when Hkv < model; the Pallas kernel path keeps
    the grouped form on real TPUs). Sharding hints are no-ops without an
    active dist.hints.sharding_rules context.
    """
    from repro.dist.hints import hint, tp_divides  # no cycle at module load
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    if not tp_divides(Hq):
        # heads can't shard on model -> q shards on SEQ ('sq' below). The
        # chunk loop would reshape/rescatter that sharding every iteration
        # (measured: +4 TB of per-chunk K/V gathers on arctic train_4k), so
        # compute attention in one seq-sharded piece instead.
        q_chunk = max(q_chunk, Sq)

    # 'sq': when heads do not divide the model axis (arctic: 56 heads vs 16)
    # attention shards over the query-seq dim instead of replicating 16x.
    q = hint(q, "dp", "sq", "tp", None)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = hint(k, "dp", "sp", "tp", None)
    v = hint(v, "dp", "sp", "tp", None)

    def chunk_attn(q_c: jax.Array, qp_c: jax.Array) -> jax.Array:
        # q_c: (B, C, Hq, hd) -> scores (B, Hq, C, Sk) in f32
        s = jnp.einsum("bchd,bshd->bhcs", q_c, k,
                       preferred_element_type=jnp.float32) * scale
        s = hint(s, "dp", "tp", "sq", None)
        s = s + _mask_bias(qp_c, k_pos, causal=causal, window=window
                           )[:, None, :, :]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhcs,bshd->bchd", p.astype(v.dtype), v)
        return hint(o, "dp", "sq", "tp", None)

    if Sq <= q_chunk:
        out = chunk_attn(q, q_pos)
    else:
        n = Sq // q_chunk
        rem = Sq - n * q_chunk
        qs = q[:, : n * q_chunk].reshape(B, n, q_chunk, Hq, hd)
        ps = q_pos[:, : n * q_chunk].reshape(B, n, q_chunk)
        outs = jax.lax.map(lambda t: chunk_attn(t[0], t[1]),
                           (qs.transpose(1, 0, 2, 3, 4),
                            ps.transpose(1, 0, 2)))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n * q_chunk, Hq, -1)
        if rem:
            tail = chunk_attn(q[:, n * q_chunk:], q_pos[:, n * q_chunk:])
            out = jnp.concatenate([out, tail], axis=1)
    return out.reshape(B, Sq, Hq, v.shape[-1])  # v head dim (MLA: != q dim)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     q_pos: jax.Array, window: jax.Array | int = 0,
                     softmax_scale: float | None = None) -> jax.Array:
    """Single-position attention against a (possibly longer) KV cache.

    q: (B, 1, Hq, hd); caches: (B, S, Hkv, hd); q_pos: (B,) absolute position.
    Entries with k_pos > q_pos (unwritten cache slots) are masked out.
    """
    from repro.dist.hints import hint, tp_divides
    B, _, Hq, hd = q.shape
    S = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    k_pos = jnp.arange(S)[None, :]
    kv_tp = tp_divides(Hkv)   # can the STORED cache shard its kv heads?
    # Grouped form throughout — no kv expansion (a jnp.repeat here would
    # materialize 2 extra cache-sized buffers PER LAYER: 8 GB/layer on the
    # gemma long_500k cell).
    qg = q.reshape(B, Hkv, G, hd)
    if kv_tp:
        # heads-local attention: cache kv->model, sweep seq locally
        qg = hint(qg, "dp", "tp", None, None)
        k_cache = hint(k_cache, "dp", "sp", "tp", None)
        v_cache = hint(v_cache, "dp", "sp", "tp", None)
    else:
        # kv heads don't divide the model axis: the cache lives seq-sharded
        # over (model, dp) [dist.sharding._cache_spec] — keep the WHOLE sweep
        # in that layout (scores seq-sharded, psum the tiny (B,H,hd) output)
        # instead of re-gathering the cache (measured: 2×1.9 GiB all-gather
        # per layer per token on gemma3-12b long_500k).
        qg = hint(qg, "dp", None, None, None)
        k_cache = hint(k_cache, "dp", "seq", None, None)
        v_cache = hint(v_cache, "dp", "seq", None, None)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = hint(s, "dp", "tp", None, None) if kv_tp \
        else hint(s, "dp", None, None, "seq")
    w = jnp.asarray(window)
    ok = k_pos <= q_pos[:, None]
    ok &= (w <= 0) | (q_pos[:, None] - k_pos < w)
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, Hq, v_cache.shape[-1])


# --------------------------------------------------------------------- GQA block
@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    hd: int


def init_attn(key: jax.Array, dims: AttnDims, dtype, n_layers: int = 1) -> Pytree:
    ks = jax.random.split(key, 4)
    d, H, Hkv, hd = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.hd
    out_scale = 1.0 / np.sqrt(H * hd) / np.sqrt(2.0 * n_layers)
    return {
        "wq": init_linear(ks[0], d, H * hd, dtype),
        "wk": init_linear(ks[1], d, Hkv * hd, dtype),
        "wv": init_linear(ks[2], d, Hkv * hd, dtype),
        "wo": init_linear(ks[3], H * hd, d, dtype, scale=out_scale),
    }


def attn_qkv(p: Pytree, x: jax.Array, dims: AttnDims
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, dims.n_heads, dims.hd)
    k = (x @ p["wk"]).reshape(B, S, dims.n_kv_heads, dims.hd)
    v = (x @ p["wv"]).reshape(B, S, dims.n_kv_heads, dims.hd)
    return q, k, v


def self_attention_block(p: Pytree, x: jax.Array, dims: AttnDims, *,
                         positions: jax.Array, theta: float,
                         causal: bool = True, window: int = 0) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = attn_qkv(p, x, dims)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    o = attention(q, k, v, q_pos=positions, k_pos=positions,
                  causal=causal, window=window)
    return o.reshape(B, S, dims.n_heads * dims.hd) @ p["wo"]


def cross_attention_block(p: Pytree, x: jax.Array, kv_src: jax.Array,
                          dims: AttnDims) -> jax.Array:
    """Encoder-decoder / VLM cross attention (no rope, no mask)."""
    B, S, _ = x.shape
    Sk = kv_src.shape[1]
    q = (x @ p["wq"]).reshape(B, S, dims.n_heads, dims.hd)
    k = (kv_src @ p["wk"]).reshape(B, Sk, dims.n_kv_heads, dims.hd)
    v = (kv_src @ p["wv"]).reshape(B, Sk, dims.n_kv_heads, dims.hd)
    qp = jnp.zeros((B, S), jnp.int32)
    kp = jnp.zeros((B, Sk), jnp.int32)
    o = attention(q, k, v, q_pos=qp, k_pos=kp, causal=False)
    return o.reshape(B, S, dims.n_heads * dims.hd) @ p["wo"]


# ----------------------------------------------------------------------- MLP
def init_mlp(key: jax.Array, d: int, d_ff: int, dtype, n_layers: int = 1,
             gated: bool = True) -> Pytree:
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / np.sqrt(d_ff) / np.sqrt(2.0 * n_layers)
    p = {"w1": init_linear(ks[0], d, d_ff, dtype),
         "w2": init_linear(ks[1], d_ff, d, dtype, scale=out_scale)}
    if gated:
        p["w3"] = init_linear(ks[2], d, d_ff, dtype)
    return p


def mlp_block(p: Pytree, x: jax.Array) -> jax.Array:
    from repro.dist.hints import hint
    roles = (("dp",) + (None,) * (x.ndim - 2)) + ("tp",)
    if "w3" in p:
        h = hint(jax.nn.silu(x @ p["w1"]) * (x @ p["w3"]), *roles)
        return h @ p["w2"]
    h = hint(jax.nn.gelu(x @ p["w1"]), *roles)
    return h @ p["w2"]


# ------------------------------------------------------------------ embedding
def init_embed(key: jax.Array, cfg: ModelConfig, dtype) -> Pytree:
    k1, k2 = jax.random.split(key)
    p = {"tok": uniform_scale_init(k1, (cfg.vocab, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["head"] = init_linear(k2, cfg.d_model, cfg.vocab, dtype)
    return p


def embed_tokens(p: Pytree, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: Pytree, h: jax.Array) -> jax.Array:
    w = p["head"] if "head" in p else p["tok"].T
    return h @ w


# -------------------------------------------------------------------- losses
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 ignore_id: int = -1) -> jax.Array:
    """Mean next-token cross entropy in f32; ``labels`` already shifted."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------- kv caches
def init_kv_cache(batch: int, max_seq: int, n_kv: int, hd: int, n_layers: int,
                  dtype) -> Pytree:
    shape = (n_layers, batch, max_seq, n_kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_update(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array,
                 v: jax.Array, pos: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Write one step (B, 1, Hkv, hd) at per-batch position ``pos`` (B,)."""
    B = k.shape[0]
    bidx = jnp.arange(B)
    ck = cache_k.at[bidx, pos].set(k[:, 0])
    cv = cache_v.at[bidx, pos].set(v[:, 0])
    return ck, cv
