"""Mamba2 (SSD) block — the zamba2-7b backbone.

Chunked SSD algorithm (Dao & Gu, 2024) adapted for TPU: the sequence is
processed in chunks of ``CHUNK``; within a chunk the recurrence is computed as
a masked quadratic form (MXU-friendly einsums — this is the TPU-native
formulation, replacing the CUDA selective-scan kernel), and a small carried
state (B, H, P, N) links chunks through an ordinary ``lax.scan``. The decay
matrix is built as ``exp(l_t - l_s)`` with ``l`` a within-chunk cumulative
log-decay — differences are ≤ 0, so no overflow.

Decode is the O(1) recurrent step on (conv window, SSM state).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import init_linear, rms_norm

Pytree = Any
CHUNK = 128


def ssm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim P, state N)."""
    d_in = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    assert d_in % P == 0
    return d_in, d_in // P, P, cfg.ssm_state


def init_mamba2(key: jax.Array, cfg: ModelConfig, dtype,
                n_layers: int = 1) -> Pytree:
    d = cfg.d_model
    d_in, H, P, N = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": init_linear(ks[0], d, 2 * d_in + 2 * N + H, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch),
                                           jnp.float32)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": init_linear(ks[2], d_in, d, dtype,
                                scale=1.0 / np.sqrt(d_in) / np.sqrt(2.0 * n_layers)),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in, H, P, N = ssm_dims(cfg)
    z, xc, Bc, Cc, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xc, Bc, Cc, dt


def _conv1d(w: jax.Array, b: jax.Array, x: jax.Array,
            state: jax.Array | None = None):
    """Depthwise causal conv, width K. x: (B, S, C). state: (B, K-1, C)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out, new_state


def mamba2_block(cfg: ModelConfig, p: Pytree, x: jax.Array,
                 return_state: bool = False):
    """Full-sequence (train/prefill) Mamba2 mixer. x: (B, S, d) -> (B, S, d).

    With ``return_state`` also returns the exact decode state {conv, ssm}
    after the last token (padding is state-neutral: padded ``loga``/``dt`` are
    zero => decay 1, no input contribution).
    """
    B, S, _ = x.shape
    d_in, H, P, N = ssm_dims(cfg)
    z, xc, Bc, Cc, dt = _split_proj(cfg, x @ p["in_proj"])
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    Kc = cfg.ssm_conv - 1
    if S >= Kc:
        conv_tail = conv_in[:, -Kc:, :].astype(jnp.float32)
    else:  # tiny smoke-test sequences
        conv_tail = jnp.pad(conv_in.astype(jnp.float32),
                            ((0, 0), (Kc - S, 0), (0, 0)))
    conv_out, _ = _conv1d(p["conv_w"], p["conv_b"], conv_in)
    conv_out = jax.nn.silu(conv_out)
    xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    A = -jnp.exp(p["A_log"])                                         # (H,)
    loga = (A * dt)                                                  # (B,S,H) <= 0

    # pad to a chunk multiple
    Q = min(CHUNK, S)
    pad = (-S) % Q
    if pad:
        def padn(a):
            return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xc, Bc, Cc, dt, loga = map(padn, (xc, Bc, Cc, dt, loga))
    Sp = S + pad
    nc = Sp // Q

    xh = xc.reshape(B, nc, Q, H, P)
    Bg = Bc.reshape(B, nc, Q, N)
    Cg = Cc.reshape(B, nc, Q, N)
    dtg = dt.reshape(B, nc, Q, H)
    lg = loga.reshape(B, nc, Q, H)

    def chunk_step(h, inp):
        xq, bq, cq, dq, lq = inp                     # (B,Q,...) one chunk
        l = jnp.cumsum(lq, axis=1)                   # (B,Q,H) inclusive
        # decay matrix exp(l_t - l_s), s<=t  (differences <= 0). Mask BEFORE
        # the exp: the s>t half has POSITIVE diffs that overflow to inf, and
        # where(mask, inf, 0) backprops 0*inf = NaN.
        Ldiff = l[:, :, None, :] - l[:, None, :, :]  # (B,Q,Q,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.exp(jnp.where(mask[None, :, :, None], Ldiff, -1e30))
        cb = jnp.einsum("btn,bsn->bts", cq, bq,
                        preferred_element_type=jnp.float32)  # (B,Q,Q)
        # intra-chunk
        y = jnp.einsum("bts,bhts,bsh,bshp->bthp",
                       cb, L.transpose(0, 3, 1, 2), dq,
                       xq.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("btn,bth,bhpn->bthp", cq, jnp.exp(l), h)
        # state update
        decay_to_end = jnp.exp(l[:, -1:, :] - l)     # (B,Q,H)
        dx = xq.astype(jnp.float32) * (dq * decay_to_end)[..., None]
        h_new = (jnp.exp(l[:, -1])[:, :, None, None] * h
                 + jnp.einsum("bshp,bsn->bhpn", dx, bq))
        return h_new, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk_step, h0,
                             (xh.transpose(1, 0, 2, 3, 4),
                              Bg.transpose(1, 0, 2, 3),
                              Cg.transpose(1, 0, 2, 3),
                              dtg.transpose(1, 0, 2, 3),
                              lg.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, P)[:, :S]
    y = y + p["D"][None, None, :, None] * xc.reshape(B, Sp, H, P)[:, :S]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"conv": conv_tail, "ssm": h_fin}
    return out


def mamba2_init_state(cfg: ModelConfig, batch: int) -> Pytree:
    d_in, H, P, N = ssm_dims(cfg)
    conv_ch = d_in + 2 * N
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
            "ssm": jnp.zeros((batch, H, P, N), jnp.float32)}


def mamba2_step(cfg: ModelConfig, p: Pytree, state: Pytree, x: jax.Array
                ) -> tuple[jax.Array, Pytree]:
    """One-token decode. x: (B, 1, d)."""
    B = x.shape[0]
    d_in, H, P, N = ssm_dims(cfg)
    z, xc, Bc, Cc, dt = _split_proj(cfg, x @ p["in_proj"])
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)      # (B,1,C)
    conv_out, conv_state = _conv1d(p["conv_w"], p["conv_b"], conv_in,
                                   state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                             # (B,H)
    xh = xc[:, 0].reshape(B, H, P).astype(jnp.float32)
    h = (state["ssm"] * a[:, :, None, None]
         + jnp.einsum("bhp,bn,bh->bhpn", xh, Bc[:, 0].astype(jnp.float32), dt))
    y = jnp.einsum("bhpn,bn->bhp", h, Cc[:, 0].astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"conv": conv_state.astype(jnp.float32),
                               "ssm": h}
