"""Mixture-of-Experts layer — capacity-based token-choice dispatch.

Covers both assigned MoE architectures:
  * deepseek-v3-671b: 1 shared expert + 256 routed, top-8, sigmoid-ish router
    (we use softmax + renormalized top-k weights), first 3 layers dense.
  * arctic-480b: 128 routed top-2 + a *dense residual* FFN in parallel.

Dispatch is the GShard/Switch capacity scheme — top-k per token, position
within expert via per-slot cumsum, scatter to (E, C, d), expert einsum, gather
back. This is dense-shape, compiles under pjit, and shards cleanly with
experts on the "model" axis (EP) and tokens on "data" — the all-to-all shows
up explicitly in the dry-run collective accounting.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import init_linear

Pytree = Any


def init_moe(key: jax.Array, cfg: ModelConfig, dtype, n_layers: int = 1) -> Pytree:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    out_scale = 1.0 / np.sqrt(ff) / np.sqrt(2.0 * n_layers)
    p = {
        "router": init_linear(ks[0], d, E, jnp.float32),  # router in f32
        "w1": (0.02 * jax.random.normal(ks[1], (E, d, ff), jnp.float32)
               ).astype(dtype),
        "w3": (0.02 * jax.random.normal(ks[2], (E, d, ff), jnp.float32)
               ).astype(dtype),
        "w2": (out_scale * jax.random.normal(ks[3], (E, ff, d), jnp.float32)
               ).astype(dtype),
    }
    if cfg.n_shared_experts:
        ff_s = ff * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": init_linear(kk[0], d, ff_s, dtype),
            "w3": init_linear(kk[1], d, ff_s, dtype),
            "w2": init_linear(kk[2], ff_s, d, dtype, scale=out_scale),
        }
    return p


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(np.ceil(cfg.capacity_factor * n_tokens * cfg.experts_per_token
                    / cfg.n_experts))
    return max(8, int(np.ceil(c / 8)) * 8)


def moe_ffn(cfg: ModelConfig, p: Pytree, x: jax.Array
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), router aux loss scalar f32).

    Under an active mesh (dist.hints.sharding_rules) the routed experts run
    through the shard_map path (local dispatch + psum combine — see
    :func:`_routed_shard_map`); the global-shape path below is the reference
    used on unmeshed CPU runs and as the numerical oracle in tests.
    """
    from repro.dist import hints as hint_rules
    r = hint_rules.get_rules()
    if r is not None and r.get("mesh") is not None:
        return _moe_ffn_sharded(cfg, p, x, r)
    return _moe_ffn_global(cfg, p, x)


def _moe_ffn_global(cfg: ModelConfig, p: Pytree, x: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    C = _capacity(cfg, T)
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # (T, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                       # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, slot) inside its expert, via a single stable
    # sort over the T*k flat assignments. (The obvious per-slot one-hot
    # cumsum materializes (T, E) int32 per slot — measured at ~1 TB of
    # transient traffic per MoE layer on the deepseek train_4k dry-run cell;
    # the sort keeps everything O(T*k). See EXPERIMENTS §Perf.)
    e_flat = topi.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(e_flat, stable=True)
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - seg_start[
        e_flat[order]]
    pos_flat = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)
    keep_f = pos_flat < C                                      # capacity drop

    # scatter tokens -> (E*C, d)
    flat_idx = e_flat * C + pos_flat                           # (T*k,)
    from repro.dist.hints import hint
    src = jnp.repeat(xt, k, axis=0) * keep_f[:, None].astype(x.dtype)
    disp = jnp.zeros((E * C, d), x.dtype).at[
        jnp.where(keep_f, flat_idx, E * C - 1)].add(
            jnp.where(keep_f[:, None], src, 0))
    disp = hint(disp.reshape(E, C, d), "tp", "dp", None)       # EP + capacity on dp

    # expert FFN (einsum over experts)
    h = hint(jnp.einsum("ecd,edf->ecf", disp, p["w1"]), "tp", "dp", None)
    g = hint(jnp.einsum("ecd,edf->ecf", disp, p["w3"]), "tp", "dp", None)
    y = hint(jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, p["w2"]),
             "tp", "dp", None)

    # gather back with routing weights; pin the gather result to the token
    # layout up front — without it SPMD "involuntarily fully rematerializes"
    # (replicates) the combine gather between the (E,C) and token shardings.
    picked = hint(y.reshape(E * C, d)[flat_idx], "dp", None)   # (T*k, d)
    w = (topw.reshape(-1) * keep_f).astype(x.dtype)
    out = (picked * w[:, None]).reshape(T, k, d).sum(axis=1)
    out = hint(out, "dp", None)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    frac = counts.astype(jnp.float32) / jnp.maximum(T * k, 1)
    mean_prob = probs.mean(0)
    aux = E * jnp.sum(frac * mean_prob) * cfg.router_aux_weight

    if "shared" in p:
        sp = p["shared"]
        out = out + ((jax.nn.silu(xt @ sp["w1"]) * (xt @ sp["w3"])) @ sp["w2"])
    return out.reshape(B, S, d), aux


# ------------------------------------------------------------- shard_map path
def _moe_ffn_sharded(cfg: ModelConfig, p: Pytree, x: jax.Array, rules: dict
                     ) -> tuple[jax.Array, jax.Array]:
    """Routed experts via shard_map — the TPU-native dispatch.

    Key observations (measured on the deepseek-v3 train_4k dry-run cell; see
    EXPERIMENTS §Perf):
      * under pjit auto-sharding, the global-capacity scatter dispatch lowers
        to full-buffer all-reduces (2+ GiB × layers × microbatches) plus
        "involuntary full rematerialization" gathers;
      * activations are replicated across the model axis anyway, so each
        (data, model) device can dispatch its LOCAL tokens to its LOCAL
        experts with a per-shard capacity — no dispatch communication at all;
      * the only cross-device traffic left is (a) the FSDP weight all_gather
        (whose AD transpose is automatically a reduce-scatter of the expert
        grads — the thing the SPMD partitioner refused to emit) and (b) one
        psum of the (T_local, d) combined output over the model axis.
    Capacity semantics shift from global to per-(data-shard, expert) — the
    standard per-device capacity used by production MoE systems.
    """
    from jax.sharding import PartitionSpec as P

    mesh = rules["mesh"]
    dp_axes = rules["dp"] or ()
    tp = rules["tp"]
    E, k = cfg.n_experts, cfg.experts_per_token
    B, S, d = x.shape
    tp_size = rules["tp_size"] if tp else 1
    dp_size = rules["dp_size"]
    if E % tp_size != 0 or (B * S) % max(dp_size, 1) != 0:
        return _moe_ffn_global(cfg, p, x)

    T_loc = B * S // max(dp_size, 1)
    C_loc = _capacity(cfg, T_loc)
    E_loc = E // tp_size

    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    in_specs = (P(dp_spec, None, None),          # x: tokens over dp
                P(None, None),                   # router (replicated inside)
                P(tp, None, "data"),             # w1 (E, d, ff)
                P(tp, None, "data"),             # w3
                P(tp, "data", None))             # w2 (E, ff, d)
    out_specs = (P(dp_spec, None, None), P())

    def local_fn(x_loc, router, w1, w3, w2):
        Bl, Sl, _ = x_loc.shape
        xt = x_loc.reshape(Bl * Sl, d)
        Tl = Bl * Sl

        logits = xt.astype(jnp.float32) @ router           # (Tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

        e_flat = topi.reshape(-1)                          # (Tl*k,)
        order = jnp.argsort(e_flat, stable=True)
        counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
        seg_start = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
        pos_flat = jnp.zeros((Tl * k,), jnp.int32).at[order].set(
            jnp.arange(Tl * k, dtype=jnp.int32) - seg_start[e_flat[order]])

        # this model rank dispatches only its expert range
        rank = jax.lax.axis_index(tp) if tp else 0
        lo = rank * E_loc
        keep = (e_flat >= lo) & (e_flat < lo + E_loc) & (pos_flat < C_loc)
        slot = jnp.where(keep, (e_flat - lo) * C_loc + pos_flat,
                         E_loc * C_loc)                    # overflow slot
        src = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
        disp = jnp.zeros((E_loc * C_loc + 1, d), xt.dtype
                         ).at[slot].add(src)[:-1].reshape(E_loc, C_loc, d)

        # FSDP gather of the local experts' weights (AD: reduce-scatter grads)
        if dp_axes:
            w1f = jax.lax.all_gather(w1, "data", axis=2, tiled=True)
            w3f = jax.lax.all_gather(w3, "data", axis=2, tiled=True)
            w2f = jax.lax.all_gather(w2, "data", axis=1, tiled=True)
        else:
            w1f, w3f, w2f = w1, w3, w2
        h = jnp.einsum("ecd,edf->ecf", disp, w1f)
        g = jnp.einsum("ecd,edf->ecf", disp, w3f)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, w2f)

        yf = jnp.concatenate([y.reshape(E_loc * C_loc, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
        picked = yf[slot]                                  # (Tl*k, d)
        w = (topw.reshape(-1) * keep).astype(xt.dtype)
        part = (picked * w[:, None]).reshape(Tl, k, d).sum(axis=1)
        out = jax.lax.psum(part, tp) if tp else part       # combine experts

        frac = counts.astype(jnp.float32) / jnp.maximum(Tl * k, 1)
        aux = E * jnp.sum(frac * probs.mean(0)) * cfg.router_aux_weight
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes if len(dp_axes) > 1
                                else dp_axes[0])
        return out.reshape(Bl, Sl, d), aux

    out, aux = jax.shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(
        x, p["router"].astype(jnp.float32), p["w1"], p["w3"], p["w2"])

    if "shared" in p:
        sp = p["shared"]
        xt = x.reshape(B * S, d)
        out = out + ((jax.nn.silu(xt @ sp["w1"]) * (xt @ sp["w3"]))
                     @ sp["w2"]).reshape(B, S, d)
    return out, aux
