"""repro — cross-layer scientific-workflow / large-model systems reproduction.

Layer map (see README.md):
  core     workflow DAG, scheduler, location-aware store, compiler hints
  dist     runtime sharding rules + hint resolution + compressed collectives
  models   the 10 architecture families (pure-functional jax)
  train    loop, optimizer, checkpoint, elastic restart
  serve    decode engine
  launch   meshes, input specs, dry-run lowering of every (arch×shape) cell
"""

from repro import _compat

_compat.install()
