"""The training loop: data prefetch + jitted step + async checkpoint +
elastic restart. This is the end-to-end driver examples/train_lm.py uses.

Fault-tolerance contract:
  * checkpoint every ``ckpt_every`` steps, asynchronously (one in flight);
  * ``simulate_failure_at`` kills the in-memory state at that step — the loop
    then restores from the latest checkpoint (possibly onto a different mesh:
    elastic restart) and continues; steps since the last checkpoint re-run;
  * the data pipeline is deterministic-by-step, so restarts replay the exact
    batches (no data loss / duplication beyond the rolled-back steps).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import PrefetchingLoader, SyntheticCorpus
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

Pytree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 50
    batch: int = 8
    seq: int = 64
    ckpt_every: int = 20
    ckpt_dir: str | None = None
    prefetch_depth: int = 2
    log_every: int = 10
    simulate_failure_at: int | None = None
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    losses: list[float]
    steps_done: int
    restarts: int
    wall_seconds: float
    data_waits: int


def extras_fn(cfg: ModelConfig, batch_np: dict, rng: np.random.Generator
              ) -> dict:
    """Attach stub modality inputs (frames/patches) where the family needs."""
    out = dict(batch_np)
    B = batch_np["tokens"].shape[0]
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (B, cfg.n_frames, cfg.d_model), np.float32).astype(np.float32)
    if cfg.family == "vlm":
        out["patches"] = rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model), np.float32).astype(np.float32)
    return out


def train(cfg: ModelConfig, tc: TrainConfig,
          opt_cfg: OptConfig | None = None,
          on_step: Callable[[int, dict], None] | None = None) -> TrainResult:
    opt_cfg = opt_cfg or OptConfig(warmup_steps=10, total_steps=tc.steps)
    cfg.validate()
    rng = np.random.default_rng(tc.seed)

    params = M.init_params(cfg, jax.random.PRNGKey(tc.seed))
    opt_state = init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    corpus = SyntheticCorpus(cfg.vocab, seed=tc.seed)
    checkpointer = (ckpt.AsyncCheckpointer(tc.ckpt_dir)
                    if tc.ckpt_dir else None)

    losses: list[float] = []
    restarts = 0
    failed_once = False
    step = 0
    data_waits = 0
    t0 = time.perf_counter()

    def make_loader(start: int) -> PrefetchingLoader:
        it = corpus.batches(tc.batch, tc.seq, start_step=start)
        return PrefetchingLoader(
            (extras_fn(cfg, b, np.random.default_rng((tc.seed, i + start)))
             for i, b in enumerate(it)),
            depth=tc.prefetch_depth)

    loader = make_loader(0)
    try:
        while step < tc.steps:
            if (tc.simulate_failure_at is not None and not failed_once
                    and step == tc.simulate_failure_at):
                # ---- simulated node failure: lose in-memory state ---------
                failed_once = True
                del params, opt_state
                if checkpointer:
                    checkpointer.wait()
                restore_step = ckpt.latest_step(tc.ckpt_dir)
                if restore_step is None:
                    # failed before the first checkpoint: cold restart —
                    # deterministic init + data pipeline replay from step 0
                    params = M.init_params(cfg, jax.random.PRNGKey(tc.seed))
                    opt_state = init_opt_state(opt_cfg, params)
                    restore_step = 0
                else:
                    tgt_p = jax.eval_shape(
                        lambda: M.init_params(cfg,
                                              jax.random.PRNGKey(tc.seed)))
                    tgt_o = jax.eval_shape(
                        lambda: init_opt_state(opt_cfg, tgt_p))
                    state = ckpt.restore(tc.ckpt_dir, restore_step,
                                         target={"p": tgt_p, "o": tgt_o})
                    params, opt_state = state["p"], state["o"]
                step = restore_step
                restarts += 1
                loader.close()
                loader = make_loader(step)
                continue

            batch = next(loader)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            step += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {step}")
            if on_step:
                on_step(step, metrics)
            if checkpointer and step % tc.ckpt_every == 0:
                checkpointer.save_async({"p": params, "o": opt_state}, step)
        if checkpointer:
            checkpointer.wait()
    finally:
        data_waits = loader.waits
        loader.close()

    return TrainResult(losses=losses, steps_done=step, restarts=restarts,
                       wall_seconds=time.perf_counter() - t0,
                       data_waits=data_waits)
