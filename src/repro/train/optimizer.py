"""AdamW with ZeRO-sharded state (moments inherit the FSDP param sharding).

Pure-pytree implementation (no optax dependency in the image). Features the
scale needs: global-norm clipping, decoupled weight decay with norm/bias
exemption, linear-warmup + cosine schedule, and configurable moment dtype —
``bf16`` moments for the ≥100B configs (671B × (2+2+2)B = 4 TB ⇒ 8 GB/chip on
the 512-chip mesh; f32 moments would blow the v5e HBM budget, see
EXPERIMENTS §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"     # "bfloat16" for the giant configs


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(cfg: OptConfig, params: Pytree) -> Pytree:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(params: Pytree) -> Pytree:
    """No weight decay on vectors/scalars (norm gains, biases)."""
    return jax.tree.map(lambda p: float(p.ndim >= 2), params)


def adamw_update(cfg: OptConfig, grads: Pytree, opt_state: Pytree,
                 params: Pytree) -> tuple[Pytree, Pytree, dict]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mask = _decay_mask(params)

    def upd(g, m, v, p, wd_on):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * wd_on * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params,
                       mask)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
