"""Fault-tolerant checkpointing: atomic, async, resharding-on-restore.

Layout (one directory per step)::

    <dir>/step_000010/
        manifest.json        # pytree structure, shapes, dtypes, file map
        arrays.npz           # leaf data (this process's shards)
    <dir>/LATEST             # atomically-updated pointer

Properties the 1000-node deployment needs, implemented here at process scale:

* **atomic**: writes go to ``step_N.tmp`` then ``os.rename`` — a crash leaves
  either the old or the new checkpoint, never a torn one;
* **async**: ``save_async`` snapshots to host RAM (jax.device_get) and writes
  on a background thread — the train loop stalls only for the device->host
  copy (the paper's pipelining argument applied to checkpoint I/O);
* **location-aware**: when given a :class:`~repro.core.locstore.LocStore`,
  each checkpoint registers placement metadata (which node wrote it) so the
  restore path can read the nearest replica — the paper's location service
  applied to checkpoints;
* **elastic restore**: ``restore`` takes an optional target pytree of
  ShapeDtypeStructs + shardings and ``jax.device_put``s each leaf, so a
  checkpoint written on one mesh restores onto another (see train/elastic.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np

from repro.core.locstore import LocStore

Pytree = Any

_SEP = "/"


def _flatten(tree: Pytree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = leaf
    return flat


def _tree_def(tree: Pytree):
    return jax.tree_util.tree_structure(tree)


def save(tree: Pytree, directory: str, step: int, *,
         store: LocStore | None = None, node: int = 0) -> str:
    """Synchronous atomic save; returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    flat = _flatten(host)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in flat.items()},
    }
    # numpy can't serialize ml_dtypes (bfloat16 etc.) natively: store a
    # same-width uint view; the manifest dtype restores the real type.
    _UINT = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}

    def storable(v: np.ndarray) -> np.ndarray:
        if v.dtype.kind in "fiub" and v.dtype.str.lstrip("<>|=") in (
                "f2", "f4", "f8", "i1", "i2", "i4", "i8", "u1", "u2", "u4",
                "u8", "b1"):
            return v
        return v.view(_UINT[v.dtype.itemsize])

    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k.replace(_SEP, "__"): storable(v) for k, v in flat.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    ptr = os.path.join(directory, "LATEST.tmp")
    with open(ptr, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr, os.path.join(directory, "LATEST"))
    if store is not None:
        size = sum(v.nbytes for v in flat.values())
        name = f"ckpt:{os.path.basename(directory)}:{step}"
        if store.exists(name):
            store.delete(name)
        store.put(name, memoryview(b""), loc=node,
                  xattr={"path": final, "size": size, "step": step})
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread, write on a background thread.

    ``wait()`` joins the in-flight write (call before shutdown / next save to
    bound staleness to one checkpoint)."""

    def __init__(self, directory: str, *, store: LocStore | None = None,
                 node: int = 0) -> None:
        self.directory = directory
        self.store = store
        self.node = node
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None
        self._error: BaseException | None = None

    def save_async(self, tree: Pytree, step: int) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                self.last_path = save(host, self.directory, step,
                                      store=self.store, node=self.node)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True,
                                        name="xflow-ckpt")
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(directory: str) -> int | None:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    return int(name.split("_")[-1])


def restore(directory: str, step: int | None = None, *,
            target: Pytree | None = None,
            sharding_fn: Callable[[str, Any], Any] | None = None) -> Pytree:
    """Load a checkpoint; with ``target`` (pytree of ShapeDtypeStruct or
    arrays) the result is device_put to the target's shardings — this is the
    elastic-restart resharding path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    import ml_dtypes  # jax dependency, always present

    def restore_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
        if str(arr.dtype) == dtype_str:
            return arr
        try:
            return arr.view(np.dtype(dtype_str))
        except TypeError:
            return arr.view(getattr(ml_dtypes, dtype_str))

    flat = {k: restore_dtype(data[k.replace(_SEP, "__")],
                             manifest["keys"][k]["dtype"])
            for k in manifest["keys"]}

    if target is None:
        # rebuild a nested dict (callers using raw mode handle structure)
        out: dict[str, Any] = {}
        for k, v in flat.items():
            cur = out
            parts = k.split(_SEP)
            for p in parts[:-1]:
                cur = cur.setdefault(p, {})
            cur[parts[-1]] = v
        return out

    t_flat = _flatten(target)
    assert set(t_flat) == set(flat), (
        f"checkpoint/target mismatch: {set(t_flat) ^ set(flat)}")
    restored = {}
    for k, tgt in t_flat.items():
        arr = flat[k]
        if str(arr.dtype) != str(tgt.dtype):
            arr = arr.astype(tgt.dtype)
        sh = getattr(tgt, "sharding", None)
        if sharding_fn is not None:
            sh = sharding_fn(k, tgt)
        restored[k] = jax.device_put(arr, sh) if sh is not None \
            else jax.numpy.asarray(arr)
    leaves_order = [restored[k] for k in _flatten(target)]
    return jax.tree_util.tree_unflatten(_tree_def(target), leaves_order)
