"""Elastic scaling: restore a checkpoint onto a DIFFERENT mesh.

The scenario at 1000+ nodes: a pod loses hosts mid-run; the job restarts on
the surviving N-k hosts with a reshaped mesh. Nothing about the checkpoint
format depends on the writing mesh (leaves are saved whole per key), so
elasticity is purely a restore-time policy:

    new_mesh  = make_mesh((new_dp, new_tp), ("data", "model"))
    params    = elastic_restore(cfg, opt_cfg, ckpt_dir, new_mesh)

Each leaf is device_put against the sharding rules evaluated on the NEW mesh
(divisibility-aware: rules degrade to replication for axes that no longer
divide). The data pipeline is deterministic-by-step, so training resumes at
the checkpoint step with the exact next batch.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state

Pytree = Any


def shard_targets(cfg: ModelConfig, opt_cfg: OptConfig, mesh: Mesh
                  ) -> dict[str, Pytree]:
    """ShapeDtypeStructs with NEW-mesh shardings for {params, opt_state}."""
    p_shapes = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    o_shapes = jax.eval_shape(lambda: init_opt_state(opt_cfg, p_shapes))
    p_spec = shd.param_specs(cfg, p_shapes, mesh)
    o_spec = {"m": p_spec, "v": p_spec,
              "step": jax.sharding.PartitionSpec()}

    def attach(shapes, specs):
        return jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            shapes, specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    return {"p": attach(p_shapes, p_spec), "o": attach(o_shapes, o_spec)}


def elastic_restore(cfg: ModelConfig, opt_cfg: OptConfig, ckpt_dir: str,
                    mesh: Mesh, step: int | None = None
                    ) -> tuple[Pytree, Pytree, int]:
    """(params, opt_state, step) resharded onto ``mesh``."""
    step = step if step is not None else (ckpt.latest_step(ckpt_dir) or 0)
    tgt = shard_targets(cfg, opt_cfg, mesh)
    with mesh:
        state = ckpt.restore(ckpt_dir, step, target=tgt)
    return state["p"], state["o"], step
