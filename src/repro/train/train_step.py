"""The jitted train/serve steps, with sharding attached.

``make_train_step(cfg, opt_cfg)`` returns ``step(params, opt_state, batch)``
suitable for ``jax.jit(..., donate_argnums=(0, 1))`` under a mesh; shardings
come from :mod:`repro.dist.sharding`. The same function is what the dry-run
lowers for every (arch × train shape) cell, so there is exactly one train-step
definition in the framework.
"""

from __future__ import annotations

from typing import Any

import jax

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train.optimizer import OptConfig, adamw_update

Pytree = Any


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, *,
                    microbatches: int = 1, accum_dtype=None,
                    grad_specs: Pytree | None = None):
    """Jitted train step; ``microbatches > 1`` scans the global batch in
    micro-slices, accumulating gradients (gradient accumulation) — the
    memory-term lever for the ≥100B dry-run cells (activations scale with
    tokens-per-pass, not tokens-per-step). ``accum_dtype`` defaults to f32;
    the giant configs pass bf16 (a f32 grad accumulator alone would be 2.7 TB
    for deepseek-v3).

    ``grad_specs`` (a PartitionSpec tree matching params) constrains each
    microbatch's gradients to the accumulator's sharding BEFORE the add —
    without it XLA all-reduces the full gradient then slices (measured 948 GiB
    × L × mb of f32 all-reduce on arctic train_4k); with it the batch-axis
    reduction lowers to a reduce-scatter at 1/tp the bytes."""

    def grads_of(params: Pytree, batch: Pytree):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
        if grad_specs is not None:
            grads = jax.tree.map(
                lambda g, sp: jax.lax.with_sharding_constraint(g, sp),
                grads, grad_specs)
        return (loss, metrics), grads

    def train_step(params: Pytree, opt_state: Pytree, batch: Pytree):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
            loss = metrics["loss"]
        else:
            mb = microbatches
            resh = jax.tree.map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]),
                batch)
            acc_dt = accum_dtype or jax.numpy.float32

            def body(acc, micro):
                (loss_i, metrics_i), g = grads_of(params, micro)
                acc_g = jax.tree.map(
                    lambda a, b: a + (b / mb).astype(a.dtype), acc[0], g)
                return (acc_g, acc[1] + loss_i / mb), metrics_i

            zeros = jax.tree.map(
                lambda p: jax.numpy.zeros(p.shape, acc_dt), params)
            (grads, loss), metrics_all = jax.lax.scan(
                body, (zeros, jax.numpy.zeros((), jax.numpy.float32)), resh)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params: Pytree, state: Pytree, tokens):
        return M.decode_step(cfg, params, state, tokens)

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params: Pytree, batch: Pytree):
        return M.prefill(cfg, params, batch, max_seq)

    return prefill_step
