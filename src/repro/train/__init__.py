"""Subpackage."""
