"""repro.dist — the runtime layer of the cross-layer design.

The paper's compiler layer (:mod:`repro.core.hints` / ``wfcompiler``) decides
*what* should move; this package is the runtime that binds those decisions to
device placement:

  sharding     divisibility-aware PartitionSpec rules for params / batches /
               decode caches on the production meshes
  hints        ``sharding_rules(mesh)`` context + ``hint(x, *roles)`` — the
               lazy in-model annotation hook every layer calls
  compression  int8 error-feedback gradient compression for DP collectives
"""

from repro.dist import compression, hints, sharding

__all__ = ["compression", "hints", "sharding"]
