"""int8 error-feedback gradient compression for data-parallel collectives.

The cross-layer data-movement lever for the DP axes: gradients are quantized
to int8 (4x fewer bytes on the wire than f32) before the all-reduce, and the
per-device quantization residual is fed back into the next step's gradient
(error feedback / EF-SGD, Seide et al. 2014; Karimireddy et al. 2019). EF
keeps the *accumulated* update unbiased, so SGD converges at the uncompressed
rate despite the lossy collective.

All functions are shard_map-friendly: :func:`compressed_psum` uses
``jax.lax.psum`` over a named mesh axis and works unchanged from 1 device to
a full pod.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


# ------------------------------------------------------------- quantization
def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale f32 scalar).

    Round-to-nearest onto the int8 grid, so the roundtrip error is bounded by
    scale/2 per element. A zero tensor gets scale 0 and q == 0."""
    xf = x.astype(jnp.float32)
    scale = (jnp.max(jnp.abs(xf)) / 127.0).astype(jnp.float32)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compression_ratio(tree: Pytree) -> float:
    """Wire-bytes ratio: original dtype bytes vs int8 payload + f32 scale."""
    leaves = jax.tree.leaves(tree)
    orig = sum(leaf.size * leaf.dtype.itemsize for leaf in leaves)
    comp = sum(leaf.size + 4 for leaf in leaves)     # int8 + per-tensor scale
    return orig / comp


# ------------------------------------------------------- compressed psum/EF
def compressed_psum(x: jax.Array, axis_name: str, err: jax.Array | None
                    ) -> tuple[jax.Array, jax.Array]:
    """Mean of ``x`` over ``axis_name`` through an int8-compressed collective,
    with error feedback. Must run inside shard_map (needs the named axis).
    The wire traffic really is int8 (an all_gather of the quantized payload
    plus per-device scales), not a dressed-up f32 psum — the HLO collective
    accounting in launch/hlo_analysis sees the compressed bytes.

    Returns ``(mean, new_err)`` where ``new_err`` is this device's residual to
    feed into the next call. Invariant (per device): the compensated value
    ``dequantized + new_err`` equals ``x + err`` exactly, which is what makes
    the accumulated means unbiased over steps.
    """
    if err is None:
        err = jnp.zeros(x.shape, jnp.float32)
    comp = x.astype(jnp.float32) + err          # error-compensated gradient
    q, scale = quantize_int8(comp)
    new_err = comp - dequantize_int8(q, scale)
    # The collective moves int8 + one f32 scale per device; dequantization
    # and the reduction happen device-locally on the gathered payload (same
    # summation order everywhere -> bitwise-identical means on all devices).
    q_all = jax.lax.all_gather(q, axis_name)
    s_all = jax.lax.all_gather(scale, axis_name)
    n = q_all.shape[0]
    deq_all = q_all.astype(jnp.float32) * s_all.reshape((n,) + (1,) * x.ndim)
    mean = deq_all.sum(axis=0) / n
    return mean.astype(x.dtype), new_err


def wrap_grads(grads: Pytree, axis_name: str, err: Pytree | None
               ) -> tuple[Pytree, Pytree]:
    """Per-leaf :func:`compressed_psum` over a gradient pytree.

    ``err`` is the error-feedback state from the previous step (None on step
    0 -> zeros). Returns ``(mean_grads, new_err)`` with ``new_err`` matching
    the structure of ``grads``."""
    struct = jax.tree.structure(grads)
    g_leaves = jax.tree.leaves(grads)
    e_leaves = jax.tree.leaves(err) if err is not None else [None] * len(g_leaves)
    pairs = [compressed_psum(g, axis_name, e)
             for g, e in zip(g_leaves, e_leaves)]
    means = jax.tree.unflatten(struct, [p[0] for p in pairs])
    errs = jax.tree.unflatten(struct, [p[1] for p in pairs])
    return means, errs
