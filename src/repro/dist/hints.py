"""Logical-axis sharding hints — the runtime half of the cross-layer contract.

Model code annotates tensors with *roles* ("dp", "tp", "seq", …) instead of
mesh axes; :func:`sharding_rules` binds roles to a concrete mesh for the
duration of a trace, and :func:`hint` resolves them into
``with_sharding_constraint`` calls. Outside a rules context every hint is a
strict no-op (identity — not even a constraint), so the same model functions
run unmodified on an unmeshed CPU.

Roles:
  "dp"                        batch-like dims -> all DP axes (pod, data)
  "tp"                        head/ff/vocab dims -> "model"
  "seq" / "sp" / "sq"         sequence dims -> whatever axes are still free
                              ("model" first — sequence parallelism kicks in
                              exactly when heads/ff can't use the TP axis)
  "sp_seq"                    Megatron-SP residual activations; inert unless
                              ``sharding_rules(mesh, seq_parallel=True)``

Resolution is divisibility-aware per dim and never reuses a mesh axis, so
hints degrade to replication instead of failing (tested: hint on a (3,7,5)
tensor under any mesh).
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd

_SEQ_ROLES = ("seq", "sp", "sq", "sp_seq")

_ACTIVE: dict[str, Any] | None = None


def _make_rules(mesh, seq_parallel: bool = False) -> dict[str, Any]:
    dp = shd.dp_axes(mesh)
    tp = shd.tp_axis(mesh)
    sizes = shd.mesh_axes(mesh)
    return {
        "mesh": mesh,
        "dp": dp,
        "tp": tp,
        "dp_size": int(np.prod([sizes[a] for a in dp])) if dp else 1,
        "tp_size": sizes.get("model", 1),
        "seq_parallel": seq_parallel,
    }


@contextlib.contextmanager
def sharding_rules(mesh, *, seq_parallel: bool = False):
    """Bind logical roles to ``mesh`` for the enclosed trace/execution.

    ``seq_parallel`` opts in to Megatron-style sequence parallelism: the
    "sp_seq" role on residual activations stays inert unless enabled (the
    structural sequence roles "seq"/"sp"/"sq" are always live)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = _make_rules(mesh, seq_parallel)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def get_rules() -> dict[str, Any] | None:
    """The active role->axis binding, or None outside a rules context."""
    return _ACTIVE


def tp_divides(dim: int) -> bool:
    """Can ``dim`` shard over the TP axis? Vacuously true without rules."""
    r = _ACTIVE
    if r is None or r["tp"] is None:
        return True
    return dim % r["tp_size"] == 0


def hint(x: jax.Array, *roles) -> jax.Array:
    """Constrain ``x``'s sharding by per-dim roles (one role per dim).

    Identity outside a :func:`sharding_rules` context. Under rules, primary
    roles ("dp", "tp") claim their axes first; sequence roles then sweep up
    any axes left unused — each axis at most once, each assignment only if it
    divides the dim.
    """
    r = _ACTIVE
    if r is None:
        return x
    assert len(roles) == x.ndim, \
        f"hint(): {len(roles)} roles for rank-{x.ndim} tensor {x.shape}"
    mesh = r["mesh"]
    used: set = set()
    entries: list = [None] * x.ndim
    for i, role in enumerate(roles):
        if role == "dp":
            entries[i] = shd._fit(mesh, x.shape[i], r["dp"], used)
        elif role == "tp" and r["tp"] is not None:
            entries[i] = shd._fit(mesh, x.shape[i], (r["tp"],), used)
    for i, role in enumerate(roles):
        if role == "sp_seq" and not r["seq_parallel"]:
            continue                       # Megatron-SP residuals are opt-in
        if role in _SEQ_ROLES:
            rest = ((r["tp"],) if r["tp"] else ()) + r["dp"]
            entries[i] = shd._fit(mesh, x.shape[i], rest, used)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
