"""Divisibility-aware sharding rules for the production meshes.

Axis semantics (launch/mesh.py): ``pod`` = cross-pod DP over DCN, ``data`` =
in-pod DP + FSDP, ``model`` = TP/EP over ICI. Every rule here goes through
:func:`_check`, which drops any mesh axis that does not divide its dim —
assignments degrade to replication instead of failing at XLA lowering. All
functions accept either a concrete ``Mesh`` or an ``AbstractMesh`` (axis sizes
without devices), so the 16×16 / 2×16×16 rules are testable on one CPU.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Pytree = Any

_DP_NAMES = ("pod", "data")


# ----------------------------------------------------------------- mesh intro
def mesh_axes(mesh) -> dict[str, int]:
    return dict(mesh.shape)


def axis_size(mesh, ax) -> int:
    """Size of one mesh axis or the product over a tuple of axes."""
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= axis_size(mesh, a)
        return n
    return mesh_axes(mesh)[ax]


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes in major-to-minor order (pod before data)."""
    names = tuple(mesh.axis_names)
    return tuple(a for a in _DP_NAMES if a in names)


def tp_axis(mesh) -> str | None:
    return "model" if "model" in tuple(mesh.axis_names) else None


# -------------------------------------------------------------- divisibility
def _fit(mesh, dim: int, candidates, used: set) -> str | tuple | None:
    """Greedily assign unused mesh axes to ``dim`` while the product divides.

    Returns a single axis name, a tuple of names, or None (replicate)."""
    if candidates is None:
        return None
    if not isinstance(candidates, (tuple, list)):
        candidates = (candidates,)
    sizes = mesh_axes(mesh)
    kept: list[str] = []
    prod = 1
    for a in candidates:
        if a is None or a in used or a not in sizes:
            continue                 # unknown axis: degrade, don't KeyError
        size = sizes[a]
        if size > 0 and dim % (prod * size) == 0:
            kept.append(a)
            prod *= size
            used.add(a)
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def _check(mesh, shape: Sequence[int], spec) -> P:
    """Validate a proposed spec against ``shape``: indivisible axes are
    dropped (replicated), and no mesh axis is used twice."""
    entries = tuple(spec)
    entries = entries + (None,) * (len(shape) - len(entries))
    used: set = set()
    return P(*[_fit(mesh, dim, ax, used) for dim, ax in zip(shape, entries)])


def named(mesh, specs: Pytree) -> Pytree:
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def _dp_entry(mesh):
    dp = dp_axes(mesh)
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def _leaf_names(path) -> list[str]:
    return [str(k.key) for k in path if isinstance(k, jax.tree_util.DictKey)]


# ------------------------------------------------------------- param specs
# (in, out) matrices: shard the contraction dim on "data" (FSDP — the AD
# transpose of the all-gather is a reduce-scatter of the grads) and the output
# dim on "model" (TP).  Row-parallel outputs (wo, w2) are the reverse.
_COL = ("data", "model")
_ROW = ("model", "data")

# Expert-stacked weights (E, d, ff) / (E, ff, d): experts on "model" (EP),
# FSDP on ff — d is the first einsum's contraction dim and must stay whole.
_EXPERT_RULES = {
    "w1": ("model", None, "data"),
    "w3": ("model", None, "data"),
    "w2": ("model", "data", None),
}

_MATRIX_RULES = {
    # embeddings: vocab on model (TP logits), d on data
    "tok": ("model", "data"),
    "head": ("data", "model"),
    # attention / projections
    "wq": _COL, "wk": _COL, "wv": _COL, "wg": _COL, "wr": _COL,
    "wo": _ROW,
    # MLA low-rank factors
    "wq_a": _COL, "wq_b": _COL, "wkv_a": _COL, "wkv_b": _COL,
    # MLPs
    "w1": _COL, "w3": _COL, "w2": _ROW,
    # mamba2
    "in_proj": _COL, "out_proj": _ROW, "conv_w": (None, "model"),
    # rwkv loras
    "tm_w1": _COL, "w_w1": _COL, "w_w2": _ROW, "tm_w2": (None, None, "data"),
    # deepseek MTP fuse projection
    "proj": _COL,
    # router stays replicated (tiny, f32, read by every token)
    "router": (None, None),
}


def _param_template(names: list[str], leaf) -> tuple:
    name = names[-1] if names else ""
    if name in _EXPERT_RULES and "moe" in names and "shared" not in names:
        return _EXPERT_RULES[name]
    if name == "wv" and "cm" in names:        # rwkv channel-mix output proj
        return _ROW
    if leaf.ndim >= 2 and name in _MATRIX_RULES:
        return _MATRIX_RULES[name]
    return ()                                  # vectors / norms: replicate


def param_specs(cfg: ModelConfig, shapes: Pytree, mesh) -> Pytree:
    """PartitionSpec tree matching a param (shape) tree.

    Templates are right-aligned: leading stacked-layer/group dims replicate.
    Every assignment is divisibility-checked against ``mesh``."""

    def spec_for(path, leaf):
        tpl = _param_template(_leaf_names(path), leaf)
        full = (None,) * (leaf.ndim - len(tpl)) + tuple(tpl)
        return _check(mesh, leaf.shape, full)

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


# ------------------------------------------------------------- batch specs
def batch_specs(cfg: ModelConfig, batch: Pytree, mesh) -> Pytree:
    """Inputs shard their leading (global-batch) dim over all DP axes; an
    indivisible batch (e.g. long_500k's batch=1) replicates."""

    def spec_for(path, leaf):
        tpl = (_dp_entry(mesh),) + (None,) * (leaf.ndim - 1)
        return _check(mesh, leaf.shape, tpl)

    return jax.tree_util.tree_map_with_path(spec_for, batch)


# ------------------------------------------------------- decode-state specs
def _cache_spec(mesh, shape, *, b_dim: int | None, s_dim: int | None,
                h_dim: int | None) -> P:
    """The decode-cache rule, in priority order:

      1. heads (or the channel dim standing in for them) take "model" if they
         divide it — heads-local attention, no cross-chip KV traffic;
      2. batch takes the DP axes;
      3. the sequence dim sweeps up whatever is left ("model" first — the
         kv<model GQA fallback — then unused DP axes when batch=1).
    """
    used: set = set()
    out: list = [None] * len(shape)
    tp = tp_axis(mesh)
    if h_dim is not None and tp is not None:
        out[h_dim] = _fit(mesh, shape[h_dim], (tp,), used)
    if b_dim is not None:
        out[b_dim] = _fit(mesh, shape[b_dim], dp_axes(mesh), used)
    if s_dim is not None:
        rest = ((tp,) if tp else ()) + dp_axes(mesh)
        out[s_dim] = _fit(mesh, shape[s_dim], rest, used)
    return P(*out)


def decode_state_specs(cfg: ModelConfig, state: Pytree, mesh) -> Pytree:
    """Specs for a decode-state pytree (any family's ``init_decode_state``)."""

    def spec_for(path, leaf):
        names = _leaf_names(path)
        name = names[-1] if names else ""
        nd = leaf.ndim
        if name == "pos" or nd == 1:
            return _check(mesh, leaf.shape, (_dp_entry(mesh),))
        if name in ("k", "v", "attn_k", "attn_v", "xk", "xv"):
            # (L, B, S, Hkv, hd) — vlm stacks an extra group dim in front
            return _cache_spec(mesh, leaf.shape, b_dim=nd - 4, s_dim=nd - 3,
                               h_dim=nd - 2)
        if name in ("moe_cache", "dense_cache"):
            if nd == 4:            # MLA latent cache (L, B, S, c)
                return _cache_spec(mesh, leaf.shape, b_dim=1, s_dim=2,
                                   h_dim=None)
            return _cache_spec(mesh, leaf.shape, b_dim=1, s_dim=2, h_dim=3)
        if name == "wkv":          # rwkv state (L, B, H, K, K): heads split
            return _cache_spec(mesh, leaf.shape, b_dim=1, s_dim=None, h_dim=2)
        if name == "ssm":          # mamba2 state (n, B, H, P, N)
            return _cache_spec(mesh, leaf.shape, b_dim=1, s_dim=None, h_dim=2)
        if name == "conv":         # conv window (n, B, w, ch): ch on model
            return _cache_spec(mesh, leaf.shape, b_dim=1, s_dim=None, h_dim=3)
        if name in ("tm_x", "cm_x"):   # rwkv shift state (L, B, d)
            return _cache_spec(mesh, leaf.shape, b_dim=1, s_dim=None, h_dim=2)
        if nd >= 2:                # unknown state: shard batch-ish dim only
            return _cache_spec(mesh, leaf.shape, b_dim=1, s_dim=None,
                               h_dim=None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, state)
