"""Jit'd public wrappers around the Pallas kernels.

``attention_op`` / ``decode_attention_op`` pick the implementation:
  * ``impl="pallas"``  — the TPU kernels (real hardware path),
  * ``impl="interpret"`` — same kernels, interpret mode (CPU validation),
  * ``impl="xla"``     — the pure-jnp reference (CPU container default; also
    what the dry-run lowers, since Pallas TPU kernels cannot compile for the
    host-CPU placeholder devices).

``window_slice`` is the decode-side optimization used by sliding-window archs:
instead of sweeping the whole cache and masking, slice the last ``window``
entries around the current position (aligned down to the block size) so the
kernel only streams live data — this converts the local-layer decode roofline
term from O(S) to O(window).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas

IMPLS = ("xla", "pallas", "interpret")


def attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                 q_offset: int = 0, softmax_scale: float | None = None,
                 impl: str = "xla"):
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset,
                                       softmax_scale=softmax_scale)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, softmax_scale=softmax_scale,
                         interpret=(impl == "interpret"))


def decode_attention_op(q, k_cache, v_cache, lengths, *, window: int = 0,
                        softmax_scale: float | None = None,
                        impl: str = "xla"):
    if impl == "xla":
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                        window=window,
                                        softmax_scale=softmax_scale)
    return _decode_pallas(q, k_cache, v_cache, lengths, window=window,
                          softmax_scale=softmax_scale,
                          interpret=(impl == "interpret"))


def window_slice(cache: jax.Array, lengths: jax.Array, window: int,
                 block: int = 512) -> tuple[jax.Array, jax.Array]:
    """Slice the last ``window`` (block-aligned) cache entries per batch row.

    cache: (B, S, H, hd); returns (sliced (B, W', H, hd), new lengths).
    W' = window rounded up to ``block`` + one extra block of slack so the
    slice start can be block-aligned (keeps DMA strides clean on TPU).
    """
    B, S, H, hd = cache.shape
    Wp = min(S, ((window + block - 1) // block + 1) * block)
    start = jnp.maximum(lengths - window, 0)
    start = (start // block) * block                     # align down
    start = jnp.clip(start, 0, S - Wp)                   # keep slice in bounds

    def take(c, s):
        return jax.lax.dynamic_slice(c, (s, 0, 0), (Wp, H, hd))

    sliced = jax.vmap(take)(cache, start)
    return sliced, lengths - start
