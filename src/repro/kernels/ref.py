"""Pure-jnp oracles for the Pallas kernels.

Self-contained (no imports from repro.models) so a kernel test failure is
attributable to the kernel alone. Math is the plain materialized-scores
formulation in f32 — the slowest, most obviously-correct spelling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset: int = 0,
                        softmax_scale: float | None = None) -> jax.Array:
    """q: (B, Sq, Hq, hd); k/v: (B, Sk, Hkv, hd); GQA via Hq % Hkv == 0.

    ``q_offset`` places query i at absolute position q_offset + i (for
    suffix/chunked prefill); keys are at absolute positions 0..Sk-1.
    """
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= qpos - kpos < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array, *, window: int = 0,
                         softmax_scale: float | None = None) -> jax.Array:
    """Single-token attention vs a cache.

    q: (B, Hq, hd); caches: (B, S, Hkv, hd); lengths: (B,) — number of valid
    cache entries (query sits at position lengths-1).
    """
    B, Hq, hd = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(S)[None, :]
    ok = kpos < lengths[:, None]
    if window > 0:
        ok &= (lengths[:, None] - 1 - kpos) < window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, v_cache.shape[-1]).astype(q.dtype)
