"""Single-token decode attention vs a long KV cache — Pallas TPU kernel.

The decode_32k / long_500k hot spot: one query row per (batch, head) against
S cache entries. Memory-bound by design (roofline: ~2·S·hd bytes of cache per
head at ~0 reuse), so the kernel's job is to stream k/v blocks through VMEM at
full HBM bandwidth while keeping the softmax state in registers/VMEM.

Grid = (B, Hq, S/BK) — the cache sweep is the sequential dim; online-softmax
state (m, l, acc) persists in VMEM scratch. Per-batch ``lengths`` masks unseen
cache slots; sliding-window archs pass ``window`` so dead blocks are skipped
with pl.when (compute-free predication — on real TPUs the bandwidth win comes
from shrinking the swept region; see ops.window_slice below).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BK = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, window: int, bk: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[pl.program_id(0)]                  # this batch's valid entries
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)[0]

    live = (ik * bk) < length
    if window > 0:
        live &= (ik * bk + bk - 1) >= (length - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, :].astype(jnp.float32)          # (hd,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, hd)
        s = (k @ q) * scale                             # (bk,)
        ok = k_pos < length
        if window > 0:
            ok &= (length - 1 - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[0, 0]
        m_cur = jnp.maximum(m_prev, s.max())
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.where(ok, jnp.exp(s - m_cur), 0.0)      # (bk,)
        l_scr[0, 0] = l_scr[0, 0] * alpha + p.sum()
        v = v_ref[0, :, 0, :].astype(jnp.float32)       # (bk, hd)
        acc_scr[0, :] = acc_scr[0, :] * alpha + p @ v
        m_scr[0, 0] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0, :] = (acc_scr[0, :]
                          / jnp.maximum(l_scr[0, 0], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softmax_scale", "block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, window: int = 0,
                     softmax_scale: float | None = None,
                     block_k: int = DEFAULT_BK,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hq, hd); caches: (B, S, Hkv, hd); lengths: (B,) int32.

    Returns (B, Hq, hd). The query sits at absolute position lengths-1.
    """
    B, Hq, hd = q.shape
    _, S, Hkv, _ = k_cache.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5

    bk = min(block_k, max(S, 8))
    s_pad = (-S) % bk
    hd_pad = (-hd) % 128
    if hd_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, hd_pad)))
    if s_pad or hd_pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_pad), (0, 0), (0, hd_pad)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_pad), (0, 0), (0, hd_pad)))
    Sp, hdp = S + s_pad, hd + hd_pad

    grid = (B, Hq, Sp // bk)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               bk=bk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # lengths, whole array
            pl.BlockSpec((1, 1, hdp), lambda b, h, ik: (b, h, 0)),
            pl.BlockSpec((1, bk, 1, hdp),
                         lambda b, h, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hdp),
                         lambda b, h, ik, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hdp), lambda b, h, ik: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, hdp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hdp), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_cache, v_cache)
    return out[:, :, :hd]
