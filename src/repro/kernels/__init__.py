"""Pallas TPU kernels for the attention hot spots + pure-jnp oracles.

flash_attention.py / decode_attention.py: pl.pallas_call + BlockSpec VMEM
tiling; ops.py: jit wrappers; ref.py: oracles. Validated in interpret mode on
CPU (TPU is the target, not the runtime).
"""

from repro.kernels.ops import attention_op, decode_attention_op, window_slice

__all__ = ["attention_op", "decode_attention_op", "window_slice"]
