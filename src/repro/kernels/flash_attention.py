"""Blockwise fused attention (flash) — Pallas TPU kernel.

TPU-native design (not a CUDA port):
  * grid = (B, Hq, Sq/BQ, Sk/BK); the LAST grid dim is sequential on TPU, so
    the online-softmax running state (m, l, acc) lives in VMEM scratch and
    persists across the k-block sweep — no atomics, no shared-memory tiling.
  * BQ = BK = 128 default: MXU-shaped (128×128) matmuls; the full working set
    (q, k, v blocks + f32 scores + f32 acc) is ~0.6 MB << 16 MB VMEM, leaving
    room for the compiler's double buffering of HBM->VMEM streams.
  * GQA: the kv-head index is derived from the q-head grid coordinate
    (h // group), so each kv block is loaded once per q-head group sweep.
  * causal + sliding-window masks are applied from absolute positions;
    fully-masked (q-block, k-block) pairs are skipped with pl.when (the
    sequential grid makes this a cheap predicated no-op).

VMEM math (BQ=BK=128, hd=256 padded, bf16 in / f32 state):
  q 64 KB + k 64 KB + v 64 KB + s 64 KB + acc 128 KB + m/l 1 KB ≈ 0.4 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  sq_valid: int, sk_valid: int, bq: int, bk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # static block-level skip bound: last k position possibly visible
    def block_live() -> bool | jax.Array:
        live = k_pos[0, 0] < sk_valid                 # any valid key at all
        if causal:
            live &= (ik * bk) <= (q_offset + iq * bq + bq - 1)
        if window > 0:
            live &= (ik * bk + bk - 1) >= (q_offset + iq * bq - window + 1)
        return live

    @pl.when(block_live())
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)     # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = k_pos < sk_valid
        ok &= (q_pos < q_offset + sq_valid)
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[:, 0]                          # (bq,)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(ok, p, 0.0)
        l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)     # (bk, hd)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "softmax_scale",
                     "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    softmax_scale: float | None = None,
                    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, hd); k/v: (B, Sk, Hkv, hd). Returns (B, Sq, Hq, hd).

    Pads Sq/Sk to block multiples and hd to a multiple of 128 (MXU lane
    width); padded keys are masked, padded queries discarded on slice-out.
    """
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    group = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    sq_pad = (-Sq) % bq
    sk_pad = (-Sk) % bk
    hd_pad = (-hd) % 128
    if sq_pad or hd_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, hd_pad)))
    if sk_pad or hd_pad:
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, hd_pad)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, hd_pad)))
    Sqp, Skp, hdp = Sq + sq_pad, Sk + sk_pad, hd + hd_pad

    grid = (B, Hq, Sqp // bq, Skp // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, sq_valid=Sq, sk_valid=Sk, bq=bq, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hdp), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, hdp),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hdp),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hdp),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sqp, Hq, hdp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running denom
            pltpu.VMEM((bq, hdp), jnp.float32),    # running accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq, :, :hd]
